//! Kill-and-restart integration tests of the durable state store
//! (DESIGN.md §16), over the synthetic fixture artifacts and the "fs"
//! backend in a temp dir — no `make artifacts` needed, so these run in
//! CI:
//!
//! * node A cold-builds, serves, publishes a full snapshot + a delta,
//!   then dies; node B on the same store warm-boots to a byte-identical
//!   N2O table — zero `item_tower` executions, digest-verified, version
//!   sequence and user-state epoch resumed, and the served top-K is
//!   bitwise identical to node A's final answers;
//! * checkpointing concurrent with traffic neither fails a request nor
//!   breaks the one-N2O-lock-per-request budget (maintenance
//!   acquisitions are accounted separately);
//! * `warm_boot = false` ignores the store and cold-builds as before.

use std::path::PathBuf;
use std::sync::Arc;

use aif::config::{ServingConfig, StorageConfig};
use aif::coordinator::{Merger, ScoreRequest};
use aif::features::LatencyModel;
use aif::nearline::N2oEntry;
use aif::storage::{state_digest, CheckpointOutcome};
use aif::util::fixture;
use aif::util::json::Value;

/// Fresh fixture dir per test (tests run in parallel).
fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("aif-warmrestart-{}-{tag}", std::process::id()));
    fixture::write(&dir).expect("fixture generation");
    dir
}

/// Removes the fixture dir when the test ends (also on panic/unwind).
struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Fast AIF config with a durable "fs" store rooted inside the fixture
/// dir.  Manual checkpoints only: the tests drive `checkpoint_now`.
fn storage_cfg(dir: &PathBuf, backend: &str) -> ServingConfig {
    ServingConfig {
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 48,
        top_k: 16,
        retrieval_latency: LatencyModel::fixed(100.0),
        user_store_latency: LatencyModel::fixed(20.0),
        item_store_latency: LatencyModel::fixed(10.0),
        sim_parse_us: 0.1,
        user_cache_ttl_ms: 60_000,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        storage: StorageConfig {
            backend: backend.to_string(),
            dir: dir.join("state").to_string_lossy().into_owned(),
            checkpoint_interval_ms: 0,
            warm_boot: true,
        },
        ..Default::default()
    }
}

/// Fixed candidate override: the retrieval stage is stochastic, the
/// scoring path must not be.
fn cands() -> Vec<u32> {
    (0..48u32).collect()
}

fn score(m: &Merger, user: usize) -> Vec<aif::coordinator::ScoredItem> {
    m.score(
        ScoreRequest::user(user).with_candidates(cands()).with_top_k(16),
    )
    .expect("request succeeds")
    .items
}

/// Flip one mantissa bit in a few N2O rows — a real nearline change
/// (identical recomputes would pointer-share and produce no delta).
fn perturb_rows(core: &aif::coordinator::ServingCore, ids: &[u32]) {
    let snap = core.n2o.snapshot();
    let rows: Vec<(u32, N2oEntry)> = ids
        .iter()
        .map(|&id| {
            let mut e = snap.get(id).expect("fixture row present").to_entry();
            e.item_vec[0] = f32::from_bits(e.item_vec[0].to_bits() ^ 1);
            (id, e)
        })
        .collect();
    core.n2o.upsert(rows);
}

#[test]
fn kill_and_restart_recovers_bitwise_identical_topk() {
    let dir = fixture_dir("roundtrip");
    let _cleanup = Cleanup(dir.clone());
    let cfg = storage_cfg(&dir, "fs");
    let users = [1usize, 5, 11];

    // ---- Node A: cold build, serve, checkpoint, die. ---------------
    let a = Merger::build(cfg.clone()).expect("node A");
    assert!(
        a.core().rtp.executions_of("item_tower") > 0,
        "empty store -> cold full build"
    );
    assert!(a.core().readiness.is_ready());
    for &u in &users {
        let _ = score(&a, u); // warm serving path before the checkpoint
    }
    assert_eq!(
        a.core().checkpoint_now().expect("first checkpoint"),
        CheckpointOutcome::Full
    );
    // Nearline change after the full snapshot: the next checkpoint must
    // publish an incremental delta, not a second full.
    perturb_rows(a.core(), &[3, 77]);
    assert_eq!(
        a.core().checkpoint_now().expect("second checkpoint"),
        CheckpointOutcome::Delta
    );
    let final_topk: Vec<_> = users.iter().map(|&u| score(&a, u)).collect();
    let digest_a = state_digest(&a.core().n2o.export());
    let version_a = a.core().n2o.version();
    let hint_a = a.core().n2o.version_hint();
    let epoch_a = a.core().user_epoch();
    drop(a); // kill the process stand-in; the store outlives it

    // ---- Node B: warm boot from the store. -------------------------
    let b = Merger::build(cfg).expect("node B");
    assert_eq!(
        b.core().rtp.executions_of("item_tower"),
        0,
        "warm boot must not re-run the item tower"
    );
    assert!(b.core().readiness.is_ready(), "ready only after verify");
    assert_eq!(b.core().n2o.version(), version_a);
    assert_eq!(
        b.core().n2o.version_hint(),
        hint_a,
        "version sequence resumes where node A left it"
    );
    assert_eq!(
        state_digest(&b.core().n2o.export()),
        digest_a,
        "restored table is byte-identical (snapshot + delta replay)"
    );
    assert!(
        b.core().user_epoch() >= epoch_a,
        "user-state epoch must never rewind across a restart"
    );
    let stats = b.core().storage_stats().expect("storage block");
    assert_eq!(
        stats.get("restored").and_then(Value::as_bool),
        Some(true)
    );
    assert_eq!(
        stats.get("delta_replays").and_then(Value::as_f64),
        Some(1.0),
        "exactly the one published delta is replayed"
    );
    assert!(stats.get("restore_ms").and_then(Value::as_f64).is_some());

    // The surviving path serves the same answers, bit for bit.
    for (&u, want) in users.iter().zip(&final_topk) {
        assert_eq!(
            &score(&b, u),
            want,
            "user {u}: restored top-K diverged from node A"
        );
    }

    // Nothing changed since node A's last checkpoint, so node B's first
    // checkpoint is a no-op — restore seeds the publisher state instead
    // of rewriting a full snapshot.
    assert_eq!(
        b.core().checkpoint_now().expect("post-restore checkpoint"),
        CheckpointOutcome::Skipped
    );
}

#[test]
fn checkpoints_under_traffic_hold_the_lock_budget() {
    let dir = fixture_dir("lockbudget");
    let _cleanup = Cleanup(dir.clone());
    let merger =
        Arc::new(Merger::build(storage_cfg(&dir, "mem")).expect("merger"));
    let n2o = &merger.core().n2o;
    let locks0 = n2o
        .lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed);
    let maint0 = n2o
        .maintenance_lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed);

    const THREADS: usize = 4;
    const PER_THREAD: usize = 25;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let checkpointer = {
        let merger = Arc::clone(&merger);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut published = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                // Epoch movement makes some checkpoints write (meta-only
                // manifests) without touching the table outside the
                // counted capture export.
                merger.core().store.bump_version();
                merger.core().checkpoint_now().expect("checkpoint");
                published += 1;
                std::thread::sleep(std::time::Duration::from_micros(300));
            }
            published
        })
    };
    let users = [1usize, 5, 11, 17];
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let merger = Arc::clone(&merger);
        handles.push(std::thread::spawn(move || {
            for m in 0..PER_THREAD {
                let items = score(&merger, users[(t + m) % users.len()]);
                assert_eq!(items.len(), 16);
            }
        }));
    }
    for h in handles {
        h.join().expect("zero failed requests under checkpointing");
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let published = checkpointer.join().expect("checkpoint thread");
    assert!(published > 0, "checkpoints actually raced the traffic");

    let lock_delta = n2o
        .lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed)
        - locks0;
    let maint_delta = n2o
        .maintenance_lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed)
        - maint0;
    assert_eq!(
        lock_delta - maint_delta,
        (THREADS * PER_THREAD) as u64,
        "concurrent checkpointing must not add request-path lock traffic"
    );
    let stats = merger.core().storage_stats().expect("storage block");
    assert!(
        stats
            .get("barrier_crossings")
            .and_then(Value::as_f64)
            .unwrap_or(0.0)
            > 0.0,
        "captures crossed the checkpoint barrier"
    );
}

#[test]
fn warm_boot_off_ignores_the_store_and_cold_builds() {
    let dir = fixture_dir("coldboot");
    let _cleanup = Cleanup(dir.clone());
    let cfg = storage_cfg(&dir, "fs");

    let a = Merger::build(cfg.clone()).expect("node A");
    let before = score(&a, 5);
    assert_eq!(
        a.core().checkpoint_now().expect("checkpoint"),
        CheckpointOutcome::Full
    );
    drop(a);

    let mut cold = cfg;
    cold.storage.warm_boot = false;
    let b = Merger::build(cold).expect("cold node");
    assert!(
        b.core().rtp.executions_of("item_tower") > 0,
        "warm_boot = false must rebuild from scratch"
    );
    assert!(b.core().readiness.is_ready());
    // Same artifacts, same world: the rebuilt table serves the same
    // answers even though nothing was restored.
    assert_eq!(score(&b, 5), before);
    let stats = b.core().storage_stats().expect("storage block");
    assert_eq!(
        stats.get("restored").and_then(Value::as_bool),
        Some(false)
    );
}
