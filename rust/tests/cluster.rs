//! Distributed serving tier integration tests (DESIGN.md §19): a
//! `RemotePreRanker` router in front of in-process worker `HttpServer`s.
//!
//! * `deadline_ms` propagation: the worker sees the *remaining* budget,
//!   and an already-expired budget 504s before any wire call;
//! * shard pinning: a user's requests always land on one worker, and
//!   `route_plan` names that worker first;
//! * failover: killing a worker ejects it after the in-flight request
//!   retries onto a replica — zero failed requests — and a joined
//!   replacement is readmitted by probing;
//! * scatter-gather over real fixture `Merger`s is BITWISE-identical to
//!   a single-node `Merger` over the same artifacts;
//! * drain + rejoin under continuous traffic drops zero requests.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use aif::config::{ClusterConfig, ServingConfig};
use aif::coordinator::{
    Merger, PhaseTimings, PreRanker, RemotePreRanker, ScenarioAdmin,
    ScoreRequest, ScoreResponse, ScoredItem, ServeError,
};
use aif::features::LatencyModel;
use aif::metrics::ServingMetrics;
use aif::server::HttpServer;
use aif::util::fixture;

/// Stub worker ranker: accepts every user, records each scoring call's
/// `(user, deadline)` so tests can inspect what crossed the wire.
struct RecordingRanker {
    tag: &'static str,
    metrics: ServingMetrics,
    calls: AtomicUsize,
    seen: Mutex<Vec<(usize, Option<Duration>)>>,
}

impl RecordingRanker {
    fn new(tag: &'static str) -> Arc<RecordingRanker> {
        Arc::new(RecordingRanker {
            tag,
            metrics: ServingMetrics::new(),
            calls: AtomicUsize::new(0),
            seen: Mutex::new(Vec::new()),
        })
    }

    /// How many scoring calls mentioned `user`.
    fn hits_for(&self, user: usize) -> usize {
        let seen = self.seen.lock().unwrap();
        seen.iter().filter(|(u, _)| *u == user).count()
    }
}

impl PreRanker for RecordingRanker {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.seen.lock().unwrap().push((req.user, req.deadline));
        Ok(ScoreResponse {
            request_id: req.request_id.unwrap_or(0),
            user: req.user,
            scenario: "mock".into(),
            variant: self.tag.into(),
            tier: None,
            items: vec![ScoredItem { item: req.user as u32, score: 1.0 }],
            timings: PhaseTimings {
                total: Duration::from_micros(10),
                retrieval: Duration::from_micros(5),
                user_async: None,
                prerank: Duration::from_micros(5),
            },
            trace: None,
        })
    }

    fn variant_name(&self) -> &str {
        self.tag
    }

    fn n_users(&self) -> usize {
        1 << 20
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }
}

/// One stub worker behind a real blocking front end on an ephemeral port.
fn spawn_worker(tag: &'static str) -> (Arc<RecordingRanker>, HttpServer) {
    let ranker = RecordingRanker::new(tag);
    let server = HttpServer::start(
        Arc::clone(&ranker) as Arc<dyn PreRanker>,
        "127.0.0.1:0",
        2,
    )
    .expect("worker front end binds");
    (ranker, server)
}

/// Router config over `workers`: probing disabled (tests drive health
/// transitions explicitly), short timeouts, tiny backoff.
fn cluster_cfg(workers: Vec<String>) -> ClusterConfig {
    ClusterConfig {
        workers,
        probe_interval_ms: 0,
        connect_timeout_ms: 500,
        request_timeout_ms: 2_000,
        backoff_ms: 1,
        ..ClusterConfig::default()
    }
}

/// Total wire attempts recorded across all cluster members.
fn wire_attempts(router: &RemotePreRanker) -> u64 {
    router
        .cluster()
        .members()
        .iter()
        .map(|n| n.stats.requests.load(Ordering::Relaxed))
        .sum()
}

#[test]
fn router_forwards_remaining_deadline_to_the_worker() {
    let (worker, server) = spawn_worker("w0");
    let router =
        RemotePreRanker::connect(cluster_cfg(vec![server.addr.clone()]));
    assert_eq!(router.cluster().n_healthy(), 1, "probe admits the worker");

    let budget = Duration::from_millis(500);
    let resp = router
        .score(ScoreRequest::user(3).with_deadline(budget))
        .expect("healthy cluster scores");
    assert_eq!(resp.user, 3);

    let (user, forwarded) = {
        let seen = worker.seen.lock().unwrap();
        assert_eq!(seen.len(), 1, "exactly one scoring call reached w0");
        seen[0]
    };
    assert_eq!(user, 3);
    let remaining = forwarded.expect("deadline must propagate to the hop");
    assert!(
        remaining <= budget,
        "remaining may not exceed the original budget: {remaining:?}"
    );
    assert!(
        remaining >= Duration::from_millis(200),
        "the router ate most of a 500ms budget before the hop: \
         {remaining:?}"
    );

    // Without a client deadline nothing is forwarded.
    router.score(ScoreRequest::user(3)).expect("scores");
    assert_eq!(worker.seen.lock().unwrap()[1].1, None);
    server.shutdown();
}

#[test]
fn expired_budget_short_circuits_before_any_wire_call() {
    let (worker, server) = spawn_worker("w0");
    let router =
        RemotePreRanker::connect(cluster_cfg(vec![server.addr.clone()]));
    let attempts_before = wire_attempts(&router);

    let err = router
        .score(ScoreRequest::user(1).with_deadline(Duration::ZERO))
        .expect_err("zero budget cannot be served");
    match &err {
        ServeError::DeadlineExceeded { budget_ms, .. } => {
            assert_eq!(*budget_ms, 0.0);
        }
        other => panic!("want DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(err.http_status(), 504);
    assert_eq!(
        worker.calls.load(Ordering::SeqCst),
        0,
        "no scoring call may reach a worker"
    );
    assert_eq!(
        wire_attempts(&router),
        attempts_before,
        "the 504 fires before any wire attempt"
    );
    server.shutdown();
}

#[test]
fn users_pin_to_one_shard_and_route_plan_names_it_first() {
    let mut workers = Vec::new();
    for tag in ["w0", "w1", "w2"] {
        workers.push(spawn_worker(tag));
    }
    let addrs: Vec<String> =
        workers.iter().map(|(_, s)| s.addr.clone()).collect();
    let router = RemotePreRanker::connect(cluster_cfg(addrs.clone()));
    assert_eq!(router.cluster().n_healthy(), 3);

    for user in 0..20 {
        for _ in 0..3 {
            router.score(ScoreRequest::user(user)).expect("scores");
        }
    }
    for user in 0..20 {
        let hits: Vec<usize> =
            workers.iter().map(|(r, _)| r.hits_for(user)).collect();
        let owners: Vec<usize> = (0..hits.len())
            .filter(|i| hits[*i] > 0)
            .collect();
        assert_eq!(
            owners.len(),
            1,
            "user {user} spread across shards: {hits:?}"
        );
        assert_eq!(hits[owners[0]], 3, "every repeat hit the same shard");
        let plan = router.route_plan(user);
        assert_eq!(
            plan[0], addrs[owners[0]],
            "route_plan primary must match where traffic went"
        );
        assert_eq!(plan.len(), 3, "plan walks every distinct healthy node");
    }
    for (_, server) in workers {
        server.shutdown();
    }
}

#[test]
fn failover_ejects_dead_worker_and_rejoin_drops_zero_requests() {
    let (ranker_a, server_a) = spawn_worker("w0");
    let (ranker_b, server_b) = spawn_worker("w1");
    let addr_a = server_a.addr.clone();
    let mut cfg =
        cluster_cfg(vec![addr_a.clone(), server_b.addr.clone()]);
    cfg.eject_after = 1;
    cfg.readmit_after = 1;
    cfg.retries = 2;
    let router = RemotePreRanker::connect(cfg);
    assert_eq!(router.cluster().n_healthy(), 2);

    // A user whose primary shard is worker A (exists: A owns vnodes).
    let victim = (0..10_000)
        .find(|u| router.route_plan(*u)[0] == addr_a)
        .expect("some user maps to worker A");

    router.score(ScoreRequest::user(victim)).expect("pre-kill scores");
    assert!(ranker_a.hits_for(victim) > 0, "victim pinned to A");

    // Kill A.  The victim's next request must fail over to B — zero
    // user-visible errors — and A is ejected after that one failure.
    server_a.shutdown();
    router
        .score(ScoreRequest::user(victim))
        .expect("failover absorbs the dead worker");
    assert!(ranker_b.hits_for(victim) > 0, "replica B served the victim");
    assert_eq!(router.cluster().n_healthy(), 1, "A is ejected");

    // Every user still scores on the survivor.
    for user in 0..16 {
        router.score(ScoreRequest::user(user)).expect("survivor serves");
    }

    // Rejoin: a replacement worker joins and is readmitted by probing.
    let (ranker_c, server_c) = spawn_worker("w2");
    router
        .cluster_join(&server_c.addr)
        .expect("join accepts a valid addr");
    assert_eq!(router.cluster().n_healthy(), 1, "joined nodes start cold");
    router.cluster().probe_all_now();
    assert_eq!(router.cluster().n_healthy(), 2, "probe readmits the join");
    for user in 0..16 {
        router.score(ScoreRequest::user(user)).expect("post-join scores");
    }
    assert!(
        ranker_c.calls.load(Ordering::SeqCst) > 0
            || ranker_b.calls.load(Ordering::SeqCst) > 0,
        "traffic flows after the rejoin"
    );
    server_b.shutdown();
    server_c.shutdown();
}

#[test]
fn drain_and_join_under_traffic_drop_zero_requests() {
    let (_ranker_a, server_a) = spawn_worker("w0");
    let (_ranker_b, server_b) = spawn_worker("w1");
    let addr_a = server_a.addr.clone();
    let router = RemotePreRanker::connect(cluster_cfg(vec![
        addr_a.clone(),
        server_b.addr.clone(),
    ]));
    assert_eq!(router.cluster().n_healthy(), 2);

    for i in 0..300usize {
        if i == 100 {
            let v = router.cluster_drain(&addr_a).expect("drain known node");
            assert!(format!("{v}").contains("draining"));
            assert_eq!(router.cluster().n_healthy(), 1);
        }
        if i == 200 {
            router.cluster_join(&addr_a).expect("rejoin drained node");
            // Default `readmit_after` is two clean probe rounds.
            router.cluster().probe_all_now();
            router.cluster().probe_all_now();
            assert_eq!(router.cluster().n_healthy(), 2);
        }
        router
            .score(ScoreRequest::user(i % 24))
            .unwrap_or_else(|e| panic!("request {i} dropped: {e:?}"));
    }
    server_a.shutdown();
    server_b.shutdown();
}

// -----------------------------------------------------------------------
// Scatter-gather vs a single node, over real fixture artifacts
// -----------------------------------------------------------------------

/// Fresh fixture dir per test (tests run in parallel).
fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("aif-fixture-{}-{tag}", std::process::id()));
    fixture::write(&dir).expect("fixture generation");
    dir
}

/// Removes the fixture dir when the test ends (also on panic/unwind).
struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Fast core config: tiny modeled latencies, small candidate sets.
fn core_cfg(dir: &PathBuf) -> ServingConfig {
    ServingConfig {
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 48,
        top_k: 16,
        retrieval_latency: LatencyModel::fixed(100.0),
        user_store_latency: LatencyModel::fixed(20.0),
        item_store_latency: LatencyModel::fixed(10.0),
        sim_parse_us: 0.1,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

#[test]
fn scatter_gather_matches_single_node_bitwise() {
    let dir = fixture_dir("cluster-sg");
    let _cleanup = Cleanup(dir.clone());
    let cfg = core_cfg(&dir);

    // Two shard workers and one single-node reference, all over the
    // SAME fixture artifacts — identical score surfaces by construction.
    let shard_a = Arc::new(Merger::build(cfg.clone()).expect("shard A"));
    let shard_b = Arc::new(Merger::build(cfg.clone()).expect("shard B"));
    let reference = Merger::build(cfg).expect("reference");
    let server_a = HttpServer::start(
        Arc::clone(&shard_a) as Arc<dyn PreRanker>,
        "127.0.0.1:0",
        2,
    )
    .expect("shard A binds");
    let server_b = HttpServer::start(
        Arc::clone(&shard_b) as Arc<dyn PreRanker>,
        "127.0.0.1:0",
        2,
    )
    .expect("shard B binds");

    let router = RemotePreRanker::connect(cluster_cfg(vec![
        server_a.addr.clone(),
        server_b.addr.clone(),
    ]));
    assert_eq!(router.cluster().n_healthy(), 2);

    let candidates: Vec<u32> = (0..48u32).collect();
    for user in [1usize, 5, 11] {
        let via_router = router
            .score(
                ScoreRequest::user(user)
                    .with_candidates(candidates.clone())
                    .with_top_k(16),
            )
            .expect("router scores");
        let direct = reference
            .score(
                ScoreRequest::user(user)
                    .with_candidates(candidates.clone())
                    .with_top_k(16)
                    .with_request_id(900 + user as u64),
            )
            .expect("reference scores");
        assert_eq!(via_router.items.len(), direct.items.len());
        for (a, b) in via_router.items.iter().zip(direct.items.iter()) {
            assert_eq!(a.item, b.item, "user {user}: item order differs");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "user {user}: score for item {} not bitwise-identical",
                a.item
            );
        }
    }
    // The explicit 48-candidate list actually scattered: both shards
    // served sub-requests.
    let served = |m: &Arc<Merger>| {
        m.metrics().requests.load(Ordering::Relaxed)
    };
    assert!(
        served(&shard_a) > 0 && served(&shard_b) > 0,
        "both shards must participate in scatter-gather"
    );
    server_a.shutdown();
    server_b.shutdown();
}
