//! End-to-end coordinator integration: bring up Mergers for key pipeline
//! configurations and serve real requests through the PJRT runtime.

use std::sync::Arc;

use aif::config::{ServingConfig, SimMode};
use aif::coordinator::{Merger, ScoreRequest, ServeError};
use aif::features::LatencyModel;

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Fast config: tiny latencies, few candidates, small fleet.
fn test_cfg(variant: &str, sim: SimMode) -> ServingConfig {
    ServingConfig {
        variant: variant.into(),
        sim_mode: sim,
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 512,
        top_k: 64,
        retrieval_latency: LatencyModel::fixed(300.0),
        user_store_latency: LatencyModel::fixed(50.0),
        item_store_latency: LatencyModel::fixed(20.0),
        sim_parse_us: 0.1,
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
            .into(),
        ..Default::default()
    }
}

#[test]
fn aif_pipeline_serves_requests() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("aif", SimMode::Precached)).unwrap());
    let mut seen_users = std::collections::HashSet::new();
    for id in 0..4u64 {
        let user = (id as usize * 37) % merger.world().n_users;
        let r = merger
            .score(ScoreRequest::user(user).with_request_id(id))
            .unwrap();
        assert_eq!(r.items.len(), 64);
        assert_eq!(r.user, user);
        assert_eq!(r.request_id, id);
        assert_eq!(r.variant, "aif");
        // Scores sorted descending, all probabilities.
        for w in r.items.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        assert!(r
            .items
            .iter()
            .all(|s| (0.0..=1.0).contains(&s.score)));
        // Async phase ran and overlapped with retrieval — on the first
        // request per user; repeats may hit the cross-request cache and
        // skip phase 1 entirely.
        if seen_users.insert(user) {
            assert!(r.timings.user_async.is_some());
        }
    }
    // No single-flight computation is left dangling, and the shared
    // cache holds at most one entry per distinct user served.
    assert_eq!(merger.core().user_cache.inflight_len(), 0);
    assert!(merger.core().user_cache.entries() <= 4);
    // N2O table was fully built.
    assert_eq!(merger.core().n2o.coverage(), 1.0);
    assert!(merger.extra_storage_bytes() > 0);
}

#[test]
fn base_pipeline_is_sequential() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("base", SimMode::Off)).unwrap());
    let r = merger
        .score(ScoreRequest::user(7).with_request_id(1))
        .unwrap();
    assert_eq!(r.items.len(), 64);
    assert!(r.timings.user_async.is_none(), "no async phase in base");
}

#[test]
fn sync_sim_pipeline_works() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("t4_sim", SimMode::Sync)).unwrap());
    let r = merger
        .score(ScoreRequest::user(11).with_request_id(2))
        .unwrap();
    assert_eq!(r.items.len(), 64);
}

#[test]
fn lsh_long_term_pipeline_works() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("t4_lsh", SimMode::Off)).unwrap());
    let r = merger
        .score(ScoreRequest::user(13).with_request_id(3))
        .unwrap();
    assert_eq!(r.items.len(), 64);
}

#[test]
fn aif_and_base_rank_differently_but_validly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let aif =
        Arc::new(Merger::build(test_cfg("aif", SimMode::Precached)).unwrap());
    let base =
        Arc::new(Merger::build(test_cfg("base", SimMode::Off)).unwrap());
    let ra = aif
        .score(ScoreRequest::user(3).with_request_id(10))
        .unwrap();
    let rb = base
        .score(ScoreRequest::user(3).with_request_id(10))
        .unwrap();
    assert_eq!(ra.items.len(), rb.items.len());
}

#[test]
fn typed_api_validates_and_honors_request_knobs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("base", SimMode::Off)).unwrap());

    // Per-request top_k override is honored...
    let r = merger.score(ScoreRequest::user(3).with_top_k(5)).unwrap();
    assert_eq!(r.items.len(), 5);
    // ...and clamped to the candidate count instead of erroring.
    let r = merger
        .score(ScoreRequest::user(3).with_top_k(10_000))
        .unwrap();
    assert_eq!(r.items.len(), 512);

    // Typed errors instead of anyhow.
    assert!(matches!(
        merger.score(ScoreRequest::user(usize::MAX)),
        Err(ServeError::UnknownUser(_))
    ));
    assert!(matches!(
        merger.score(ScoreRequest::user(1).with_top_k(0)),
        Err(ServeError::BadRequest(_))
    ));
    assert!(matches!(
        merger.score(ScoreRequest::user(1).with_candidates(vec![])),
        Err(ServeError::BadRequest(_))
    ));
    assert!(matches!(
        merger
            .score(ScoreRequest::user(1).with_candidates(vec![u32::MAX])),
        Err(ServeError::BadRequest(_))
    ));

    // Candidate override scores exactly the requested set.
    let r = merger
        .score(ScoreRequest::user(1).with_candidates(vec![1, 2, 3]))
        .unwrap();
    assert_eq!(r.items.len(), 3);
    assert!(r.items.iter().all(|s| [1, 2, 3].contains(&s.item)));

    // Trace reports the stage breakdown.
    let r = merger
        .score(ScoreRequest::user(1).with_trace(true))
        .unwrap();
    let t = r.trace.expect("trace requested");
    assert_eq!(t.n_candidates, 512);
    assert!(t.stages.iter().any(|s| s.stage == "prerank"));
    assert!(t.stages.iter().any(|s| s.stage == "retrieval"));

    // A request id is allocated when absent.
    let a = merger.score(ScoreRequest::user(1)).unwrap();
    let b = merger.score(ScoreRequest::user(1)).unwrap();
    assert_ne!(a.request_id, b.request_id);
}
