//! End-to-end coordinator integration: bring up Mergers for key pipeline
//! configurations and serve real requests through the PJRT runtime.

use std::sync::Arc;

use aif::config::{ServingConfig, SimMode};
use aif::coordinator::Merger;
use aif::features::LatencyModel;

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Fast config: tiny latencies, few candidates, small fleet.
fn test_cfg(variant: &str, sim: SimMode) -> ServingConfig {
    ServingConfig {
        variant: variant.into(),
        sim_mode: sim,
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 512,
        top_k: 64,
        retrieval_latency: LatencyModel::fixed(300.0),
        user_store_latency: LatencyModel::fixed(50.0),
        item_store_latency: LatencyModel::fixed(20.0),
        sim_parse_us: 0.1,
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
            .into(),
        ..Default::default()
    }
}

#[test]
fn aif_pipeline_serves_requests() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("aif", SimMode::Precached)).unwrap());
    for id in 0..4u64 {
        let r = merger.handle(id, (id as usize * 37) % merger.world.n_users)
            .unwrap();
        assert_eq!(r.top_k.len(), 64);
        // Scores sorted descending, all probabilities.
        for w in r.top_k.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        assert!(r.top_k.iter().all(|&(_, s)| (0.0..=1.0).contains(&s)));
        // Async phase ran and overlapped with retrieval.
        assert!(r.timings.user_async.is_some());
    }
    // User cache is drained (two-phase handoff consumed).
    assert!(merger.user_cache.is_empty());
    // N2O table was fully built.
    assert_eq!(merger.n2o.coverage(), 1.0);
    assert!(merger.extra_storage_bytes() > 0);
}

#[test]
fn base_pipeline_is_sequential() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("base", SimMode::Off)).unwrap());
    let r = merger.handle(1, 7).unwrap();
    assert_eq!(r.top_k.len(), 64);
    assert!(r.timings.user_async.is_none(), "no async phase in base");
}

#[test]
fn sync_sim_pipeline_works() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("t4_sim", SimMode::Sync)).unwrap());
    let r = merger.handle(2, 11).unwrap();
    assert_eq!(r.top_k.len(), 64);
}

#[test]
fn lsh_long_term_pipeline_works() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("t4_lsh", SimMode::Off)).unwrap());
    let r = merger.handle(3, 13).unwrap();
    assert_eq!(r.top_k.len(), 64);
}

#[test]
fn aif_and_base_rank_differently_but_validly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let aif =
        Arc::new(Merger::build(test_cfg("aif", SimMode::Precached)).unwrap());
    let base =
        Arc::new(Merger::build(test_cfg("base", SimMode::Off)).unwrap());
    let ra = aif.handle(10, 3).unwrap();
    let rb = base.handle(10, 3).unwrap();
    assert_eq!(ra.top_k.len(), rb.top_k.len());
}
