//! Multi-scenario serving core integration tests (DESIGN.md §13), running
//! against the synthetic fixture artifact set (`util::fixture`) over the
//! deterministic PJRT stand-in — no `make artifacts` needed, so these run
//! in CI:
//!
//! * one `ServingCore` serves >= 3 concurrently registered scenarios with
//!   scores BITWISE-equal to dedicated single-variant Mergers;
//! * every engine shares the single RtpPool / N2oTable substrate, and
//!   scenarios on the same head artifact share ONE coalescer queue;
//! * hot reload/add/remove under concurrent traffic: zero failed
//!   requests, no lost replies, responses stay bitwise-identical across
//!   the swap.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use aif::config::{ScenarioConfig, ServingConfig, SimMode};
use aif::coordinator::{Merger, ScoreRequest, ServeError};
use aif::features::LatencyModel;
use aif::util::fixture;

/// Fresh fixture dir per test (tests run in parallel).
fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("aif-fixture-{}-{tag}", std::process::id()));
    fixture::write(&dir).expect("fixture generation");
    dir
}

/// Removes the fixture dir when the test ends (also on panic/unwind).
struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Fast core config: tiny modeled latencies, small candidate sets.
fn core_cfg(dir: &PathBuf) -> ServingConfig {
    ServingConfig {
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 48,
        top_k: 16,
        retrieval_latency: LatencyModel::fixed(100.0),
        user_store_latency: LatencyModel::fixed(20.0),
        item_store_latency: LatencyModel::fixed(10.0),
        sim_parse_us: 0.1,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

fn scenario(
    name: &str,
    variant: &str,
    sim: SimMode,
    base: &ServingConfig,
) -> ScenarioConfig {
    ScenarioConfig {
        name: name.into(),
        variant: variant.into(),
        sim_mode: sim,
        ..ScenarioConfig::from_serving(name, base)
    }
}

fn dedicated(
    dir: &PathBuf,
    variant: &str,
    sim: SimMode,
) -> Arc<Merger> {
    let cfg = ServingConfig {
        variant: variant.into(),
        sim_mode: sim,
        ..core_cfg(dir)
    };
    Arc::new(Merger::build(cfg).expect("dedicated merger"))
}

/// Fixed candidate override: the retrieval stage is stochastic, the
/// scoring path must not be.
fn cands() -> Vec<u32> {
    (0..48u32).collect()
}

#[test]
fn shared_core_matches_dedicated_mergers_bitwise() {
    let dir = fixture_dir("equiv");
    let _cleanup = Cleanup(dir.clone());
    let base = core_cfg(&dir);
    let mut cfg = core_cfg(&dir);
    cfg.scenarios = vec![
        scenario("base-arm", "base", SimMode::Off, &base),
        scenario("aif-arm", "aif", SimMode::Precached, &base),
        scenario("aif-sync", "aif", SimMode::Sync, &base),
    ];
    cfg.default_scenario = Some("base-arm".into());
    let shared = Arc::new(Merger::build(cfg).expect("shared merger"));
    assert_eq!(shared.registry().len(), 3);

    // One substrate: every engine serves over the same core, i.e. the
    // same RtpPool / N2oTable / cache instances.
    let engines = shared.registry().engines();
    assert_eq!(engines.len(), 3);
    for e in &engines[1..] {
        assert!(
            Arc::ptr_eq(e.core(), engines[0].core()),
            "engines must share one ServingCore"
        );
    }
    // The nearline table was built once and is fully covered.
    assert_eq!(shared.core().n2o.coverage(), 1.0);

    // Bitwise score-equivalence with dedicated single-variant Mergers.
    let refs: Vec<(&str, Arc<Merger>)> = vec![
        ("base-arm", dedicated(&dir, "base", SimMode::Off)),
        ("aif-arm", dedicated(&dir, "aif", SimMode::Precached)),
        ("aif-sync", dedicated(&dir, "aif", SimMode::Sync)),
    ];
    for (name, ded) in &refs {
        for (i, user) in [1usize, 5, 11].into_iter().enumerate() {
            let req = |id: u64| {
                ScoreRequest::user(user)
                    .with_request_id(id)
                    .with_candidates(cands())
                    .with_top_k(16)
            };
            let a = ded.score(req(10 + i as u64)).expect("dedicated scores");
            let b = shared
                .score(req(20 + i as u64).with_scenario(*name))
                .expect("shared-core scores");
            assert_eq!(
                a.items, b.items,
                "{name}/user {user}: shared-core top-K diverged from the \
                 dedicated Merger"
            );
            assert_eq!(b.scenario, *name);
        }
    }

    // Responses carry the scenario that served them; default routing
    // goes to the configured default.
    let r = shared
        .score(ScoreRequest::user(2).with_candidates(cands()))
        .unwrap();
    assert_eq!(r.scenario, "base-arm");
    assert_eq!(r.variant, "base");
}

#[test]
fn scenarios_on_one_head_share_a_single_coalescer_queue() {
    let dir = fixture_dir("coalesce");
    let _cleanup = Cleanup(dir.clone());
    let base = core_cfg(&dir);
    let mut a = scenario("aif-a", "aif", SimMode::Precached, &base);
    a.coalesce.enabled = true;
    let mut b = scenario("aif-b", "aif", SimMode::Off, &base);
    b.coalesce.enabled = true;
    let mut cfg = core_cfg(&dir);
    cfg.scenarios = vec![a, b];
    cfg.default_scenario = Some("aif-a".into());
    let shared = Arc::new(Merger::build(cfg).expect("shared merger"));

    let engines = shared.registry().engines();
    assert!(engines.iter().all(|e| e.coalescing()));
    let (a, b) = (
        engines[0].coalescer_handle().expect("aif-a coalescer"),
        engines[1].coalescer_handle().expect("aif-b coalescer"),
    );
    assert!(
        Arc::ptr_eq(a, b),
        "two scenarios on head_aif must share ONE coalescer queue"
    );
    assert_eq!(shared.core().live_coalescers(), 1);

    // Cross-scenario coalesced dispatch stays score-invariant: identical
    // to a dedicated non-coalescing Merger.
    let solo = dedicated(&dir, "aif", SimMode::Off);
    let req = |id: u64| {
        ScoreRequest::user(7)
            .with_request_id(id)
            .with_candidates(cands())
            .with_top_k(16)
    };
    let want = solo.score(req(1)).unwrap();
    let got = shared.score(req(2).with_scenario("aif-b")).unwrap();
    assert_eq!(want.items, got.items, "coalesced == per-request scores");
}

#[test]
fn hot_reload_and_churn_under_concurrent_traffic() {
    let dir = fixture_dir("reload");
    let _cleanup = Cleanup(dir.clone());
    let base = core_cfg(&dir);
    let mut cfg = core_cfg(&dir);
    cfg.scenarios = vec![
        scenario("base-arm", "base", SimMode::Off, &base),
        scenario("aif-arm", "aif", SimMode::Precached, &base),
    ];
    cfg.default_scenario = Some("base-arm".into());
    let shared = Arc::new(Merger::build(cfg).expect("shared merger"));

    // Reference responses BEFORE any reload: the swap must be
    // score-preserving, bitwise.
    let users = [1usize, 5, 11, 17];
    let reference: Vec<Vec<_>> = ["base-arm", "aif-arm"]
        .iter()
        .map(|name| {
            users
                .iter()
                .map(|&u| {
                    shared
                        .score(
                            ScoreRequest::user(u)
                                .with_candidates(cands())
                                .with_top_k(16)
                                .with_scenario(*name),
                        )
                        .expect("reference scores")
                        .items
                })
                .collect()
        })
        .collect();

    const N_THREADS: usize = 4;
    const M_REQUESTS: usize = 40;
    let stop_churn = Arc::new(AtomicBool::new(false));

    // Churn thread: hot reload "aif-arm" + add/remove a third scenario in
    // a loop while traffic flows.
    let churner = {
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop_churn);
        let base = core_cfg(&dir);
        std::thread::spawn(move || {
            let mut reloads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                shared
                    .registry()
                    .reload("aif-arm")
                    .expect("hot reload succeeds");
                reloads += 1;
                let churn =
                    scenario("churn", "base", SimMode::Off, &base);
                shared.registry().add(churn).expect("hot add succeeds");
                shared
                    .registry()
                    .remove("churn")
                    .expect("hot remove succeeds");
                // Leave the scheduler room for the traffic threads on
                // small CI machines.
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            reloads
        })
    };

    // Traffic threads: every request must succeed AND return exactly the
    // pre-reload reference scores (no lost replies: the thread loop
    // itself completing proves every request got a response).
    let mut handles = Vec::new();
    for t in 0..N_THREADS {
        let shared = Arc::clone(&shared);
        let reference = reference.clone();
        handles.push(std::thread::spawn(move || {
            for m in 0..M_REQUESTS {
                let which = (t + m) % 2;
                let name = ["base-arm", "aif-arm"][which];
                let (ui, user) = {
                    let i = (t * M_REQUESTS + m) % users.len();
                    (i, users[i])
                };
                // Thread-unique ids: concurrent identical ids on one
                // engine would alias the async-phase cache key.
                let id = (t * M_REQUESTS + m) as u64 + 1000;
                let r = shared
                    .score(
                        ScoreRequest::user(user)
                            .with_request_id(id)
                            .with_candidates(cands())
                            .with_top_k(16)
                            .with_scenario(name),
                    )
                    .expect("no failed requests during hot reload");
                assert_eq!(
                    r.items, reference[which][ui],
                    "scores changed across a hot reload ({name}, user \
                     {user})"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("traffic thread panicked");
    }
    stop_churn.store(true, Ordering::Relaxed);
    let reloads = churner.join().expect("churn thread panicked");
    assert!(reloads > 0, "at least one reload raced the traffic");

    // Reload bumped the generation; the registry is back to 2 scenarios.
    let aif = shared.registry().get(Some("aif-arm")).unwrap();
    assert_eq!(aif.generation, reloads);
    assert_eq!(shared.registry().len(), 2);
    // No single-flight computation is left dangling across any engine
    // generation (the quiescence check the request-scoped `is_empty`
    // used to provide; shared entries persist by design).
    assert_eq!(shared.core().user_cache.inflight_len(), 0);
}

#[test]
fn zero_copy_path_is_bitwise_identical_lock_frugal_and_leak_free() {
    // ISSUE 4 acceptance, integration-shaped: the arena-backed hot path
    // must (a) return bitwise-identical responses to the owned path,
    // (b) take exactly ONE N2O lock per request, and (c) hold no arena
    // buffer once the response is out.
    let dir = fixture_dir("zerocopy");
    let _cleanup = Cleanup(dir.clone());
    // core_cfg defaults to the full AIF variant (async user + nearline
    // items + SIM precached) — the hot path under test.
    let on = Arc::new(Merger::build(core_cfg(&dir)).expect("zero-copy"));
    let off_cfg = ServingConfig {
        zero_copy: false,
        ..core_cfg(&dir)
    };
    let off = Arc::new(Merger::build(off_cfg).expect("owned path"));

    for (i, user) in [1usize, 5, 11, 17].into_iter().enumerate() {
        let req = |id: u64| {
            ScoreRequest::user(user)
                .with_request_id(id)
                .with_candidates(cands())
                .with_top_k(16)
        };
        let a = off.score(req(600 + i as u64)).expect("owned scores");
        let b = on.score(req(700 + i as u64)).expect("zero-copy scores");
        assert_eq!(
            a.items, b.items,
            "user {user}: zero-copy top-K diverged from the owned path"
        );
    }

    // One snapshot pin — one lock acquisition — per request, however
    // many mini-batches the request fans out into.
    let n2o = &on.core().n2o;
    let before = n2o
        .lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed);
    const N: u64 = 12;
    for id in 0..N {
        let r = on
            .score(
                ScoreRequest::user((id as usize * 7) % 24)
                    .with_request_id(5000 + id)
                    .with_candidates(cands())
                    .with_top_k(16),
            )
            .expect("zero-copy request");
        assert_eq!(r.items.len(), 16);
    }
    let delta = n2o
        .lock_acquisitions
        .load(std::sync::atomic::Ordering::Relaxed)
        - before;
    assert_eq!(delta, N, "exactly one N2O lock acquisition per request");

    // Every pooled buffer taken on those requests is back in the pool.
    let arena = &on.core().arena;
    assert_eq!(arena.outstanding(), 0, "arena buffers leaked");
    assert!(
        arena
            .reuses
            .load(std::sync::atomic::Ordering::Relaxed)
            > 0,
        "the zero-copy path must actually hit the arena"
    );
    // The owned-path core must not have touched its arena at all.
    assert_eq!(
        off.core()
            .arena
            .allocs
            .load(std::sync::atomic::Ordering::Relaxed)
            + off
                .core()
                .arena
                .reuses
                .load(std::sync::atomic::Ordering::Relaxed),
        0,
        "zero_copy=false must keep the legacy owned allocations"
    );
}

#[test]
fn registry_admin_contract() {
    let dir = fixture_dir("admin");
    let _cleanup = Cleanup(dir.clone());
    let base = core_cfg(&dir);
    let mut cfg = core_cfg(&dir);
    cfg.scenarios = vec![
        scenario("main", "aif", SimMode::Precached, &base),
        scenario("fallback", "base", SimMode::Off, &base),
    ];
    cfg.default_scenario = Some("main".into());
    let merger = Merger::build(cfg).expect("merger");
    let reg = merger.registry();

    // Routing: named, default, unknown.
    let r = merger
        .score(
            ScoreRequest::user(1)
                .with_candidates(cands())
                .with_scenario("fallback"),
        )
        .unwrap();
    assert_eq!(r.scenario, "fallback");
    let main_engine = reg.get(Some("main")).unwrap();
    let errs_before = main_engine
        .metrics
        .errors
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(matches!(
        merger.score(ScoreRequest::user(1).with_scenario("nope")),
        Err(ServeError::UnknownScenario(_))
    ));
    // Routing failures are not charged to any scenario's error metric.
    assert_eq!(
        main_engine
            .metrics
            .errors
            .load(std::sync::atomic::Ordering::Relaxed),
        errs_before
    );

    // Listing order + default flag.
    let names = reg.names();
    assert_eq!(names, vec!["main".to_string(), "fallback".to_string()]);
    let infos = reg.infos();
    assert!(infos[0].is_default && !infos[1].is_default);
    assert_eq!(infos[0].variant, "aif");

    // Duplicate add fails; unknown reload/remove are typed errors; the
    // default cannot be removed.
    let dup = scenario("main", "base", SimMode::Off, &core_cfg(&dir));
    assert!(reg.add(dup).is_err());
    assert!(matches!(
        reg.reload("nope"),
        Err(ServeError::UnknownScenario(_))
    ));
    assert!(matches!(
        reg.remove("nope"),
        Err(ServeError::UnknownScenario(_))
    ));
    assert!(matches!(
        reg.remove("main"),
        Err(ServeError::BadRequest(_))
    ));

    // Remove works for non-default; traffic to it then 404s.
    reg.remove("fallback").unwrap();
    assert_eq!(reg.len(), 1);
    assert!(matches!(
        merger.score(ScoreRequest::user(1).with_scenario("fallback")),
        Err(ServeError::UnknownScenario(_))
    ));

    // Unknown variants fail registration cleanly (fleet keeps serving).
    let bad = scenario("bad", "no_such_variant", SimMode::Off, &core_cfg(&dir));
    assert!(reg.add(bad).is_err());
    assert!(merger
        .score(ScoreRequest::user(1).with_candidates(cands()))
        .is_ok());
}
