//! Streaming nearline pipeline tests (DESIGN.md §17): queue semantics
//! against a mock applier (coalescing, subsumption, backpressure, retry,
//! shutdown drain) plus worker-level checks over the synthetic fixture
//! (empty-batch no-op, one write lock per drained batch, fault-injected
//! retries that lose nothing).

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use aif::config::{BackpressurePolicy, NearlineConfig};
use aif::features::World;
use aif::lsh::Hasher;
use aif::nearline::{
    IncrementalReport, N2oTable, NearlineWorker, PublishOutcome,
    UpdateApplier, UpdateEvent, UpdateQueue,
};
use aif::runtime::{Manifest, RtpPool};
use aif::util::fixture;

// ---------------------------------------------------------------- mock --

/// Scriptable applier: records every batch, optionally blocks on a gate
/// (so tests control exactly which events share a drained batch) and
/// fails a chosen id set for a budgeted number of batches.
#[derive(Default)]
struct MockApplier {
    batches: Mutex<Vec<Vec<u32>>>,
    full_versions: Mutex<Vec<u64>>,
    /// Held by the test to park the drain thread inside an apply.
    gate: Mutex<()>,
    in_apply: AtomicBool,
    /// Separate park for full builds, so a test can release incremental
    /// applies while still holding the build mid-flight.
    gate_full: Mutex<()>,
    in_full: AtomicBool,
    fail_ids: Mutex<BTreeSet<u32>>,
    /// How many more applies report `fail_ids` as failed.
    fail_budget: AtomicU64,
    fail_full_budget: AtomicU64,
}

impl MockApplier {
    fn wait_in_apply(&self) {
        while !self.in_apply.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn wait_in_full(&self) {
        while !self.in_full.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn applied_ids(&self) -> Vec<u32> {
        let batches = self.batches.lock().unwrap();
        batches.iter().flatten().copied().collect()
    }
}

impl UpdateApplier for MockApplier {
    fn apply_incremental(&self, items: &[u32]) -> IncrementalReport {
        self.in_apply.store(true, Ordering::Release);
        let _g = self.gate.lock().unwrap();
        self.in_apply.store(false, Ordering::Release);
        let failing = self
            .fail_budget
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                v.checked_sub(1)
            })
            .is_ok();
        let fail_ids = self.fail_ids.lock().unwrap();
        let (failed, ok): (Vec<u32>, Vec<u32>) = items
            .iter()
            .partition(|&&id| failing && fail_ids.contains(&id));
        self.batches.lock().unwrap().push(ok.clone());
        IncrementalReport {
            applied: ok.len(),
            failed,
            last_error: failing.then(|| "scripted failure".into()),
        }
    }

    fn apply_full(&self, version: u64) -> anyhow::Result<()> {
        self.in_full.store(true, Ordering::Release);
        let _g = self.gate_full.lock().unwrap();
        self.in_full.store(false, Ordering::Release);
        let failing = self
            .fail_full_budget
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                v.checked_sub(1)
            })
            .is_ok();
        anyhow::ensure!(!failing, "scripted full-build failure");
        self.full_versions.lock().unwrap().push(version);
        Ok(())
    }
}

fn cfg(capacity: usize, policy: BackpressurePolicy) -> NearlineConfig {
    NearlineConfig {
        queue_capacity: capacity,
        policy,
        max_batch: 1024,
        linger_ms: 1.0,
        retry_limit: 3,
        hot_min_touches: 0,
        compact_every: 0,
    }
}

// -------------------------------------------------- queue (mock) tests --

#[test]
fn duplicate_ids_coalesce_into_one_apply() {
    let mock = Arc::new(MockApplier::default());
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    // Park the drain thread on a decoy so the three overlapping events
    // are all pending when the next batch is taken.
    let gate = mock.gate.lock().unwrap();
    q.publish(UpdateEvent::ItemFeatures(vec![900]));
    mock.wait_in_apply();
    q.publish(UpdateEvent::ItemFeatures(vec![1, 2, 3]));
    q.publish(UpdateEvent::ItemFeatures(vec![2, 3, 4]));
    q.publish(UpdateEvent::ItemFeatures(vec![3, 4, 5]));
    drop(gate);
    q.flush();

    let batches = mock.batches.lock().unwrap().clone();
    assert_eq!(batches.len(), 2, "decoy batch + one coalesced batch");
    assert_eq!(batches[1], vec![1, 2, 3, 4, 5], "sorted unique union");
    assert_eq!(q.stats.coalesced_items.load(Ordering::Relaxed), 4);
    assert_eq!(q.stats.applied_items.load(Ordering::Relaxed), 6);
    assert_eq!(q.stats.failed_updates.load(Ordering::Relaxed), 0);
    // Every published id has a visibility watermark.
    for id in [1, 2, 3, 4, 5, 900] {
        assert!(q.updated_at_ms(id).is_some(), "watermark for {id}");
    }
    q.shutdown();
}

#[test]
fn model_swap_subsumes_prior_incrementals_but_not_later_ones() {
    let mock = Arc::new(MockApplier::default());
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    // Queue incrementals BEFORE the swap while the drain thread is
    // parked in a decoy apply, so the swap sees them as pending.  The
    // full-build gate is held too, parking the build the moment the
    // drain thread reaches it.
    let gate = mock.gate.lock().unwrap();
    let gate_full = mock.gate_full.lock().unwrap();
    q.publish(UpdateEvent::ItemFeatures(vec![700]));
    mock.wait_in_apply();
    q.publish(UpdateEvent::ItemFeatures(vec![1, 2, 3]));
    q.publish(UpdateEvent::ModelSwap { version: 7 });
    drop(gate);
    // Publish an event mid-build: it must NOT be subsumed.
    mock.wait_in_full();
    q.publish(UpdateEvent::ItemFeatures(vec![9]));
    drop(gate_full);
    q.flush();

    assert_eq!(*mock.full_versions.lock().unwrap(), vec![7]);
    assert_eq!(q.stats.subsumed_items.load(Ordering::Relaxed), 3);
    let applied = mock.applied_ids();
    assert!(!applied.contains(&1), "pre-swap event was subsumed");
    assert!(applied.contains(&9), "mid-build event was applied");
    for id in [1, 2, 3, 9] {
        assert!(q.updated_at_ms(id).is_some(), "watermark for {id}");
    }
    q.shutdown();
}

#[test]
fn reject_policy_counts_drops_when_full() {
    let mock = Arc::new(MockApplier::default());
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(4, BackpressurePolicy::Reject),
        None,
    );
    let gate = mock.gate.lock().unwrap();
    q.publish(UpdateEvent::ItemFeatures(vec![100]));
    mock.wait_in_apply(); // decoy in flight; lanes empty again
    assert_eq!(
        q.publish(UpdateEvent::ItemFeatures(vec![1, 2, 3])),
        PublishOutcome::Enqueued
    );
    assert_eq!(
        q.publish(UpdateEvent::ItemFeatures(vec![4, 5, 6])),
        PublishOutcome::Rejected,
        "3 pending + 3 new > capacity 4"
    );
    drop(gate);
    q.flush();
    assert_eq!(q.stats.rejected_items.load(Ordering::Relaxed), 3);
    let applied = mock.applied_ids();
    assert!(applied.contains(&1) && !applied.contains(&4));
    q.shutdown();
}

#[test]
fn block_policy_stalls_producer_until_capacity_frees() {
    let mock = Arc::new(MockApplier::default());
    let q = Arc::new(UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(4, BackpressurePolicy::Block),
        None,
    ));
    let gate = mock.gate.lock().unwrap();
    q.publish(UpdateEvent::ItemFeatures(vec![100]));
    mock.wait_in_apply();
    q.publish(UpdateEvent::ItemFeatures(vec![1, 2, 3]));
    let q2 = Arc::clone(&q);
    let producer = std::thread::spawn(move || {
        q2.publish(UpdateEvent::ItemFeatures(vec![4, 5, 6]))
    });
    // The producer must be parked on the capacity condvar.
    while q.stats.blocked_publishes.load(Ordering::Relaxed) == 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    drop(gate); // drain resumes -> capacity frees -> producer completes
    assert_eq!(producer.join().unwrap(), PublishOutcome::Enqueued);
    q.flush();
    let applied: BTreeSet<u32> = mock.applied_ids().into_iter().collect();
    for id in [1, 2, 3, 4, 5, 6, 100] {
        assert!(applied.contains(&id), "blocked publish still landed {id}");
    }
    assert_eq!(q.stats.rejected_items.load(Ordering::Relaxed), 0);
    q.stop();
}

#[test]
fn shutdown_drains_every_pending_event() {
    let mock = Arc::new(MockApplier::default());
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    let mut published: BTreeSet<u32> = BTreeSet::new();
    for i in 0..20u32 {
        let ids: Vec<u32> = (i * 10..i * 10 + 7).collect();
        published.extend(&ids);
        assert_eq!(
            q.publish(UpdateEvent::ItemFeatures(ids)),
            PublishOutcome::Enqueued
        );
    }
    q.shutdown(); // drains, then joins
    let applied: BTreeSet<u32> = mock.applied_ids().into_iter().collect();
    assert_eq!(applied, published, "no event lost across shutdown");
    // The queue is closed to new work after shutdown begins.
    assert_eq!(q.stats.failed_updates.load(Ordering::Relaxed), 0);
}

#[test]
fn failed_batch_requeues_and_eventually_applies() {
    let mock = Arc::new(MockApplier::default());
    *mock.fail_ids.lock().unwrap() = BTreeSet::from([2]);
    mock.fail_budget.store(2, Ordering::Relaxed);
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    q.publish(UpdateEvent::ItemFeatures(vec![1, 2, 3]));
    q.flush();
    assert_eq!(q.stats.failed_updates.load(Ordering::Relaxed), 0);
    assert_eq!(q.stats.retried_batches.load(Ordering::Relaxed), 2);
    assert_eq!(q.stats.requeued_items.load(Ordering::Relaxed), 2);
    assert_eq!(q.stats.applied_items.load(Ordering::Relaxed), 3);
    assert!(q.updated_at_ms(2).is_some(), "retried id became visible");
    q.shutdown();
}

#[test]
fn retry_exhaustion_is_counted_not_silent() {
    let mock = Arc::new(MockApplier::default());
    *mock.fail_ids.lock().unwrap() = BTreeSet::from([5]);
    mock.fail_budget.store(u64::MAX, Ordering::Relaxed);
    let mut c = cfg(1 << 16, BackpressurePolicy::Block);
    c.retry_limit = 1;
    let q = UpdateQueue::start_with(Arc::clone(&mock) as Arc<dyn UpdateApplier>, c, None);
    q.publish(UpdateEvent::ItemFeatures(vec![5]));
    q.flush();
    assert_eq!(
        q.stats.failed_updates.load(Ordering::Relaxed),
        1,
        "exhausted retries are accounted, not dropped with a log line"
    );
    assert_eq!(q.updated_at_ms(5), None);
    assert_eq!(q.depth(), 0, "exhausted item no longer pending");
    q.shutdown();
}

#[test]
fn failed_full_build_retries_then_lands() {
    let mock = Arc::new(MockApplier::default());
    mock.fail_full_budget.store(1, Ordering::Relaxed);
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    q.publish(UpdateEvent::ModelSwap { version: 3 });
    q.flush();
    assert_eq!(*mock.full_versions.lock().unwrap(), vec![3]);
    assert_eq!(q.stats.retried_batches.load(Ordering::Relaxed), 1);
    assert_eq!(q.stats.full_rebuilds.load(Ordering::Relaxed), 1);
    assert_eq!(q.stats.failed_full_builds.load(Ordering::Relaxed), 0);
    q.shutdown();
}

#[test]
fn empty_event_is_a_noop() {
    let mock = Arc::new(MockApplier::default());
    let q = UpdateQueue::start_with(
        Arc::clone(&mock) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    assert_eq!(
        q.publish(UpdateEvent::ItemFeatures(vec![])),
        PublishOutcome::Enqueued
    );
    q.flush();
    assert_eq!(q.depth(), 0);
    assert_eq!(q.stats.enqueued_items.load(Ordering::Relaxed), 0);
    assert!(mock.batches.lock().unwrap().is_empty());
    q.shutdown();
}

// ------------------------------------------- worker (fixture) tests --

fn fixture_dir(tag: &str) -> PathBuf {
    let name = format!("aif-nlchurn-{}-{tag}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    fixture::write(&dir).expect("fixture generation");
    dir
}

struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn worker_over(dir: &PathBuf) -> (Arc<NearlineWorker>, Arc<N2oTable>) {
    let manifest = Arc::new(Manifest::load(dir.to_str().unwrap()).expect("manifest"));
    let world = Arc::new(World::load(&manifest).expect("world"));
    let rtp = Arc::new(RtpPool::new(
        Arc::clone(&manifest),
        vec!["item_tower".into()],
        2,
    ));
    let hasher = Arc::new(Hasher::from_table(&world.w_hash));
    let table = Arc::new(N2oTable::new(
        world.n_items,
        manifest.dim("D"),
        manifest.dim("N_BRIDGE"),
        manifest.dim("D_LSH_BITS"),
    ));
    let worker = Arc::new(NearlineWorker::new(
        rtp,
        world,
        hasher,
        Arc::clone(&table),
        manifest.batch,
    ));
    (worker, table)
}

#[test]
fn worker_empty_incremental_is_noop_and_batch_takes_one_lock() {
    let dir = fixture_dir("worker");
    let _cleanup = Cleanup(dir.clone());
    let (worker, table) = worker_over(&dir);
    worker.full_build(1).expect("full build");

    // Satellite fix: `incremental(&[])` must not panic in
    // `item_raw_tensor` and must not touch the table.
    let locks0 = table.lock_acquisitions.load(Ordering::Relaxed);
    let report = worker.incremental(&[]);
    assert_eq!(report.applied, 0);
    assert!(report.failed.is_empty());
    assert_eq!(table.lock_acquisitions.load(Ordering::Relaxed), locks0);

    // A multi-chunk batch (3 × batch size) lands in ONE write lock, and
    // that lock is maintenance-counted (request budget untouched).
    let before = table.snapshot();
    let n = worker.batch * 3;
    let ids: Vec<u32> = (0..n as u32).collect();
    let locks0 = table.lock_acquisitions.load(Ordering::Relaxed);
    let maint0 = table.maintenance_lock_acquisitions.load(Ordering::Relaxed);
    let report = worker.incremental(&ids);
    assert_eq!(report.applied, n);
    assert_eq!(
        table.lock_acquisitions.load(Ordering::Relaxed) - locks0,
        1,
        "one write lock per drained batch, not per chunk"
    );
    assert_eq!(
        table.maintenance_lock_acquisitions.load(Ordering::Relaxed) - maint0,
        1
    );
    // Deterministic model -> recompute writes bitwise-identical rows.
    let after = table.snapshot();
    let (b, a) = (before.get(5).unwrap(), after.get(5).unwrap());
    assert_eq!(b.to_entry(), a.to_entry(), "recompute is bitwise stable");
}

#[test]
fn injected_failures_requeue_through_queue_without_loss() {
    let dir = fixture_dir("faults");
    let _cleanup = Cleanup(dir.clone());
    let (worker, table) = worker_over(&dir);
    worker.full_build(1).expect("full build");

    // Direct worker call first: the failed chunk's ids come back.
    worker.inject_failures(1);
    let report = worker.incremental(&[3, 4]);
    assert_eq!(report.applied, 0);
    assert_eq!(report.failed, vec![3, 4]);
    assert!(report.last_error.is_some());

    // Through the queue: the retry path heals the injected failure.
    let q = UpdateQueue::start_with(
        Arc::clone(&worker) as Arc<dyn UpdateApplier>,
        cfg(1 << 16, BackpressurePolicy::Block),
        None,
    );
    worker.inject_failures(1);
    q.publish(UpdateEvent::ItemFeatures(vec![7, 8, 9]));
    q.flush();
    assert_eq!(q.stats.failed_updates.load(Ordering::Relaxed), 0);
    assert!(q.stats.requeued_items.load(Ordering::Relaxed) > 0);
    for id in [7, 8, 9] {
        assert!(q.updated_at_ms(id).is_some(), "watermark for {id}");
    }
    assert_eq!(table.version(), 1, "incrementals never bump the version");
    q.shutdown();
}
