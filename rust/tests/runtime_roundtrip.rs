//! Cross-language correctness anchor: replay the golden fixture emitted by
//! `python/compile/aot.py` through the rust PJRT runtime and assert the
//! towers and heads reproduce the python oracle outputs.

use aif::runtime::{Engine, Manifest, Tensor};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

const TOL: f32 = 5e-4;

#[test]
fn user_tower_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "user_tower").unwrap();
    let inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("seq_long_raw").unwrap(),
        m.load_golden("seq_sign").unwrap(),
    ];
    let out = engine.execute("user_tower", &inputs).unwrap();
    let expect = [
        m.load_golden("user_tower.u_vec").unwrap(),
        m.load_golden("user_tower.bea_v").unwrap(),
        m.load_golden("user_tower.seq_emb").unwrap(),
        m.load_golden("user_tower.din_base").unwrap(),
        m.load_golden("user_tower.din_g").unwrap(),
    ];
    for (o, e) in out.iter().zip(&expect) {
        let d = o.max_abs_diff(e);
        assert!(d < TOL, "user_tower diff {d}");
    }
}

#[test]
fn item_tower_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "item_tower").unwrap();
    let inputs = vec![m.load_golden("item_raw").unwrap()];
    let out = engine.execute("item_tower", &inputs).unwrap();
    let expect = [
        m.load_golden("item_tower.item_vec").unwrap(),
        m.load_golden("item_tower.bea_w").unwrap(),
    ];
    for (o, e) in out.iter().zip(&expect) {
        let d = o.max_abs_diff(e);
        assert!(d < TOL, "item_tower diff {d}");
    }
}

#[test]
fn head_base_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "head_base").unwrap();
    let inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("item_raw").unwrap(),
    ];
    let scores = engine.execute1("head_base", &inputs).unwrap();
    let expect = m.load_golden("head_base.scores").unwrap();
    let d = scores.max_abs_diff(&expect);
    assert!(d < TOL, "head_base diff {d}");
}

#[test]
fn head_aif_matches_golden_via_towers() {
    // Full AIF composition: towers produce the async tensors, head consumes
    // them — the exact two-phase flow the Merger performs.
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    for a in ["user_tower", "item_tower", "head_aif"] {
        engine.load(&m, a).unwrap();
    }
    let user_out = engine
        .execute(
            "user_tower",
            &[
                m.load_golden("profile").unwrap(),
                m.load_golden("seq_short").unwrap(),
                m.load_golden("seq_long_raw").unwrap(),
                m.load_golden("seq_sign").unwrap(),
            ],
        )
        .unwrap();
    let item_out = engine
        .execute("item_tower", &[m.load_golden("item_raw").unwrap()])
        .unwrap();
    let inputs = vec![
        user_out[0].clone(),                       // u_vec
        item_out[0].clone(),                       // item_vec
        user_out[1].clone(),                       // bea_v
        item_out[1].clone(),                       // bea_w
        user_out[3].clone(),                       // din_base (hoisted DIN)
        user_out[4].clone(),                       // din_g
        m.load_golden("item_sign").unwrap(),
        m.load_golden("tiers_in").unwrap(),        // serving-engine SimTier
        m.load_golden("sim_cross").unwrap(),
    ];
    let scores = engine.execute1("head_aif", &inputs).unwrap();
    let expect = m.load_golden("head_aif.scores").unwrap();
    let d = scores.max_abs_diff(&expect);
    assert!(d < TOL, "head_aif diff {d}");
    // Scores are probabilities.
    assert!(scores.data().iter().all(|s| (0.0..=1.0).contains(s)));
}

#[test]
fn pallas_flavor_matches_ref_flavor() {
    // The Pallas-lowered artifacts (the TPU deployment shape, with the
    // fused LSH kernel computing SimTier in-kernel) must agree with the
    // ref-lowered serving artifacts — both through the SAME rust PJRT path.
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    for a in [
        "user_tower",
        "user_tower_pallas",
        "item_tower",
        "item_tower_pallas",
        "head_aif",
        "head_aif_pallas",
    ] {
        engine.load(&m, a).unwrap();
    }
    let user_inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("seq_long_raw").unwrap(),
        m.load_golden("seq_sign").unwrap(),
    ];
    let u_ref = engine.execute("user_tower", &user_inputs).unwrap();
    let u_pal = engine
        .execute("user_tower_pallas", &user_inputs[..3])
        .unwrap();
    for (a, b) in u_ref.iter().take(3).zip(&u_pal) {
        assert!(a.max_abs_diff(b) < TOL, "user tower flavors diverge");
    }
    let item_inputs = vec![m.load_golden("item_raw").unwrap()];
    let i_ref = engine.execute("item_tower", &item_inputs).unwrap();
    let i_pal = engine.execute("item_tower_pallas", &item_inputs).unwrap();
    for (a, b) in i_ref.iter().zip(&i_pal) {
        assert!(a.max_abs_diff(b) < TOL, "item tower flavors diverge");
    }
    // Heads: the ref flavor takes tiers_in; the pallas flavor computes
    // SimTier inside the fused kernel.  Same scores either way.
    let ref_inputs = vec![
        u_ref[0].clone(),
        i_ref[0].clone(),
        u_ref[1].clone(),
        i_ref[1].clone(),
        u_ref[3].clone(), // din_base
        u_ref[4].clone(), // din_g
        m.load_golden("item_sign").unwrap(),
        m.load_golden("tiers_in").unwrap(),
        m.load_golden("sim_cross").unwrap(),
    ];
    let pallas_inputs = vec![
        u_ref[0].clone(),
        i_ref[0].clone(),
        u_ref[1].clone(),
        i_ref[1].clone(),
        u_ref[2].clone(), // seq_emb — the kernel pools in full
        m.load_golden("item_sign").unwrap(),
        m.load_golden("seq_sign").unwrap(),
        m.load_golden("sim_cross").unwrap(),
    ];
    let s_ref = engine.execute1("head_aif", &ref_inputs).unwrap();
    let s_pal = engine.execute1("head_aif_pallas", &pallas_inputs).unwrap();
    let d = s_ref.max_abs_diff(&s_pal);
    assert!(d < TOL, "pallas vs ref head diff {d}");
}

#[test]
fn engine_rejects_bad_shapes() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "head_base").unwrap();
    let bad = vec![
        Tensor::zeros(vec![1, 3]), // wrong profile shape
        m.load_golden("seq_short").unwrap(),
        m.load_golden("item_raw").unwrap(),
    ];
    assert!(engine.execute("head_base", &bad).is_err());
    assert!(engine.execute("head_base", &[]).is_err());
    assert!(engine.execute("not_loaded", &[]).is_err());
}
