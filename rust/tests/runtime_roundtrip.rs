//! Cross-language correctness anchor: replay the golden fixture emitted by
//! `python/compile/aot.py` through the rust PJRT runtime and assert the
//! towers and heads reproduce the python oracle outputs.

use aif::runtime::{Engine, Manifest, Tensor};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

const TOL: f32 = 5e-4;

#[test]
fn user_tower_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "user_tower").unwrap();
    let inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("seq_long_raw").unwrap(),
        m.load_golden("seq_sign").unwrap(),
    ];
    let out = engine.execute("user_tower", &inputs).unwrap();
    let expect = [
        m.load_golden("user_tower.u_vec").unwrap(),
        m.load_golden("user_tower.bea_v").unwrap(),
        m.load_golden("user_tower.seq_emb").unwrap(),
        m.load_golden("user_tower.din_base").unwrap(),
        m.load_golden("user_tower.din_g").unwrap(),
    ];
    for (o, e) in out.iter().zip(&expect) {
        let d = o.max_abs_diff(e);
        assert!(d < TOL, "user_tower diff {d}");
    }
}

#[test]
fn item_tower_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "item_tower").unwrap();
    let inputs = vec![m.load_golden("item_raw").unwrap()];
    let out = engine.execute("item_tower", &inputs).unwrap();
    let expect = [
        m.load_golden("item_tower.item_vec").unwrap(),
        m.load_golden("item_tower.bea_w").unwrap(),
    ];
    for (o, e) in out.iter().zip(&expect) {
        let d = o.max_abs_diff(e);
        assert!(d < TOL, "item_tower diff {d}");
    }
}

#[test]
fn head_base_matches_golden() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "head_base").unwrap();
    let inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("item_raw").unwrap(),
    ];
    let scores = engine.execute1("head_base", &inputs).unwrap();
    let expect = m.load_golden("head_base.scores").unwrap();
    let d = scores.max_abs_diff(&expect);
    assert!(d < TOL, "head_base diff {d}");
}

#[test]
fn head_aif_matches_golden_via_towers() {
    // Full AIF composition: towers produce the async tensors, head consumes
    // them — the exact two-phase flow the Merger performs.
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    for a in ["user_tower", "item_tower", "head_aif"] {
        engine.load(&m, a).unwrap();
    }
    let user_out = engine
        .execute(
            "user_tower",
            &[
                m.load_golden("profile").unwrap(),
                m.load_golden("seq_short").unwrap(),
                m.load_golden("seq_long_raw").unwrap(),
                m.load_golden("seq_sign").unwrap(),
            ],
        )
        .unwrap();
    let item_out = engine
        .execute("item_tower", &[m.load_golden("item_raw").unwrap()])
        .unwrap();
    let inputs = vec![
        user_out[0].clone(),                       // u_vec
        item_out[0].clone(),                       // item_vec
        user_out[1].clone(),                       // bea_v
        item_out[1].clone(),                       // bea_w
        user_out[3].clone(),                       // din_base (hoisted DIN)
        user_out[4].clone(),                       // din_g
        m.load_golden("item_sign").unwrap(),
        m.load_golden("tiers_in").unwrap(),        // serving-engine SimTier
        m.load_golden("sim_cross").unwrap(),
    ];
    let scores = engine.execute1("head_aif", &inputs).unwrap();
    let expect = m.load_golden("head_aif.scores").unwrap();
    let d = scores.max_abs_diff(&expect);
    assert!(d < TOL, "head_aif diff {d}");
    // Scores are probabilities.
    assert!(scores.data().iter().all(|s| (0.0..=1.0).contains(s)));
}

#[test]
fn pallas_flavor_matches_ref_flavor() {
    // The Pallas-lowered artifacts (the TPU deployment shape, with the
    // fused LSH kernel computing SimTier in-kernel) must agree with the
    // ref-lowered serving artifacts — both through the SAME rust PJRT path.
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    for a in [
        "user_tower",
        "user_tower_pallas",
        "item_tower",
        "item_tower_pallas",
        "head_aif",
        "head_aif_pallas",
    ] {
        engine.load(&m, a).unwrap();
    }
    let user_inputs = vec![
        m.load_golden("profile").unwrap(),
        m.load_golden("seq_short").unwrap(),
        m.load_golden("seq_long_raw").unwrap(),
        m.load_golden("seq_sign").unwrap(),
    ];
    let u_ref = engine.execute("user_tower", &user_inputs).unwrap();
    let u_pal = engine
        .execute("user_tower_pallas", &user_inputs[..3])
        .unwrap();
    for (a, b) in u_ref.iter().take(3).zip(&u_pal) {
        assert!(a.max_abs_diff(b) < TOL, "user tower flavors diverge");
    }
    let item_inputs = vec![m.load_golden("item_raw").unwrap()];
    let i_ref = engine.execute("item_tower", &item_inputs).unwrap();
    let i_pal = engine.execute("item_tower_pallas", &item_inputs).unwrap();
    for (a, b) in i_ref.iter().zip(&i_pal) {
        assert!(a.max_abs_diff(b) < TOL, "item tower flavors diverge");
    }
    // Heads: the ref flavor takes tiers_in; the pallas flavor computes
    // SimTier inside the fused kernel.  Same scores either way.
    let ref_inputs = vec![
        u_ref[0].clone(),
        i_ref[0].clone(),
        u_ref[1].clone(),
        i_ref[1].clone(),
        u_ref[3].clone(), // din_base
        u_ref[4].clone(), // din_g
        m.load_golden("item_sign").unwrap(),
        m.load_golden("tiers_in").unwrap(),
        m.load_golden("sim_cross").unwrap(),
    ];
    let pallas_inputs = vec![
        u_ref[0].clone(),
        i_ref[0].clone(),
        u_ref[1].clone(),
        i_ref[1].clone(),
        u_ref[2].clone(), // seq_emb — the kernel pools in full
        m.load_golden("item_sign").unwrap(),
        m.load_golden("seq_sign").unwrap(),
        m.load_golden("sim_cross").unwrap(),
    ];
    let s_ref = engine.execute1("head_aif", &ref_inputs).unwrap();
    let s_pal = engine.execute1("head_aif_pallas", &pallas_inputs).unwrap();
    let d = s_ref.max_abs_diff(&s_pal);
    assert!(d < TOL, "pallas vs ref head diff {d}");
}

#[test]
fn coalesced_head_matches_regular_head() {
    // The `_mu` flavor with the whole request on slot 0 (padding rows
    // repeating the last row) must reproduce head_aif's scores on the
    // real rows — coalescing is score-invariant by construction.
    let Some(m) = manifest() else { return };
    if m.artifact("head_aif_mu").is_err() {
        eprintln!("skipping: artifacts predate head_aif_mu");
        return;
    }
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "head_aif_mu").unwrap();
    let expect = m.load_golden("head_aif_mu.scores").unwrap();
    let solo = m.load_golden("head_aif.scores").unwrap();
    let b = solo.len();
    // The golden packs the fixture request into the mu layout; replay it.
    let spec = m.artifact("head_aif_mu").unwrap().clone();
    let b_mu = spec.outputs[0].shape[0];
    let slots = spec.inputs[0].shape[0];
    let tile = |t: &Tensor, reps: usize| {
        let mut data = Vec::with_capacity(t.len() * reps);
        for _ in 0..reps {
            data.extend_from_slice(t.data());
        }
        let mut shape = vec![reps];
        shape.extend_from_slice(if t.shape[0] == 1 {
            &t.shape[1..]
        } else {
            &t.shape[..]
        });
        Tensor::new(shape, data)
    };
    let pad_rows = |t: &Tensor| {
        let w: usize = t.shape[1..].iter().product();
        let mut data = t.data().to_vec();
        let last = data[(b - 1) * w..b * w].to_vec();
        for _ in b..b_mu {
            data.extend_from_slice(&last);
        }
        let mut shape = vec![b_mu];
        shape.extend_from_slice(&t.shape[1..]);
        Tensor::new(shape, data)
    };
    let user = m
        .load_golden("user_tower.u_vec")
        .and_then(|u| {
            Ok((
                u,
                m.load_golden("user_tower.bea_v")?,
                m.load_golden("user_tower.din_base")?,
                m.load_golden("user_tower.din_g")?,
            ))
        })
        .unwrap();
    let inputs = vec![
        tile(&user.0, slots),
        tile(&user.1, slots),
        tile(&user.2, slots),
        tile(&user.3, slots),
        pad_rows(&m.load_golden("item_tower.item_vec").unwrap()),
        pad_rows(&m.load_golden("item_tower.bea_w").unwrap()),
        pad_rows(&m.load_golden("item_sign").unwrap()),
        pad_rows(&m.load_golden("tiers_in").unwrap()),
        pad_rows(&m.load_golden("sim_cross").unwrap()),
        Tensor::zeros(vec![b_mu]), // every row on slot 0
    ];
    let scores = engine.execute1("head_aif_mu", &inputs).unwrap();
    let d = scores.max_abs_diff(&expect);
    assert!(d < TOL, "head_aif_mu golden diff {d}");
    // The real rows match the per-request head exactly.
    for (i, (mu, one)) in scores
        .data()
        .iter()
        .take(b)
        .zip(solo.data().iter())
        .enumerate()
    {
        assert!((mu - one).abs() < TOL, "row {i}: mu {mu} vs solo {one}");
    }
}

#[test]
fn engine_rejects_bad_shapes() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new().unwrap();
    engine.load(&m, "head_base").unwrap();
    let bad = vec![
        Tensor::zeros(vec![1, 3]), // wrong profile shape
        m.load_golden("seq_short").unwrap(),
        m.load_golden("item_raw").unwrap(),
    ];
    assert!(engine.execute("head_base", &bad).is_err());
    assert!(engine.execute("head_base", &[]).is_err());
    assert!(engine.execute("not_loaded", &[]).is_err());
}
