//! Load-adaptive computation tiering, integration-shaped (DESIGN.md §20;
//! ISSUE 10 acceptance):
//!
//! * sustained overload steps the scenario down its ladder one rung per
//!   controller tick; load dropping steps it back up — with zero failed
//!   requests either way;
//! * `guaranteed` traffic NEVER observes a degraded tier, through
//!   degradation, forced pins and hot reloads;
//! * within a pinned tier, responses are bitwise-deterministic, and the
//!   served tier is visible on the response, the trace and `/metrics`;
//! * `ScenarioRegistry::reload` under degradation preserves the
//!   controller's current tier instead of resetting to full.
//!
//! Runs against the synthetic fixture artifact set over the
//! deterministic PJRT stand-in, like the other serving suites.  The
//! controller loop is driven by explicit `controller_tick` calls against
//! a registered [`FrontendStats`] block, so every transition here is
//! deterministic — the wall-clock sampling thread is covered by the
//! overload bench.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use aif::config::{
    OverloadConfig, ScenarioConfig, ServingConfig, SimMode, SlaClass,
    TierSpec,
};
use aif::coordinator::overload::{controller_tick, EwmaState, LoadSample};
use aif::coordinator::{Merger, ScoreRequest};
use aif::features::LatencyModel;
use aif::server::http::FrontendStats;
use aif::util::fixture;

/// Fresh fixture dir per test (tests run in parallel).
fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("aif-overload-{}-{tag}", std::process::id()));
    fixture::write(&dir).expect("fixture generation");
    dir
}

/// Removes the fixture dir when the test ends (also on panic/unwind).
struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Three-rung ladder: full AIF, a truncated-candidate AIF tier, and a
/// cheap synchronous floor on the base variant.
fn ladder() -> Vec<TierSpec> {
    vec![
        TierSpec::full("aif"),
        TierSpec {
            name: "lite".into(),
            variant: "aif".into(),
            max_candidates: 24,
        },
        TierSpec {
            name: "floor".into(),
            variant: "base".into(),
            max_candidates: 16,
        },
    ]
}

/// Queue-depth-only controller config with no dwell: one deterministic
/// rung per tick.  `enabled` stays false — the tests drive ticks by
/// hand; the sampling thread adds nothing but wall-clock jitter here.
fn overload_cfg() -> OverloadConfig {
    OverloadConfig {
        degrade_queue_depth: 8,
        recover_queue_depth: 1,
        dwell_ms: 0,
        ..OverloadConfig::default()
    }
}

/// Fast core config: tiny modeled latencies, small candidate sets, one
/// laddered scenario named "ranked".
fn core_cfg(dir: &PathBuf) -> ServingConfig {
    let base = ServingConfig {
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 48,
        top_k: 16,
        retrieval_latency: LatencyModel::fixed(100.0),
        user_store_latency: LatencyModel::fixed(20.0),
        item_store_latency: LatencyModel::fixed(10.0),
        sim_parse_us: 0.1,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        overload: overload_cfg(),
        ..Default::default()
    };
    let ranked = ScenarioConfig {
        sim_mode: SimMode::Precached,
        ladder: ladder(),
        ..ScenarioConfig::from_serving("ranked", &base)
    };
    ServingConfig {
        scenarios: vec![ranked],
        default_scenario: Some("ranked".into()),
        ..base
    }
}

/// Fixed candidate override: retrieval is stochastic, tiering is not.
fn cands() -> Vec<u32> {
    (0..48u32).collect()
}

fn req(user: usize, id: u64) -> ScoreRequest {
    ScoreRequest::user(user)
        .with_request_id(id)
        .with_candidates(cands())
        .with_top_k(16)
}

#[test]
fn overload_degrades_recovers_and_never_fails_guaranteed() {
    let dir = fixture_dir("degrade");
    let _cleanup = Cleanup(dir.clone());
    let merger = Arc::new(Merger::build(core_cfg(&dir)).expect("merger"));
    let entry = merger.registry().entry(Some("ranked")).unwrap();
    assert_eq!(entry.stats.n_tiers(), 3);

    // The controller reads load from registered front-end stat blocks.
    let fe = Arc::new(FrontendStats::new("test"));
    merger.core().overload_signals.register(&fe);
    let ov = overload_cfg();
    let mut ewmas: HashMap<String, EwmaState> = HashMap::new();

    // Unloaded baseline: everyone serves the full tier.
    let r = merger.score(req(1, 10)).expect("baseline");
    assert_eq!(r.tier, Some(0));

    // Sustained overload: one rung per tick, clamped at the floor.
    fe.queue_depth.store(20, Ordering::Relaxed);
    for want in [1usize, 2, 2] {
        controller_tick(
            &ov,
            merger.registry(),
            &merger.core().overload_signals,
            &mut ewmas,
        );
        assert_eq!(entry.stats.tier(), want, "degrade walks one rung/tick");
    }
    assert_eq!(entry.stats.be_tier(), 2);

    // 4-thread mixed-SLA traffic against the degraded scenario: ZERO
    // failures, guaranteed pinned to the full tier, everything else at
    // the floor — and the served tier visible on every response.
    const N_THREADS: usize = 4;
    const M_REQUESTS: usize = 24;
    let mut handles = Vec::new();
    for t in 0..N_THREADS {
        let merger = Arc::clone(&merger);
        handles.push(std::thread::spawn(move || {
            let mut guaranteed = 0u64;
            for m in 0..M_REQUESTS {
                let sla = [
                    SlaClass::Degradable,
                    SlaClass::Guaranteed,
                    SlaClass::BestEffort,
                ][m % 3];
                let id = 1000 + (t * M_REQUESTS + m) as u64;
                let r = merger
                    .score(req((t + m) % 24, id).with_sla(sla))
                    .expect("no failed requests under degradation");
                match sla {
                    SlaClass::Guaranteed => {
                        assert_eq!(
                            r.tier,
                            Some(0),
                            "guaranteed served below the top tier"
                        );
                        guaranteed += 1;
                    }
                    _ => assert_eq!(r.tier, Some(2)),
                }
            }
            guaranteed
        }));
    }
    let guaranteed: u64 = handles
        .into_iter()
        .map(|h| h.join().expect("traffic thread panicked"))
        .sum();
    assert_eq!(guaranteed, (N_THREADS * M_REQUESTS / 3) as u64);

    // Load drops: recovery walks back up, best-effort trailing.
    fe.queue_depth.store(0, Ordering::Relaxed);
    for want in [1usize, 0, 0] {
        controller_tick(
            &ov,
            merger.registry(),
            &merger.core().overload_signals,
            &mut ewmas,
        );
        assert_eq!(entry.stats.tier(), want, "recovery walks one rung/tick");
    }
    assert_eq!(entry.stats.be_tier(), 0);
    assert_eq!(entry.stats.transitions(), (2, 2));

    // The /metrics snapshot reflects all of it.
    let snaps = merger.registry().overload_snapshots();
    let (_, snap) = snaps
        .iter()
        .find(|(name, _)| name == "ranked")
        .expect("ranked overload snapshot");
    assert_eq!(snap.get("tier").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(snap.get("n_tiers").unwrap().as_f64().unwrap(), 3.0);
    assert_eq!(
        snap.get("guaranteed_served").unwrap().as_f64().unwrap() as u64,
        guaranteed
    );
    let served = snap.get("served_by_tier").unwrap();
    assert!(
        served.get("floor").unwrap().as_f64().unwrap() > 0.0,
        "degraded traffic must be visible per rung"
    );
    assert_eq!(
        snap.get("inputs")
            .unwrap()
            .get("queue_depth")
            .unwrap()
            .as_f64()
            .unwrap(),
        0.0
    );
}

#[test]
fn pinned_tiers_are_bitwise_deterministic_and_fully_visible() {
    let dir = fixture_dir("determinism");
    let _cleanup = Cleanup(dir.clone());
    let merger = Merger::build(core_cfg(&dir)).expect("merger");
    let entry = merger.registry().entry(Some("ranked")).unwrap();

    // The rung engines really are cheaper: the compute knob clamps each
    // rung's candidate count.
    assert_eq!(entry.tiers[0].cfg.n_candidates, 48);
    assert_eq!(entry.tiers[1].cfg.n_candidates, 24);
    assert_eq!(entry.tiers[2].cfg.n_candidates, 16);

    let mut tier0_items = None;
    for (tier, want_cands) in [(0usize, 48usize), (1, 24), (2, 16)] {
        merger.force_tier(Some("ranked"), Some(tier)).unwrap();
        let a = merger
            .score(req(7, 100 + tier as u64).with_trace(true))
            .expect("pinned-tier request");
        let b = merger
            .score(req(7, 200 + tier as u64).with_trace(true))
            .expect("pinned-tier repeat");
        assert_eq!(
            a.items, b.items,
            "tier {tier}: responses must be bitwise-deterministic"
        );
        // The tier is visible on the response AND the trace, and the
        // trace shows the rung's truncated candidate set.
        assert_eq!(a.tier, Some(tier));
        let trace = a.trace.as_ref().expect("trace requested");
        assert_eq!(trace.tier, Some(tier));
        assert_eq!(trace.n_candidates, want_cands);
        if tier == 0 {
            tier0_items = Some(a.items.clone());
        }
        // A pin never touches guaranteed traffic: full tier, full bits.
        let g = merger
            .score(req(7, 300 + tier as u64).with_sla(SlaClass::Guaranteed))
            .expect("guaranteed under pin");
        assert_eq!(g.tier, Some(0));
        assert_eq!(
            Some(&g.items),
            tier0_items.as_ref(),
            "guaranteed must serve exactly the full-tier scores"
        );
    }
    // The floor rung serves the base variant, and says so.
    let floor = merger.score(req(3, 400)).expect("floor request");
    assert_eq!(floor.variant, "base");

    // Unpin: the controller tier (still 0) takes back over.
    merger.force_tier(Some("ranked"), None).unwrap();
    assert_eq!(merger.score(req(7, 500)).unwrap().tier, Some(0));
}

#[test]
fn reload_under_degradation_preserves_the_current_tier() {
    let dir = fixture_dir("reload");
    let _cleanup = Cleanup(dir.clone());
    let merger = Merger::build(core_cfg(&dir)).expect("merger");
    let entry = merger.registry().entry(Some("ranked")).unwrap();
    let ov = overload_cfg();

    // Degrade to the floor through the stats state machine directly.
    let overloaded = LoadSample {
        queue_depth: 20,
        ..LoadSample::default()
    };
    entry.stats.tick(&ov, &overloaded);
    entry.stats.tick(&ov, &overloaded);
    assert_eq!(entry.stats.tier(), 2);

    // Hot reload must NOT reset a saturated scenario to full compute.
    merger.registry().reload("ranked").expect("hot reload");
    let fresh = merger.registry().entry(Some("ranked")).unwrap();
    assert!(
        Arc::ptr_eq(&fresh.stats, &entry.stats),
        "overload state must survive the reload"
    );
    assert_eq!(fresh.stats.tier(), 2, "reload reset the degraded tier");
    assert_eq!(fresh.stats.n_tiers(), 3);
    assert_eq!(fresh.tiers[0].generation, 1);

    // Traffic keeps serving at the preserved tier; guaranteed stays top.
    let r = merger.score(req(5, 600)).expect("post-reload request");
    assert_eq!(r.tier, Some(2));
    let g = merger
        .score(req(5, 601).with_sla(SlaClass::Guaranteed))
        .expect("post-reload guaranteed");
    assert_eq!(g.tier, Some(0));

    // Recovery still works on the reloaded entry.
    let idle = LoadSample::default();
    fresh.stats.tick(&ov, &idle);
    fresh.stats.tick(&ov, &idle);
    assert_eq!(fresh.stats.tier(), 0);
}
