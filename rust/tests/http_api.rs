//! Integration tests of the `/v1` HTTP surface.
//!
//! The server is generic over [`PreRanker`], so these run against a stub
//! service — no artifacts required: status codes, reason phrases, JSON
//! shapes and the `Allow` header are all asserted over a real TCP socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use aif::config::FrontendConfig;
use aif::coordinator::{
    PhaseTimings, PreRanker, ScenarioAdmin, ScenarioInfo, ScoreRequest,
    ScoreResponse, ScoredItem, ServeError,
};
use aif::metrics::ServingMetrics;
use aif::server::HttpServer;
use aif::util::json::{Object, Value};

/// Stub pipeline: `N_CANDIDATES` fake candidates, descending scores.
struct MockRanker {
    metrics: ServingMetrics,
}

const N_USERS: usize = 100;
const N_CANDIDATES: usize = 50;
const DEFAULT_TOP_K: usize = 16;

impl PreRanker for MockRanker {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        if req.user >= N_USERS {
            return Err(ServeError::UnknownUser(req.user));
        }
        let top_k = req.top_k.unwrap_or(DEFAULT_TOP_K);
        if top_k == 0 {
            return Err(ServeError::BadRequest("top_k must be >= 1".into()));
        }
        let n = top_k.min(N_CANDIDATES);
        let items = (0..n as u32)
            .map(|i| ScoredItem {
                item: i,
                score: 1.0 - i as f32 * 0.001,
            })
            .collect();
        let zero = Duration::ZERO;
        let timings = PhaseTimings {
            total: zero,
            retrieval: zero,
            user_async: None,
            prerank: zero,
        };
        self.metrics.record_request(
            timings.total,
            timings.prerank,
            timings.user_async,
            timings.retrieval,
        );
        Ok(ScoreResponse {
            request_id: req.request_id.unwrap_or(1),
            user: req.user,
            scenario: req
                .scenario
                .clone()
                .unwrap_or_else(|| "mock".to_string()),
            variant: "mock".into(),
            tier: None,
            items,
            timings,
            trace: None,
        })
    }

    fn variant_name(&self) -> &str {
        "mock"
    }

    fn n_users(&self) -> usize {
        N_USERS
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }
}

fn start_server() -> HttpServer {
    let ranker: Arc<dyn PreRanker> = Arc::new(MockRanker {
        metrics: ServingMetrics::new(),
    });
    HttpServer::start(ranker, "127.0.0.1:0", 2).expect("server starts")
}

/// Stub registry admin: two fixed scenarios, reload bumps a counter.
/// Optional durable-store surface (`storage = true`) and a flippable
/// readiness flag drive the `/readyz`, `/v1/storage` and
/// `/v1/checkpoint` tests.
struct MockAdmin {
    reloads: std::sync::atomic::AtomicU64,
    metrics: ServingMetrics,
    ready: std::sync::atomic::AtomicBool,
    storage: bool,
    checkpoints: std::sync::atomic::AtomicU64,
}

impl MockAdmin {
    fn new(storage: bool) -> MockAdmin {
        MockAdmin {
            reloads: std::sync::atomic::AtomicU64::new(0),
            metrics: ServingMetrics::new(),
            ready: std::sync::atomic::AtomicBool::new(true),
            storage,
            checkpoints: std::sync::atomic::AtomicU64::new(0),
        }
    }
}

impl ScenarioAdmin for MockAdmin {
    fn list_scenarios(&self) -> Vec<ScenarioInfo> {
        vec![
            ScenarioInfo {
                name: "main".into(),
                variant: "aif".into(),
                is_default: true,
                generation: self
                    .reloads
                    .load(std::sync::atomic::Ordering::Relaxed),
                requests: 0,
                coalescing: false,
            },
            ScenarioInfo {
                name: "fallback".into(),
                variant: "base".into(),
                is_default: false,
                generation: 0,
                requests: 0,
                coalescing: false,
            },
        ]
    }

    fn default_scenario(&self) -> String {
        "main".into()
    }

    fn reload_scenario(
        &self,
        name: &str,
    ) -> Result<ScenarioInfo, ServeError> {
        if name != "main" && name != "fallback" {
            return Err(ServeError::UnknownScenario(name.to_string()));
        }
        self.reloads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(self.list_scenarios().remove(0))
    }

    fn scenario_metrics(
        &self,
        wall: Duration,
    ) -> Vec<(String, Value)> {
        vec![
            ("main".to_string(), self.metrics.snapshot(wall)),
            ("fallback".to_string(), self.metrics.snapshot(wall)),
        ]
    }

    fn storage_stats(&self) -> Option<Value> {
        self.storage.then(|| {
            let mut o = Object::new();
            o.insert(
                "snapshots_full",
                self.checkpoints
                    .load(std::sync::atomic::Ordering::Relaxed),
            );
            o.insert("bytes_written", 123u64);
            Value::Obj(o)
        })
    }

    fn readiness(&self) -> Value {
        let ready =
            self.ready.load(std::sync::atomic::Ordering::Relaxed);
        let mut o = Object::new();
        o.insert("ready", ready);
        o.insert("state", if ready { "ready" } else { "restoring" });
        Value::Obj(o)
    }

    fn trigger_checkpoint(&self) -> Result<Value, ServeError> {
        if !self.storage {
            return Err(ServeError::BadRequest(
                "no storage backend configured".into(),
            ));
        }
        self.checkpoints
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut o = Object::new();
        o.insert("outcome", "full");
        Ok(Value::Obj(o))
    }
}

fn start_admin_server() -> HttpServer {
    start_admin_server_with(MockAdmin::new(false)).0
}

fn start_admin_server_with(
    admin: MockAdmin,
) -> (HttpServer, Arc<MockAdmin>) {
    let ranker: Arc<dyn PreRanker> = Arc::new(MockRanker {
        metrics: ServingMetrics::new(),
    });
    let admin = Arc::new(admin);
    let server = HttpServer::start_with_admin(
        ranker,
        Some(Arc::clone(&admin) as Arc<dyn ScenarioAdmin>),
        "127.0.0.1:0",
        2,
    )
    .expect("server starts");
    (server, admin)
}

/// Send a raw request; return (status, header block, body).
fn raw_request(addr: &str, raw: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).expect("write");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read");
    let text = String::from_utf8(buf).expect("utf8 response");
    let (head, body) = text
        .split_once("\r\n\r\n")
        .unwrap_or((text.as_str(), ""));
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    (status, head.to_string(), body.to_string())
}

fn get(addr: &str, path: &str) -> (u16, String, String) {
    raw_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn post(addr: &str, path: &str, body: &str) -> (u16, String, String) {
    raw_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

#[test]
fn healthz_and_metrics() {
    let server = start_server();
    let (status, _, body) = get(&server.addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body, "ok");

    let (status, _, body) = get(&server.addr, "/metrics");
    assert_eq!(status, 200);
    let v = Value::parse(&body).expect("metrics is JSON");
    assert!(v.get("requests").is_some());
    assert!(v.get("qps").is_some());
    // Coalescing counters ride along (zeroed when the knob is off).
    let co = v.req("coalesce");
    assert_eq!(co.req("executions").as_usize(), Some(0));
    assert!(co.get("queue_wait_p99_ms").is_some());
    server.shutdown();
}

#[test]
fn score_happy_path_honors_top_k() {
    let server = start_server();
    let (status, _, body) = get(&server.addr, "/v1/score?user=3&top_k=4");
    assert_eq!(status, 200);
    let v = Value::parse(&body).expect("JSON body");
    assert_eq!(v.req("user").as_usize(), Some(3));
    assert_eq!(v.req("variant").as_str(), Some("mock"));
    let items = v.req("items").as_arr().unwrap();
    assert_eq!(items.len(), 4, "requested top-K is honored");
    assert!(items[0].get("item").is_some());
    assert!(items[0].get("score").is_some());

    // Default top-K when the param is absent.
    let (_, _, body) = get(&server.addr, "/v1/score?user=3");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("items").as_arr().unwrap().len(), DEFAULT_TOP_K);
    server.shutdown();
}

#[test]
fn top_k_clamps_to_candidate_count() {
    let server = start_server();
    let (status, _, body) =
        get(&server.addr, "/v1/score?user=1&top_k=10000");
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("items").as_arr().unwrap().len(), N_CANDIDATES);
    server.shutdown();
}

#[test]
fn unknown_user_is_404() {
    let server = start_server();
    let (status, head, body) = get(&server.addr, "/v1/score?user=99999");
    assert_eq!(status, 404);
    assert!(head.starts_with("HTTP/1.1 404 Not Found"), "{head}");
    let v = Value::parse(&body).expect("error body is JSON");
    assert!(v.req("error").as_str().unwrap().contains("unknown user"));
    server.shutdown();
}

#[test]
fn bad_query_params_are_400() {
    let server = start_server();
    for path in [
        "/v1/score",
        "/v1/score?user=abc",
        "/v1/score?user=1&top_k=0",
        "/v1/score?user=1&nope=2",
    ] {
        let (status, _, _) = get(&server.addr, path);
        assert_eq!(status, 400, "{path}");
    }
    server.shutdown();
}

#[test]
fn post_single_and_batch() {
    let server = start_server();
    let (status, _, body) =
        post(&server.addr, "/v1/score", r#"{"user": 1, "top_k": 2}"#);
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("items").as_arr().unwrap().len(), 2);

    // Batch: knobs are shared; per-user failures come back inline.
    let (status, _, body) = post(
        &server.addr,
        "/v1/score",
        r#"{"users": [1, 2, 99999], "top_k": 1}"#,
    );
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    let results = v.req("results").as_arr().unwrap();
    assert_eq!(results.len(), 3);
    assert_eq!(results[0].req("items").as_arr().unwrap().len(), 1);
    assert_eq!(results[1].req("user").as_usize(), Some(2));
    assert!(results[2].get("error").is_some(), "bad user fails inline");
    assert_eq!(results[2].req("status").as_usize(), Some(404));
    server.shutdown();
}

#[test]
fn malformed_body_is_400_and_bad_shape_is_422() {
    let server = start_server();
    let (status, _, body) = post(&server.addr, "/v1/score", "{not json");
    assert_eq!(status, 400);
    assert!(body.contains("malformed JSON"));

    // Parses as JSON, but the shape is invalid -> 422 with the right
    // reason phrase (previously mislabeled "Internal Server Error").
    let (status, head, _) =
        post(&server.addr, "/v1/score", r#"{"user": "three"}"#);
    assert_eq!(status, 422);
    assert!(
        head.starts_with("HTTP/1.1 422 Unprocessable Entity"),
        "{head}"
    );

    let (status, _, _) =
        post(&server.addr, "/v1/score", r#"{"users": []}"#);
    assert_eq!(status, 422);
    server.shutdown();
}

#[test]
fn unsupported_methods_are_405_with_allow() {
    let server = start_server();
    let (status, head, _) = raw_request(
        &server.addr,
        "DELETE /v1/score HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    assert!(
        head.starts_with("HTTP/1.1 405 Method Not Allowed"),
        "{head}"
    );
    let allow = head
        .lines()
        .find(|l| l.to_ascii_lowercase().starts_with("allow:"))
        .expect("Allow header present");
    assert!(allow.contains("GET") && allow.contains("POST"), "{allow}");

    let (status, head, _) = raw_request(
        &server.addr,
        "POST /healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
         Connection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    let allow = head
        .lines()
        .find(|l| l.to_ascii_lowercase().starts_with("allow:"))
        .expect("Allow header present");
    assert!(allow.contains("GET") && !allow.contains("POST"), "{allow}");
    server.shutdown();
}

#[test]
fn unversioned_score_is_gone_and_unknown_paths_404() {
    let server = start_server();
    let (status, _, body) = get(&server.addr, "/score?user=1");
    assert_eq!(status, 404);
    assert!(body.contains("/v1/score"), "points at the new surface");
    let (status, _, _) = get(&server.addr, "/nope");
    assert_eq!(status, 404);
    server.shutdown();
}

#[test]
fn scenarios_listing_reload_and_per_scenario_metrics() {
    let server = start_admin_server();

    // Listing.
    let (status, _, body) = get(&server.addr, "/v1/scenarios");
    assert_eq!(status, 200);
    let v = Value::parse(&body).expect("listing is JSON");
    assert_eq!(v.req("default").as_str(), Some("main"));
    let rows = v.req("scenarios").as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].req("name").as_str(), Some("main"));
    assert_eq!(rows[0].req("default").as_bool(), Some(true));
    assert_eq!(rows[1].req("variant").as_str(), Some("base"));
    assert!(rows[0].get("generation").is_some());
    assert!(rows[0].get("coalescing").is_some());

    // Reload endpoint bumps the generation; unknown scenario is 404.
    let (status, _, body) =
        post(&server.addr, "/v1/scenarios/main/reload", "");
    assert_eq!(status, 200, "{body}");
    let v = Value::parse(&body).unwrap();
    assert_eq!(
        v.req("reloaded").req("generation").as_usize(),
        Some(1)
    );
    let (status, _, _) =
        post(&server.addr, "/v1/scenarios/nope/reload", "");
    assert_eq!(status, 404);

    // Method guards.
    let (status, head, _) = get(&server.addr, "/v1/scenarios/main/reload");
    assert_eq!(status, 405);
    assert!(head.to_ascii_lowercase().contains("allow: post"), "{head}");
    let (status, head, _) = raw_request(
        &server.addr,
        "DELETE /v1/scenarios HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    assert!(head.to_ascii_lowercase().contains("allow: get"), "{head}");

    // Per-scenario metrics blocks.
    let (_, _, body) = get(&server.addr, "/metrics");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("default_scenario").as_str(), Some("main"));
    let per = v.req("scenarios");
    assert!(per.get("main").is_some());
    assert!(per
        .req("fallback")
        .get("requests")
        .is_some());

    // Scenario routing rides the score endpoints.
    let (status, _, body) =
        get(&server.addr, "/v1/score?user=1&scenario=fallback");
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("scenario").as_str(), Some("fallback"));
    server.shutdown();
}

#[test]
fn scenario_surface_absent_without_admin() {
    let server = start_server();
    let (status, _, body) = get(&server.addr, "/v1/scenarios");
    assert_eq!(status, 404);
    assert!(body.contains("scenario registry"), "{body}");
    let (status, _, _) =
        post(&server.addr, "/v1/scenarios/main/reload", "");
    assert_eq!(status, 404);
    server.shutdown();
}

#[test]
fn readyz_gates_on_admin_state_and_defaults_to_ready() {
    // No admin: the server is born ready.
    let server = start_server();
    let (status, _, body) = get(&server.addr, "/readyz");
    assert_eq!(status, 200);
    let v = Value::parse(&body).expect("readiness is JSON");
    assert_eq!(v.req("ready").as_bool(), Some(true));
    // Liveness stays 200 regardless of readiness.
    let (status, _, _) = get(&server.addr, "/healthz");
    assert_eq!(status, 200);
    server.shutdown();

    // Admin-backed: 503 with the boot state while not ready, 200 after.
    let (server, admin) = start_admin_server_with(MockAdmin::new(false));
    admin
        .ready
        .store(false, std::sync::atomic::Ordering::Relaxed);
    let (status, head, body) = get(&server.addr, "/readyz");
    assert_eq!(status, 503);
    assert!(
        head.starts_with("HTTP/1.1 503 Service Unavailable"),
        "{head}"
    );
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("ready").as_bool(), Some(false));
    assert_eq!(v.req("state").as_str(), Some("restoring"));
    let (status, _, _) = get(&server.addr, "/healthz");
    assert_eq!(status, 200, "liveness != readiness");

    admin
        .ready
        .store(true, std::sync::atomic::Ordering::Relaxed);
    let (status, _, body) = get(&server.addr, "/readyz");
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("state").as_str(), Some("ready"));

    // Method guard.
    let (status, head, _) = raw_request(
        &server.addr,
        "POST /readyz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
         Connection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    assert!(head.to_ascii_lowercase().contains("allow: get"), "{head}");
    server.shutdown();
}

#[test]
fn storage_surface_with_backend() {
    let (server, admin) = start_admin_server_with(MockAdmin::new(true));

    let (status, _, body) = get(&server.addr, "/v1/storage");
    assert_eq!(status, 200);
    let v = Value::parse(&body).expect("storage stats are JSON");
    assert_eq!(v.req("snapshots_full").as_usize(), Some(0));
    assert_eq!(v.req("bytes_written").as_usize(), Some(123));

    // Forced checkpoint: outcome comes back, the counter moves.
    let (status, _, body) = post(&server.addr, "/v1/checkpoint", "");
    assert_eq!(status, 200, "{body}");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("outcome").as_str(), Some("full"));
    assert_eq!(
        admin
            .checkpoints
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );

    // The /metrics snapshot carries the storage block.
    let (_, _, body) = get(&server.addr, "/metrics");
    let v = Value::parse(&body).unwrap();
    assert!(v.req("storage").get("snapshots_full").is_some());

    // Method guards.
    let (status, head, _) = raw_request(
        &server.addr,
        "POST /v1/storage HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
         Connection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    assert!(head.to_ascii_lowercase().contains("allow: get"), "{head}");
    let (status, head, _) = get(&server.addr, "/v1/checkpoint");
    assert_eq!(status, 405);
    assert!(head.to_ascii_lowercase().contains("allow: post"), "{head}");
    server.shutdown();
}

#[test]
fn storage_surface_absent_without_backend() {
    // Admin without a configured backend: stats 404, checkpoint 400.
    let server = start_admin_server();
    let (status, _, body) = get(&server.addr, "/v1/storage");
    assert_eq!(status, 404);
    assert!(body.contains("no durable storage"), "{body}");
    let (status, _, body) = post(&server.addr, "/v1/checkpoint", "");
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("no storage backend"), "{body}");
    let (_, _, body) = get(&server.addr, "/metrics");
    let v = Value::parse(&body).unwrap();
    assert!(v.get("storage").is_none(), "no storage block");
    server.shutdown();

    // No admin at all: both 404.
    let server = start_server();
    let (status, _, _) = get(&server.addr, "/v1/storage");
    assert_eq!(status, 404);
    let (status, _, _) = post(&server.addr, "/v1/checkpoint", "");
    assert_eq!(status, 404);
    server.shutdown();
}

#[test]
fn metrics_count_served_requests() {
    let server = start_server();
    for _ in 0..3 {
        let (status, _, _) = get(&server.addr, "/v1/score?user=1");
        assert_eq!(status, 200);
    }
    let (_, _, body) = get(&server.addr, "/metrics");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.req("requests").as_usize(), Some(3));
    server.shutdown();
}

// =====================================================================
// Front-end battery: the same assertions against BOTH the blocking and
// the evented front end (ISSUE 8) — keep-alive negotiation, pipelining,
// fragmentation, protocol limits, timeouts, drain.
// =====================================================================

const MODES: [&str; 2] = ["blocking", "evented"];

fn frontend_cfg(mode: &str) -> FrontendConfig {
    FrontendConfig {
        mode: mode.into(),
        ..FrontendConfig::default()
    }
}

fn start_mode_with(cfg: FrontendConfig, workers: usize) -> HttpServer {
    let ranker: Arc<dyn PreRanker> = Arc::new(MockRanker {
        metrics: ServingMetrics::new(),
    });
    HttpServer::start_frontend(ranker, None, "127.0.0.1:0", &cfg, workers)
        .expect("server starts")
}

fn start_mode(mode: &str) -> HttpServer {
    start_mode_with(frontend_cfg(mode), 2)
}

/// Reads exactly one response per call off a (possibly keep-alive)
/// connection; leftover bytes stay buffered for the next call, so
/// pipelined responses come back one at a time, in order.
struct RespReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl RespReader {
    fn connect(addr: &str) -> RespReader {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .expect("read timeout");
        RespReader {
            stream,
            buf: Vec::new(),
        }
    }

    fn send(&mut self, raw: &str) {
        self.stream.write_all(raw.as_bytes()).expect("write request");
    }

    fn next(&mut self) -> (u16, String, String) {
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(pos) =
                self.buf.windows(4).position(|w| w == b"\r\n\r\n")
            {
                break pos;
            }
            let n = self.stream.read(&mut chunk).expect("read head");
            assert!(n > 0, "EOF before a full response head");
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8(self.buf[..head_end].to_vec())
            .expect("utf8 head");
        let content_length: usize = head
            .lines()
            .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
            .and_then(|l| l.split(':').nth(1))
            .and_then(|v| v.trim().parse().ok())
            .expect("Content-Length header");
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let n = self.stream.read(&mut chunk).expect("read body");
            assert!(n > 0, "EOF mid response body");
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = String::from_utf8(self.buf[head_end + 4..total].to_vec())
            .expect("utf8 body");
        self.buf.drain(..total);
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .expect("status code")
            .parse()
            .expect("numeric status");
        (status, head, body)
    }

    /// True when the server has closed its end (no buffered leftovers).
    fn at_eof(&mut self) -> bool {
        if !self.buf.is_empty() {
            return false;
        }
        let mut b = [0u8; 1];
        matches!(self.stream.read(&mut b), Ok(0))
    }
}

#[test]
fn frontends_answer_identical_bytes() {
    // Bitwise identity across front ends, by construction: both run the
    // same dispatch + the same serializer.  /metrics is excluded (live
    // counters legitimately differ).
    // Large-but-legal head: padding stays under MAX_HEADER_BYTES.
    let big = "x".repeat(8 * 1024);
    let requests = [
        "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"
            .to_string(),
        "GET /v1/score?user=3&top_k=4 HTTP/1.1\r\nHost: t\r\n\
         Connection: close\r\n\r\n"
            .to_string(),
        "GET /v1/score?user=99999 HTTP/1.1\r\nHost: t\r\n\
         Connection: close\r\n\r\n"
            .to_string(),
        "GET /healthz HTTP/1.0\r\nHost: t\r\n\r\n".to_string(),
        "DELETE /v1/score HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"
            .to_string(),
        "GET /nope HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"
            .to_string(),
        "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Length: 32\r\n\
         Connection: close\r\n\r\n{\"users\": [1, 2, 3], \"top_k\": 2}"
            .to_string(),
        "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Length: 9\r\n\
         Connection: close\r\n\r\n{not json"
            .to_string(),
        "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Length: 2000000\r\n\
         \r\n"
            .to_string(),
        format!(
            "GET /healthz HTTP/1.1\r\nHost: t\r\nX-Pad: {big}\r\n\
             Connection: close\r\n\r\n"
        ),
    ];
    let blocking = start_mode("blocking");
    let evented = start_mode("evented");
    for raw in &requests {
        let a = raw_request(&blocking.addr, raw);
        let b = raw_request(&evented.addr, raw);
        let label = raw.lines().next().unwrap_or("");
        assert_eq!(a, b, "front ends diverged on {label:?}");
    }
    blocking.shutdown();
    evented.shutdown();
}

#[test]
fn keep_alive_reuses_connection_and_close_is_honored() {
    for mode in MODES {
        let server = start_mode(mode);
        let mut r = RespReader::connect(&server.addr);
        for _ in 0..3 {
            r.send("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            let (status, head, body) = r.next();
            assert_eq!(status, 200, "{mode}");
            assert!(
                head.contains("Connection: keep-alive"),
                "{mode}: {head}"
            );
            assert_eq!(body, "ok", "{mode}");
        }
        // Explicit close is honored and echoed back.
        r.send(
            "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        );
        let (status, head, _) = r.next();
        assert_eq!(status, 200, "{mode}");
        assert!(head.contains("Connection: close"), "{mode}: {head}");
        assert!(r.at_eof(), "{mode}: server must close after close");
        let stats = server.frontend_stats();
        assert_eq!(stats.mode(), mode);
        assert!(
            stats.keepalive_reuses.load(Ordering::Relaxed) >= 3,
            "{mode}: keep-alive reuse must be counted"
        );
        server.shutdown();
    }
}

#[test]
fn keepalive_budget_caps_requests_per_connection() {
    for mode in MODES {
        let mut cfg = frontend_cfg(mode);
        cfg.keepalive_max_requests = 2;
        let server = start_mode_with(cfg, 2);
        let mut r = RespReader::connect(&server.addr);
        r.send("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        let (_, head, _) = r.next();
        assert!(head.contains("Connection: keep-alive"), "{mode}: {head}");
        r.send("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        let (_, head, _) = r.next();
        assert!(
            head.contains("Connection: close"),
            "{mode}: budget of 2 exhausted -> close; got {head}"
        );
        assert!(r.at_eof(), "{mode}: connection closes at the budget");
        server.shutdown();
    }
}

#[test]
fn http10_defaults_to_close_and_keep_alive_token_overrides() {
    for mode in MODES {
        let server = start_mode(mode);
        // HTTP/1.0 without a Connection header: close by default.
        let (status, head, _) = raw_request(
            &server.addr,
            "GET /healthz HTTP/1.0\r\nHost: t\r\n\r\n",
        );
        assert_eq!(status, 200, "{mode}");
        assert!(head.contains("Connection: close"), "{mode}: {head}");
        // HTTP/1.0 + explicit keep-alive: stays open.
        let mut r = RespReader::connect(&server.addr);
        r.send(
            "GET /healthz HTTP/1.0\r\nHost: t\r\n\
             Connection: keep-alive\r\n\r\n",
        );
        let (status, head, _) = r.next();
        assert_eq!(status, 200, "{mode}");
        assert!(head.contains("Connection: keep-alive"), "{mode}: {head}");
        r.send("GET /healthz HTTP/1.0\r\nHost: t\r\n\r\n");
        let (status, _, _) = r.next();
        assert_eq!(status, 200, "{mode}: connection stayed usable");
        server.shutdown();
    }
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    for mode in MODES {
        let server = start_mode(mode);
        let mut r = RespReader::connect(&server.addr);
        let mut batch = String::new();
        for user in [1usize, 2, 3] {
            batch += &format!(
                "GET /v1/score?user={user} HTTP/1.1\r\nHost: t\r\n\r\n"
            );
        }
        r.send(&batch);
        for user in [1usize, 2, 3] {
            let (status, _, body) = r.next();
            assert_eq!(status, 200, "{mode}");
            let v = Value::parse(&body).expect("JSON body");
            assert_eq!(v.req("user").as_usize(), Some(user), "{mode}");
        }
        server.shutdown();
    }
}

#[test]
fn byte_at_a_time_request_parses_over_the_socket() {
    for mode in MODES {
        let server = start_mode(mode);
        let mut s = TcpStream::connect(&server.addr).expect("connect");
        let raw =
            b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
        for b in raw {
            s.write_all(std::slice::from_ref(b)).expect("write byte");
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).expect("read");
        let text = String::from_utf8(buf).expect("utf8");
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{mode}: {text}");
        assert!(text.ends_with("ok"), "{mode}: {text}");
        server.shutdown();
    }
}

#[test]
fn oversized_header_431_and_oversized_body_413_over_the_socket() {
    for mode in MODES {
        let server = start_mode(mode);
        // An unterminated head that crosses MAX_HEADER_BYTES.  Sent in
        // two phases (the bound trips strictly past 16 KiB) so the
        // server has consumed every byte before it errors: the close
        // is then a clean FIN, never an RST that could destroy the
        // in-flight 431 reply.
        let mut s = TcpStream::connect(&server.addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let prefix = "GET /healthz HTTP/1.1\r\nX-Pad: ";
        let first = "a".repeat(16 * 1024 - prefix.len());
        s.write_all(prefix.as_bytes()).expect("write");
        s.write_all(first.as_bytes()).expect("write");
        std::thread::sleep(Duration::from_millis(100));
        s.write_all(&[b'a'; 1024]).expect("write");
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).expect("read 431");
        let text = String::from_utf8(buf).expect("utf8");
        assert!(text.starts_with("HTTP/1.1 431"), "{mode}: {text}");
        assert!(text.contains("Connection: close"), "{mode}: {text}");
        // Declared-oversized body is refused before any body byte.
        let (status, _, body) = raw_request(
            &server.addr,
            "POST /v1/score HTTP/1.1\r\nHost: t\r\n\
             Content-Length: 2000000\r\n\r\n",
        );
        assert_eq!(status, 413, "{mode}: {body}");
        assert!(
            server.frontend_stats().parse_errors.load(Ordering::Relaxed)
                >= 2,
            "{mode}"
        );
        server.shutdown();
    }
}

#[test]
fn slow_loris_times_out_without_reaching_a_scoring_worker() {
    for mode in MODES {
        let mut cfg = frontend_cfg(mode);
        cfg.header_timeout_ms = 200;
        let server = start_mode_with(cfg, 2);
        let mut s = TcpStream::connect(&server.addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        // A partial request line, then silence.
        s.write_all(b"GET /healthz HT").expect("write");
        let started = Instant::now();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).expect("read 408");
        let text = String::from_utf8(buf).expect("utf8");
        assert!(
            text.starts_with("HTTP/1.1 408 Request Timeout"),
            "{mode}: {text}"
        );
        assert!(
            started.elapsed() < Duration::from_secs(10),
            "{mode}: timeout ladder must cut the slow client promptly"
        );
        let stats = server.frontend_stats();
        assert_eq!(
            stats.requests.load(Ordering::Relaxed),
            0,
            "{mode}: an unparsed connection must never become a request"
        );
        assert!(
            stats.timed_out_header.load(Ordering::Relaxed) >= 1,
            "{mode}"
        );
        server.shutdown();
    }
}

/// MockRanker behind an artificial scoring delay, for drain tests.
struct SlowRanker {
    inner: MockRanker,
    delay: Duration,
}

impl PreRanker for SlowRanker {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        std::thread::sleep(self.delay);
        self.inner.score(req)
    }

    fn variant_name(&self) -> &str {
        "slow-mock"
    }

    fn n_users(&self) -> usize {
        self.inner.n_users()
    }

    fn metrics(&self) -> &ServingMetrics {
        self.inner.metrics()
    }
}

#[test]
fn graceful_drain_loses_no_replies() {
    for mode in MODES {
        let ranker: Arc<dyn PreRanker> = Arc::new(SlowRanker {
            inner: MockRanker {
                metrics: ServingMetrics::new(),
            },
            delay: Duration::from_millis(150),
        });
        let server = HttpServer::start_frontend(
            ranker,
            None,
            "127.0.0.1:0",
            &frontend_cfg(mode),
            4,
        )
        .expect("server starts");
        let stats = Arc::clone(server.frontend_stats());
        let n: u64 = 6;
        let clients: Vec<_> = (0..n)
            .map(|i| {
                let addr = server.addr.clone();
                std::thread::spawn(move || {
                    let (status, _, body) = raw_request(
                        &addr,
                        &format!(
                            "GET /v1/score?user={i} HTTP/1.1\r\nHost: t\r\n\
                             Connection: close\r\n\r\n"
                        ),
                    );
                    assert_eq!(status, 200, "{body}");
                })
            })
            .collect();
        // Wait until every request has reached the server, then drain
        // while all of them are still being scored.
        let deadline = Instant::now() + Duration::from_secs(10);
        while stats.requests.load(Ordering::Relaxed) < n
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            stats.requests.load(Ordering::Relaxed),
            n,
            "{mode}: all requests in flight before drain"
        );
        server.shutdown();
        for c in clients {
            c.join().expect("no client lost its reply");
        }
        assert_eq!(
            stats.responses.load(Ordering::Relaxed),
            n,
            "{mode}: drain must flush every accepted request's reply"
        );
        assert_eq!(
            stats.open.load(Ordering::Relaxed),
            0,
            "{mode}: drain must close every connection"
        );
    }
}

#[test]
fn evented_enforces_max_connections_while_idle_conns_stay_cheap() {
    let mut cfg = frontend_cfg("evented");
    cfg.max_connections = 8;
    let server = start_mode_with(cfg, 2);
    // Fill capacity with idle keep-alive connections.
    let mut idle: Vec<TcpStream> = (0..8)
        .map(|_| TcpStream::connect(&server.addr).expect("connect"))
        .collect();
    let stats = Arc::clone(server.frontend_stats());
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.open.load(Ordering::Relaxed) < 8
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(stats.open.load(Ordering::Relaxed), 8);
    // The ninth is rejected at accept: dropped without a response.
    let mut extra = TcpStream::connect(&server.addr).expect("connect");
    extra
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut b = [0u8; 16];
    match extra.read(&mut b) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("rejected conn got {n} bytes"),
    }
    assert!(
        stats.rejected_capacity.load(Ordering::Relaxed) >= 1,
        "rejection must be counted"
    );
    // The idle connections are still live: one request round-trips.
    let stream = idle.pop().unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let mut r = RespReader {
        stream,
        buf: Vec::new(),
    };
    r.send("GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let (status, _, body) = r.next();
    assert_eq!(status, 200);
    assert_eq!(body, "ok");
    server.shutdown();
}

fn header_value(head: &str, name: &str) -> Option<String> {
    head.lines().find_map(|l| {
        let (k, v) = l.split_once(':')?;
        k.eq_ignore_ascii_case(name).then(|| v.trim().to_string())
    })
}

/// Stub that sheds every request, for the `Retry-After` surface.
struct OverloadedRanker {
    metrics: ServingMetrics,
}

impl PreRanker for OverloadedRanker {
    fn score(&self, _req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        Err(ServeError::Overloaded("synthetic overload".into()))
    }

    fn variant_name(&self) -> &str {
        "overloaded"
    }

    fn n_users(&self) -> usize {
        N_USERS
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }
}

#[test]
fn overload_429_carries_retry_after_in_both_modes() {
    for mode in MODES {
        let ranker: Arc<dyn PreRanker> = Arc::new(OverloadedRanker {
            metrics: ServingMetrics::new(),
        });
        let server = HttpServer::start_frontend(
            ranker,
            None,
            "127.0.0.1:0",
            &frontend_cfg(mode),
            2,
        )
        .expect("server starts");
        let (status, head, body) = get(&server.addr, "/v1/score?user=1");
        assert_eq!(status, 429, "{mode}: {body}");
        let ra = header_value(&head, "Retry-After").unwrap_or_else(|| {
            panic!("{mode}: 429 without Retry-After:\n{head}")
        });
        assert!(
            ra.parse::<u64>().expect("integer Retry-After") >= 1,
            "{mode}: {ra}"
        );
        server.shutdown();
    }
}

/// Stub whose requests block on a gate until the test opens it — holds
/// worker threads occupied so queue overflow is deterministic.
struct GatedRanker {
    inner: MockRanker,
    entered: std::sync::atomic::AtomicUsize,
    gate: (Mutex<bool>, Condvar),
}

impl GatedRanker {
    fn new() -> GatedRanker {
        GatedRanker {
            inner: MockRanker {
                metrics: ServingMetrics::new(),
            },
            entered: std::sync::atomic::AtomicUsize::new(0),
            gate: (Mutex::new(false), Condvar::new()),
        }
    }

    fn release(&self) {
        let (m, c) = &self.gate;
        *m.lock().unwrap() = true;
        c.notify_all();
    }
}

impl PreRanker for GatedRanker {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let (m, c) = &self.gate;
        let mut open = m.lock().unwrap();
        while !*open {
            open = c.wait(open).unwrap();
        }
        drop(open);
        self.inner.score(req)
    }

    fn variant_name(&self) -> &str {
        "gated"
    }

    fn n_users(&self) -> usize {
        self.inner.n_users()
    }

    fn metrics(&self) -> &ServingMetrics {
        self.inner.metrics()
    }
}

#[test]
fn queue_overflow_429_advertises_queue_derived_retry_after() {
    // One evented worker => job-queue capacity 8 (OVERLOAD_QUEUE_FACTOR).
    let ranker = Arc::new(GatedRanker::new());
    let server = HttpServer::start_frontend(
        Arc::clone(&ranker) as Arc<dyn PreRanker>,
        None,
        "127.0.0.1:0",
        &frontend_cfg("evented"),
        1,
    )
    .expect("server starts");
    let stats = Arc::clone(server.frontend_stats());
    let wait = |what: &str, ok: &dyn Fn() -> bool| {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !ok() {
            assert!(Instant::now() < deadline, "timed out waiting: {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    };
    // One request occupies the single worker ...
    let mut held = vec![RespReader::connect(&server.addr)];
    held[0].send("GET /v1/score?user=0 HTTP/1.1\r\nHost: t\r\n\r\n");
    wait("worker occupied", &|| {
        ranker.entered.load(Ordering::SeqCst) == 1
    });
    // ... eight more fill the bounded job queue to its cap ...
    for user in 1..=8usize {
        let mut r = RespReader::connect(&server.addr);
        r.send(&format!(
            "GET /v1/score?user={user} HTTP/1.1\r\nHost: t\r\n\r\n"
        ));
        held.push(r);
    }
    wait("queue full", &|| {
        stats.queue_depth.load(Ordering::Relaxed) == 8
    });
    // ... so the ninth is shed with the queue-derived hint:
    // ceil((cap + 1) / cap) = 2 seconds.
    let (status, head, body) = get(&server.addr, "/v1/score?user=9");
    assert_eq!(status, 429, "{body}");
    assert_eq!(
        header_value(&head, "Retry-After").as_deref(),
        Some("2"),
        "queue-derived hint:\n{head}"
    );
    assert!(stats.shed_overload.load(Ordering::Relaxed) >= 1);
    // Opening the gate drains every held request successfully — the
    // shed never cost an accepted request its reply.
    ranker.release();
    for r in &mut held {
        let (status, _, _) = r.next();
        assert_eq!(status, 200);
    }
    server.shutdown();
}

#[test]
fn metrics_expose_frontend_block_in_both_modes() {
    for mode in MODES {
        let server = start_mode(mode);
        let (status, _, body) = get(&server.addr, "/metrics");
        assert_eq!(status, 200, "{mode}");
        let v = Value::parse(&body).expect("metrics is JSON");
        let fe = v.req("frontend");
        assert_eq!(fe.req("mode").as_str(), Some(mode));
        assert!(fe.req("open").as_usize().is_some(), "{mode}");
        assert!(fe.req("accepted").as_usize().is_some(), "{mode}");
        assert!(fe.req("timed_out").get("idle").is_some(), "{mode}");
        assert!(fe.get("queue_depth").is_some(), "{mode}");
        assert!(fe.get("keepalive_reuses").is_some(), "{mode}");
        server.shutdown();
    }
}
