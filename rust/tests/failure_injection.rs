//! Failure-injection + consistency tests over the serving stack.

use std::sync::Arc;

use aif::cache::{RequestKey, UserVecCache};
use aif::config::{ServingConfig, SimMode};
use aif::coordinator::{Merger, ScoreRequest};
use aif::features::LatencyModel;
use aif::nearline::{N2oEntry, N2oTable};

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn test_cfg(variant: &str, sim: SimMode) -> ServingConfig {
    ServingConfig {
        variant: variant.into(),
        sim_mode: sim,
        n_rtp_workers: 2,
        n_candidates: 512,
        top_k: 64,
        retrieval_latency: LatencyModel::fixed(200.0),
        user_store_latency: LatencyModel::fixed(30.0),
        item_store_latency: LatencyModel::fixed(10.0),
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
            .into(),
        ..Default::default()
    }
}

#[test]
fn missing_n2o_rows_surface_as_errors_not_corruption() {
    // A snapshot with missing rows must refuse assembly (the Merger then
    // errors the request) — never serve garbage.
    let t = N2oTable::new(8, 4, 2, 8);
    let entry = N2oEntry {
        item_vec: vec![1.0; 4],
        bea_w: vec![0.5; 2],
        sign_packed: vec![0xFF],
    };
    t.swap_full(
        vec![
            Some(entry.clone()),
            None, // hole
            Some(entry.clone()),
            None,
            None,
            None,
            None,
            None,
        ],
        1,
    );
    let snap = t.snapshot();
    assert!(snap.assemble(&[0, 2], 4).is_some());
    assert!(snap.assemble(&[0, 1], 4).is_none(), "hole must be detected");
}

#[test]
fn user_cache_double_take_is_a_miss_not_a_stale_read() {
    let cache = UserVecCache::new(4);
    let key = RequestKey::new(9, "u9");
    assert!(cache.take(key).is_none());
    assert_eq!(
        cache.misses.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

#[test]
fn merger_rejects_unknown_variant() {
    if !have_artifacts() {
        return;
    }
    let cfg = test_cfg("no_such_variant", SimMode::Off);
    assert!(Merger::build(cfg).is_err());
}

#[test]
fn merger_survives_concurrent_nearline_updates() {
    // Incremental N2O upserts racing live traffic: every request must keep
    // seeing a complete, consistent generation (snapshot isolation).
    if !have_artifacts() {
        return;
    }
    let merger =
        Arc::new(Merger::build(test_cfg("aif", SimMode::Precached)).unwrap());
    let n2o = Arc::clone(&merger.core().n2o);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let updater = std::thread::spawn(move || {
        let mut v = 0u32;
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            // Churn rows in place (same values, new allocation).
            let snap = n2o.snapshot();
            if let Some(e) = snap.get(v % 100) {
                n2o.upsert(vec![(v % 100, e.to_entry())]);
            }
            v += 1;
        }
    });
    for id in 0..6u64 {
        let user = (id as usize * 29) % merger.world().n_users;
        let r = merger
            .score(ScoreRequest::user(user).with_request_id(id))
            .unwrap();
        assert_eq!(r.items.len(), 64);
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    updater.join().unwrap();
}

#[test]
fn request_ids_do_not_collide_across_users() {
    // Same request id, different users -> different cache keys (the
    // consistent-hash key includes the nickname).
    let a = RequestKey::new(42, "user-1");
    let b = RequestKey::new(42, "user-2");
    assert_ne!(a, b);
}
