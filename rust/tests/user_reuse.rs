//! Cross-request user-state reuse integration tests (DESIGN.md §15),
//! running against the synthetic fixture artifact set over the
//! deterministic PJRT stand-in — no `make artifacts` needed, so these run
//! in CI:
//!
//! * N concurrent requests for one user coalesce into exactly ONE
//!   `user_tower` execution per (user, epoch) through the single-flight
//!   layer;
//! * reuse is bitwise score-identical to the cold request-scoped path
//!   (`user_reuse = false`), and `ScoreTrace.user_side` records
//!   hit / miss / joined;
//! * a hot reload mid-traffic invalidates cached state (epoch bump, tower
//!   re-runs) with zero failed requests;
//! * a deadline-abandoned request KEEPS the shared entry (other requests
//!   reuse it) while the legacy path still drops its request-scoped one;
//! * cached entries are detached from the arena — no pooled buffer is
//!   pinned by a cache resident, before or after eviction.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use aif::cache::{ArenaPool, Claim, UserAsync, UserKey, UserStateCache};
use aif::config::ServingConfig;
use aif::coordinator::{Merger, ScenarioAdmin, ScoreRequest, ServeError};
use aif::features::LatencyModel;
use aif::runtime::Tensor;
use aif::util::fixture;

/// Fresh fixture dir per test (tests run in parallel).
fn fixture_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("aif-userreuse-{}-{tag}", std::process::id()));
    fixture::write(&dir).expect("fixture generation");
    dir
}

/// Removes the fixture dir when the test ends (also on panic/unwind).
struct Cleanup(PathBuf);

impl Drop for Cleanup {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Fast config over the full AIF variant (async user side, nearline
/// items, SIM precached).  Long TTL: nothing expires mid-test.
fn core_cfg(dir: &PathBuf) -> ServingConfig {
    ServingConfig {
        n_rtp_workers: 2,
        n_async_workers: 4,
        n_candidates: 48,
        top_k: 16,
        retrieval_latency: LatencyModel::fixed(100.0),
        user_store_latency: LatencyModel::fixed(20.0),
        item_store_latency: LatencyModel::fixed(10.0),
        sim_parse_us: 0.1,
        user_cache_ttl_ms: 60_000,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

/// Fixed candidate override: the retrieval stage is stochastic, the
/// scoring path must not be.
fn cands() -> Vec<u32> {
    (0..48u32).collect()
}

fn tower_execs(m: &Merger) -> u64 {
    m.core().rtp.executions_of("user_tower")
}

#[test]
fn concurrent_requests_share_one_tower_call() {
    let dir = fixture_dir("singleflight");
    let _cleanup = Cleanup(dir.clone());
    let merger = Arc::new(Merger::build(core_cfg(&dir)).expect("merger"));
    assert_eq!(tower_execs(&merger), 0, "no tower call before traffic");

    const N: usize = 8;
    let barrier = Arc::new(Barrier::new(N));
    let mut handles = Vec::new();
    for _ in 0..N {
        let merger = Arc::clone(&merger);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            merger
                .score(
                    ScoreRequest::user(7)
                        .with_candidates(cands())
                        .with_top_k(16)
                        .with_trace(true),
                )
                .expect("concurrent request")
        }));
    }
    let responses: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // One tower execution total: the single-flight leader's.
    assert_eq!(
        tower_execs(&merger),
        1,
        "N concurrent requests for one user must share ONE user_tower call"
    );
    for r in &responses[1..] {
        assert_eq!(r.items, responses[0].items, "divergent scores");
    }
    // Exactly one miss led the flight; everyone else hit or joined.
    let sides: Vec<&str> = responses
        .iter()
        .map(|r| r.trace.as_ref().unwrap().user_side.unwrap())
        .collect();
    assert_eq!(
        sides.iter().filter(|s| **s == "miss").count(),
        1,
        "sides: {sides:?}"
    );
    assert!(
        sides.iter().all(|s| matches!(*s, "miss" | "hit" | "joined")),
        "sides: {sides:?}"
    );
    let uc = &merger.core().user_cache;
    assert_eq!(uc.stats.misses.load(Ordering::Relaxed), 1);
    assert_eq!(
        uc.stats.hits.load(Ordering::Relaxed)
            + uc.stats.single_flight_joins.load(Ordering::Relaxed),
        (N - 1) as u64
    );
    assert_eq!(uc.inflight_len(), 0, "flight retired");
    assert_eq!(uc.entries(), 1);
    assert!(uc.resident_bytes() > 0);

    // The `/metrics` user_cache block carries the observability fields.
    let snap = merger.user_cache_stats().expect("user_cache block");
    assert_eq!(snap.req("mode").as_str(), Some("shared"));
    assert_eq!(snap.req("misses").as_usize(), Some(1));
    assert!(snap.req("single_flight_joins").as_usize().is_some());
    assert!(snap.req("evictions").as_usize().is_some());
    assert!(snap.req("resident_bytes").as_usize().unwrap() > 0);
    assert!(snap.req("epoch").as_usize().is_some());
}

#[test]
fn reuse_is_bitwise_identical_to_cold_path() {
    let dir = fixture_dir("bitwise");
    let _cleanup = Cleanup(dir.clone());
    let on = Arc::new(Merger::build(core_cfg(&dir)).expect("reuse on"));
    let off_cfg = ServingConfig {
        user_reuse: false,
        ..core_cfg(&dir)
    };
    let off = Arc::new(Merger::build(off_cfg).expect("reuse off"));

    let users = [1usize, 5, 11];
    for (i, &user) in users.iter().enumerate() {
        for round in 0..2 {
            let req = || {
                ScoreRequest::user(user)
                    .with_candidates(cands())
                    .with_top_k(16)
                    .with_trace(true)
            };
            let a = off
                .score(req().with_request_id((100 + 10 * i + round) as u64))
                .expect("cold-path scores");
            let b = on.score(req()).expect("reuse scores");
            assert_eq!(
                a.items, b.items,
                "user {user} round {round}: reuse diverged from cold path"
            );
            // Trace: reuse path misses once then hits; the cold path
            // recomputes every time.
            let side = |r: &aif::coordinator::ScoreResponse| {
                r.trace.as_ref().unwrap().user_side.unwrap()
            };
            assert_eq!(side(&a), "miss");
            assert_eq!(
                side(&b),
                if round == 0 { "miss" } else { "hit" }
            );
            if round == 1 {
                assert!(
                    b.timings.user_async.is_none(),
                    "a hit must skip the async phase entirely"
                );
            }
        }
    }
    assert_eq!(
        tower_execs(&on),
        users.len() as u64,
        "one tower call per distinct user with reuse on"
    );
    assert_eq!(
        tower_execs(&off),
        2 * users.len() as u64,
        "one tower call per REQUEST with reuse off"
    );
}

#[test]
fn reload_invalidates_without_failed_requests() {
    let dir = fixture_dir("reload");
    let _cleanup = Cleanup(dir.clone());
    let merger = Arc::new(Merger::build(core_cfg(&dir)).expect("merger"));
    let name = merger.registry().default_name();

    // Warm, hit, then reload: the epoch moves and the tower re-runs.
    let req = || {
        ScoreRequest::user(3).with_candidates(cands()).with_top_k(16)
    };
    let before = merger.score(req()).expect("warm request");
    assert_eq!(tower_execs(&merger), 1);
    let _ = merger.score(req()).expect("hit request");
    assert_eq!(tower_execs(&merger), 1, "second request hits the cache");
    let epoch_before = merger.core().user_epoch();
    merger.registry().reload(&name).expect("hot reload");
    assert!(
        merger.core().user_epoch() > epoch_before,
        "reload must bump the user-state epoch"
    );
    let after = merger.score(req()).expect("post-reload request");
    assert_eq!(
        tower_execs(&merger),
        2,
        "post-reload request must recompute (old epoch invalidated)"
    );
    assert_eq!(before.items, after.items, "reload changed the scores");

    // Reload churn under concurrent traffic: zero failed requests.
    let stop = Arc::new(AtomicBool::new(false));
    let churner = {
        let merger = Arc::clone(&merger);
        let stop = Arc::clone(&stop);
        let name = name.clone();
        std::thread::spawn(move || {
            let mut reloads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                merger.registry().reload(&name).expect("reload succeeds");
                reloads += 1;
                std::thread::sleep(Duration::from_micros(200));
            }
            reloads
        })
    };
    let users = [1usize, 5, 11, 17];
    let mut handles = Vec::new();
    for t in 0..4usize {
        let merger = Arc::clone(&merger);
        handles.push(std::thread::spawn(move || {
            for m in 0..25usize {
                let user = users[(t + m) % users.len()];
                let r = merger
                    .score(
                        ScoreRequest::user(user)
                            .with_candidates(cands())
                            .with_top_k(16),
                    )
                    .expect("no failed requests during reload churn");
                assert_eq!(r.items.len(), 16);
            }
        }));
    }
    for h in handles {
        h.join().expect("traffic thread panicked");
    }
    stop.store(true, Ordering::Relaxed);
    let reloads = churner.join().expect("churn thread panicked");
    assert!(reloads > 0, "at least one reload raced the traffic");
    assert_eq!(merger.core().user_cache.inflight_len(), 0);
}

/// The feature-store leg of the epoch contract: `bump_version` (a
/// wholesale re-ingest of user features) must invalidate cached user
/// state on the next request, exactly like a reload or a nearline swap.
#[test]
fn feature_store_version_bump_invalidates() {
    let dir = fixture_dir("storever");
    let _cleanup = Cleanup(dir.clone());
    let merger = Arc::new(Merger::build(core_cfg(&dir)).expect("merger"));
    let req = || {
        ScoreRequest::user(5).with_candidates(cands()).with_top_k(16)
    };
    let before = merger.score(req()).expect("warm request");
    let _ = merger.score(req()).expect("hit request");
    assert_eq!(tower_execs(&merger), 1);
    let epoch = merger.core().user_epoch();
    merger.core().store.bump_version();
    assert!(
        merger.core().user_epoch() > epoch,
        "store version feeds the composed epoch"
    );
    let after = merger.score(req()).expect("post-bump request");
    assert_eq!(
        tower_execs(&merger),
        2,
        "a store version bump must recompute the user side"
    );
    // The fixture data didn't actually change, so scores are identical.
    assert_eq!(before.items, after.items);
}

#[test]
fn abandoned_deadline_keeps_shared_entry() {
    let dir = fixture_dir("deadline");
    let _cleanup = Cleanup(dir.clone());
    let merger = Arc::new(Merger::build(core_cfg(&dir)).expect("merger"));

    // A deadline nobody can meet: the request is abandoned at the gate
    // AFTER phase 1 resolved.
    let doomed = merger.score(
        ScoreRequest::user(9)
            .with_candidates(cands())
            .with_top_k(16)
            .with_deadline(Duration::from_nanos(1)),
    );
    assert!(
        matches!(doomed, Err(ServeError::DeadlineExceeded { .. })),
        "{doomed:?}"
    );
    // The shared entry survives the abandonment: the next request for
    // this user reuses it instead of re-running the tower.
    let ok = merger
        .score(
            ScoreRequest::user(9).with_candidates(cands()).with_top_k(16),
        )
        .expect("follow-up request");
    assert_eq!(ok.items.len(), 16);
    assert_eq!(
        tower_execs(&merger),
        1,
        "abandonment of one request must not evict reusable user state"
    );

    // Legacy contrast: the request-scoped entry is keyed by the doomed
    // request and is correctly dropped at the gate (no leak) — the
    // follow-up pays a fresh tower call.
    let off_cfg = ServingConfig {
        user_reuse: false,
        ..core_cfg(&dir)
    };
    let off = Arc::new(Merger::build(off_cfg).expect("reuse off"));
    let doomed = off.score(
        ScoreRequest::user(9)
            .with_request_id(1)
            .with_candidates(cands())
            .with_top_k(16)
            .with_deadline(Duration::from_nanos(1)),
    );
    assert!(matches!(doomed, Err(ServeError::DeadlineExceeded { .. })));
    assert_eq!(
        off.core().user_cache.entries(),
        0,
        "request-scoped entry must not leak after abandonment"
    );
    let _ = off
        .score(
            ScoreRequest::user(9)
                .with_request_id(2)
                .with_candidates(cands())
                .with_top_k(16),
        )
        .expect("follow-up request");
    assert_eq!(tower_execs(&off), 2, "no reuse on the legacy path");
}

/// Satellite: cache inserts detach arena-backed tensors, so a long-lived
/// entry can never pin a pooled buffer — asserted through the
/// single-flight insert path, before and after eviction.
#[test]
fn cached_entries_pin_no_arena_buffers() {
    let pool = ArenaPool::new(8);
    let pooled_tensor = |shape: Vec<usize>, v: f32| {
        let n: usize = shape.iter().product();
        let mut buf = pool.get(n);
        buf.extend(std::iter::repeat(v).take(n));
        Tensor::from_pooled(shape, buf)
    };
    let pooled_ua = |v: f32| UserAsync {
        u_vec: pooled_tensor(vec![1, 8], v),
        bea_v: pooled_tensor(vec![4, 8], v),
        seq_emb: pooled_tensor(vec![6, 8], v),
        din_base: pooled_tensor(vec![1, 8], v),
        din_g: pooled_tensor(vec![6, 8], v),
        seq_sign_packed: Arc::new(vec![0xA5, 0x3C]),
        long_seq: vec![1, 2, 3],
    };

    // Capacity 2 over 2 shards: the third distinct key evicts.
    let cache = UserStateCache::shared(2, None, 0, 2);
    let key = UserKey::new(0, 1, 0);
    let Claim::Lead(flight) = cache.claim(key) else {
        panic!("first claim must lead");
    };
    let ua = pooled_ua(1.5);
    assert!(ua.is_pooled(), "precondition: tensors ride the arena");
    assert!(pool.outstanding() > 0);
    cache.complete(key, &flight, Ok((ua, Duration::ZERO)));

    // The insert detached: every pooled buffer is back, yet the cached
    // entry is alive and carries the same values.
    assert_eq!(
        pool.outstanding(),
        0,
        "cache insert must not pin arena buffers"
    );
    let Claim::Hit(cached) = cache.claim(key) else {
        panic!("must hit");
    };
    assert!(!cached.is_pooled(), "cached tensors are owned");
    assert_eq!(cached.u_vec.data(), &[1.5; 8][..]);

    // Evict by filling past capacity with fresh pooled entries; the
    // books stay balanced with entries coming AND going.
    for user in 2..8u32 {
        let k = UserKey::new(0, user, 0);
        let Claim::Lead(f) = cache.claim(k) else {
            panic!("cold key must lead");
        };
        cache.complete(k, &f, Ok((pooled_ua(user as f32), Duration::ZERO)));
    }
    assert!(cache.entries() <= 2, "capacity enforced");
    drop(cached);
    assert_eq!(
        pool.outstanding(),
        0,
        "no arena buffer pinned by evicted or resident entries"
    );
}
