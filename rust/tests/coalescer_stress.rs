//! Deterministic concurrency harness for the cross-request
//! [`BatchCoalescer`]: N threads x M requests with a seeded `Pcg64`
//! workload through a pure in-process executor, asserting that every
//! request gets back exactly its own scores (no cross-request scatter
//! leaks), that per-artifact queues never mix, and that shutdown drains —
//! no reply channel is ever dropped.  No artifacts or PJRT involved.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use aif::cache::ArenaPool;
use aif::metrics::CoalesceStats;
use aif::runtime::{
    BatchCoalescer, CoalescerConfig, HeadExecutor, HeadJob, JobScores,
    Tensor,
};
use aif::util::rng::Pcg64;

/// Deterministic mu-gather executor mirroring the `_mu` artifact
/// contract: inputs are `[user_slots [U,1], row_vals [B,1], row_user
/// [B]]` and `score[r] = mult * user[row_user[r]] + row_vals[r]`.  The
/// per-artifact multiplier makes any cross-artifact mixing show up as a
/// wrong score, not just a wrong count.
struct GatherExec;

/// Power-of-two multipliers keep every score an exactly representable
/// f32 integer (all terms stay below 2^24), so the assertions are
/// bitwise-exact rather than tolerance-based.
fn artifact_mult(artifact: &str) -> f32 {
    match artifact {
        "mu_a" => 131_072.0,    // 2^17
        "mu_b" => 1_048_576.0,  // 2^20
        other => panic!("unexpected artifact {other:?}"),
    }
}

impl HeadExecutor for GatherExec {
    fn execute_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>, anyhow::Error>> {
        let (tx, rx) = channel();
        let mult = artifact_mult(artifact);
        let users = inputs[0].data();
        let rows = inputs[1].data();
        let idx = inputs[2].data();
        assert_eq!(rows.len(), idx.len(), "row inputs align with row_user");
        let scores: Vec<f32> = rows
            .iter()
            .zip(idx.iter())
            .map(|(&v, &s)| mult * users[s as usize] + v)
            .collect();
        let n = scores.len();
        let _ = tx.send(Ok(vec![Tensor::new(vec![n], scores)]));
        rx
    }
}

fn coalescer(cfg: CoalescerConfig) -> (BatchCoalescer, Arc<CoalesceStats>) {
    let stats = Arc::new(CoalesceStats::default());
    let c = BatchCoalescer::new(
        Arc::new(GatherExec),
        cfg,
        Arc::clone(&stats),
    );
    (c, stats)
}

/// One request's job: row values encoding (request, row), a user value
/// encoding the request, and the exact scores the executor must return.
/// Every term is an integer below 2^24, so f32 arithmetic is exact.
fn make_job(
    artifact: &str,
    request: u32,
    n_rows: usize,
) -> (HeadJob, Vec<f32>, Receiver<Result<JobScores, anyhow::Error>>) {
    let user_val = (request % 8) as f32;
    let rows: Vec<f32> = (0..n_rows)
        .map(|r| (request * 64 + r as u32) as f32)
        .collect();
    let expect: Vec<f32> = rows
        .iter()
        .map(|v| artifact_mult(artifact) * user_val + v)
        .collect();
    let (reply, rx): (Sender<Result<JobScores, anyhow::Error>>, _) =
        channel();
    (
        HeadJob {
            artifact: artifact.into(),
            rows: n_rows,
            row_inputs: vec![Tensor::new(vec![n_rows, 1], rows)],
            user_inputs: vec![Tensor::new(vec![1], vec![user_val])],
            deadline: None,
            reply,
        },
        expect,
        rx,
    )
}

#[test]
fn stress_no_scatter_leaks_across_requests() {
    const N_THREADS: usize = 8;
    const M_REQUESTS: usize = 200;
    let (c, stats) = coalescer(CoalescerConfig {
        exec_rows: 64,
        max_rows: 64,
        max_slots: 4,
        window: Duration::from_micros(200),
        bypass_margin: Duration::from_millis(2),
    });
    let c = Arc::new(c);
    let mut handles = Vec::new();
    for t in 0..N_THREADS {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::with_stream(0xC0A1E5CE, t as u64);
            for m in 0..M_REQUESTS {
                let request = (t * M_REQUESTS + m) as u32;
                // Both artifacts, skewed toward partial batches so most
                // executions coalesce several requests.
                let artifact = if rng.chance(0.25) { "mu_b" } else { "mu_a" };
                let n_rows = 1 + rng.below(48) as usize;
                let (job, expect, rx) = make_job(artifact, request, n_rows);
                c.submit(job);
                let got = rx
                    .recv()
                    .expect("reply channel alive")
                    .expect("execution succeeds");
                assert_eq!(
                    got.scores, expect,
                    "request {request} got someone else's rows"
                );
                assert!(got.coalesced_jobs >= 1);
                assert!(got.coalesced_rows >= n_rows);
            }
        }));
    }
    for h in handles {
        h.join().expect("no worker panicked");
    }
    let total = (N_THREADS * M_REQUESTS) as u64;
    let jobs = stats.jobs.load(std::sync::atomic::Ordering::Relaxed);
    let execs = stats
        .executions
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(jobs, total, "every job was dispatched exactly once");
    assert!(execs <= jobs, "executions never exceed jobs");
    drop(c);
}

#[test]
fn seeded_workload_is_exact_under_forced_merging() {
    // Single-threaded, giant window: all jobs of a wave must merge into
    // full packs deterministically, and each must still get exactly its
    // own slice back.
    let (c, stats) = coalescer(CoalescerConfig {
        exec_rows: 32,
        max_rows: 32,
        max_slots: 3,
        window: Duration::from_millis(300),
        bypass_margin: Duration::from_millis(1),
    });
    let mut rng = Pcg64::new(0xA1F);
    let mut pending = Vec::new();
    for request in 0..40u32 {
        let n_rows = 1 + rng.below(16) as usize;
        let (job, expect, rx) = make_job("mu_a", request, n_rows);
        c.submit(job);
        pending.push((request, expect, rx));
    }
    for (request, expect, rx) in pending {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.scores, expect, "request {request}");
    }
    let execs = stats
        .executions
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(execs < 40, "forced merging produced fewer executions: {execs}");
    drop(c);
}

#[test]
fn arena_backed_merging_is_exact_and_leak_free_under_stress() {
    // Same stress shape as above, but merged executions assemble into an
    // arena pool: scores must stay bitwise-exact, and once the coalescer
    // drains and joins, every pooled buffer taken for a merged input must
    // be back in the pool (the RTP-retire return path).
    const N_THREADS: usize = 6;
    const M_REQUESTS: usize = 120;
    let stats = Arc::new(CoalesceStats::default());
    let arena = ArenaPool::new(16);
    let c = Arc::new(BatchCoalescer::with_arena(
        Arc::new(GatherExec),
        CoalescerConfig {
            exec_rows: 64,
            max_rows: 64,
            max_slots: 4,
            window: Duration::from_micros(200),
            bypass_margin: Duration::from_millis(2),
        },
        Arc::clone(&stats),
        Some(Arc::clone(&arena)),
    ));
    let mut handles = Vec::new();
    for t in 0..N_THREADS {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::with_stream(0xA7E4A, t as u64);
            for m in 0..M_REQUESTS {
                let request = (t * M_REQUESTS + m) as u32;
                let artifact =
                    if rng.chance(0.25) { "mu_b" } else { "mu_a" };
                let n_rows = 1 + rng.below(48) as usize;
                let (job, expect, rx) = make_job(artifact, request, n_rows);
                c.submit(job);
                let got = rx
                    .recv()
                    .expect("reply channel alive")
                    .expect("execution succeeds");
                assert_eq!(
                    got.scores, expect,
                    "request {request}: arena-backed merge corrupted rows"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("no worker panicked");
    }
    drop(c); // drains queues, joins dispatch + scatter threads
    assert_eq!(
        arena.outstanding(),
        0,
        "merged-input buffers must all return once the coalescer drains"
    );
    assert!(
        arena.reuses.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "steady-state merging must recycle buffers, not allocate"
    );
}

#[test]
fn shutdown_drains_every_reply_channel() {
    // Jobs parked behind an hour-long window; dropping the coalescer must
    // flush them through the executor rather than dropping the repliers.
    let (c, _) = coalescer(CoalescerConfig {
        exec_rows: 256,
        max_rows: 256,
        max_slots: 8,
        window: Duration::from_secs(3600),
        bypass_margin: Duration::from_millis(1),
    });
    let mut pending = Vec::new();
    for request in 0..30u32 {
        let (job, expect, rx) = make_job("mu_a", request, 5);
        c.submit(job);
        pending.push((expect, rx));
    }
    drop(c);
    for (expect, rx) in pending {
        let got = rx
            .recv()
            .expect("no reply channel dropped on shutdown")
            .expect("drained jobs execute, not error");
        assert_eq!(got.scores, expect);
    }
}

#[test]
fn deadline_bypass_jumps_the_window() {
    let (c, stats) = coalescer(CoalescerConfig {
        exec_rows: 64,
        max_rows: 64,
        max_slots: 8,
        window: Duration::from_secs(3600),
        bypass_margin: Duration::from_millis(5),
    });
    let (mut job, expect, rx) = make_job("mu_a", 7, 3);
    job.deadline = Some(Instant::now() + Duration::from_millis(1));
    let t0 = Instant::now();
    c.submit(job);
    let got = rx.recv().unwrap().unwrap();
    assert_eq!(got.scores, expect);
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "bypass must not wait out the hour-long window"
    );
    assert_eq!(
        stats.bypass_jobs.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    // A job with plenty of budget does not bypass; it rides the next
    // flush (here: shutdown drain).
    let (mut job, expect, rx) = make_job("mu_a", 8, 2);
    job.deadline = Some(Instant::now() + Duration::from_secs(3600));
    c.submit(job);
    drop(c);
    assert_eq!(rx.recv().unwrap().unwrap().scores, expect);
    assert_eq!(
        stats.bypass_jobs.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "far deadlines do not bypass"
    );
}
