//! Property-based invariants over the coordinator substrates, via the
//! in-repo mini framework (`util::prop`) — DESIGN.md §9.

use aif::cache::{ArenaPool, ShardedLru};
use aif::coordinator::batcher;
use aif::coordinator::Router;
use aif::features::{assembly, ItemFeatures};
use aif::nearline::{N2oEntry, N2oTable};
use aif::storage::{
    decode_full, encode_full, state_digest, FsStorage, MemStorage, Storage,
};
use aif::util::bits;
use aif::util::prop::{check, usize_in, vec_of, Gen};
use aif::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Batcher: cover / disjoint / ordered / bounded.
// ---------------------------------------------------------------------
#[test]
fn prop_batcher_partition() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n = 1 + rng.below(5000) as usize;
        let batch = 1 + rng.below(512) as usize;
        (n, batch)
    });
    check("batcher partitions", &gen, 300, |&(n, batch)| {
        let cands: Vec<u32> = (0..n as u32).collect();
        let batches = batcher::split(&cands, batch);
        let rejoined: Vec<u32> = batches
            .iter()
            .flat_map(|b| b.items.iter().copied())
            .collect();
        if rejoined != cands {
            return Err("not a cover / order broken".into());
        }
        for b in &batches {
            if b.items.len() > batch {
                return Err(format!("batch {} too large", b.index));
            }
            if b.offset != b.index * batch {
                return Err("offset mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merge_scores_strips_padding_exactly() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n = 1 + rng.below(2000) as usize;
        let batch = 1 + rng.below(300) as usize;
        (n, batch)
    });
    check("merge strips padding", &gen, 200, |&(n, batch)| {
        let n_batches = n.div_ceil(batch);
        // Scores encode their global index; padding rows get NaN sentinel.
        let per: Vec<Vec<f32>> = (0..n_batches)
            .map(|i| {
                (0..batch)
                    .map(|j| {
                        let g = i * batch + j;
                        if g < n {
                            g as f32
                        } else {
                            f32::NAN
                        }
                    })
                    .collect()
            })
            .collect();
        let merged = batcher::merge_scores(n, batch, &per);
        for (g, v) in merged.iter().enumerate() {
            if *v != g as f32 {
                return Err(format!("index {g} got {v}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Cross-request coalescing: pack_jobs / scatter_scores offsets.
// ---------------------------------------------------------------------

/// Generator for (n_candidates, batch, max_rows, max_slots) coalescing
/// shapes, with `batch <= max_rows` as the coalescer enforces.
fn coalesce_shape_gen() -> Gen<(usize, usize, usize, usize)> {
    Gen::new(|rng: &mut Pcg64| {
        let n = 1 + rng.below(3000) as usize;
        let batch = 1 + rng.below(300) as usize;
        let max_rows = batch * (1 + rng.below(4) as usize);
        let max_slots = 1 + rng.below(6) as usize;
        (n, batch, max_rows, max_slots)
    })
}

#[test]
fn prop_pack_jobs_partitions_fifo_within_caps() {
    check(
        "pack_jobs partitions",
        &coalesce_shape_gen(),
        300,
        |&(n, batch, max_rows, max_slots)| {
            let cands: Vec<u32> = (0..n as u32).collect();
            let rows: Vec<usize> = batcher::split(&cands, batch)
                .iter()
                .map(|b| b.items.len())
                .collect();
            let plan = batcher::pack_jobs(&rows, max_rows, max_slots);
            let mut next_job = 0usize;
            for exec in &plan {
                if exec.is_empty() {
                    return Err("empty execution".into());
                }
                if exec.len() > max_slots {
                    return Err(format!("{} slots > {max_slots}", exec.len()));
                }
                let total: usize = exec.iter().map(|s| s.rows).sum();
                if total > max_rows {
                    return Err(format!("{total} rows > {max_rows}"));
                }
                let mut offset = 0usize;
                for slot in exec {
                    // FIFO: jobs appear exactly once, in submission order,
                    // at prefix-sum offsets.
                    if slot.job != next_job {
                        return Err(format!(
                            "job {} out of order (expected {next_job})",
                            slot.job
                        ));
                    }
                    if slot.offset != offset || slot.rows != rows[slot.job] {
                        return Err(format!("bad slot {slot:?}"));
                    }
                    next_job += 1;
                    offset += slot.rows;
                }
            }
            if next_job != rows.len() {
                return Err(format!(
                    "{next_job} of {} jobs packed",
                    rows.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coalesced_scatter_equals_per_request_merge() {
    // End to end: split a request into mini-batches, pack them through the
    // coalescer's plan, score the merged (padded) executions, scatter the
    // slices back, merge per-request — identical to scoring per-request.
    // Scores encode the global candidate index; padding rows repeat the
    // last real row exactly like runtime::coalescer::merge_inputs does.
    check(
        "coalesced merge == merge_scores",
        &coalesce_shape_gen(),
        300,
        |&(n, batch, max_rows, max_slots)| {
            let cands: Vec<u32> = (0..n as u32).collect();
            let jobs = batcher::split(&cands, batch);
            let rows: Vec<usize> =
                jobs.iter().map(|b| b.items.len()).collect();
            let plan = batcher::pack_jobs(&rows, max_rows, max_slots);
            let mut per_batch: Vec<Option<Vec<f32>>> =
                vec![None; jobs.len()];
            for exec in &plan {
                // Gather: concatenate each job's real rows...
                let mut merged: Vec<f32> = Vec::new();
                for slot in exec {
                    if slot.offset != merged.len() {
                        return Err(format!(
                            "gather offset {} != {}",
                            slot.offset,
                            merged.len()
                        ));
                    }
                    merged.extend(
                        jobs[slot.job].items.iter().map(|&g| g as f32),
                    );
                }
                // ...then pad to the artifact batch with the last row,
                // as the merged execution does.
                let last = *merged.last().unwrap();
                merged.resize(max_rows, last);
                // Scatter the "scores" back by offset.
                for (job, scores) in
                    batcher::scatter_scores(exec, &merged)
                {
                    if per_batch[job].is_some() {
                        return Err(format!("job {job} scattered twice"));
                    }
                    per_batch[job] = Some(scores);
                }
            }
            let per_batch: Vec<Vec<f32>> = per_batch
                .into_iter()
                .map(|b| b.ok_or("job never scattered".to_string()))
                .collect::<Result<_, _>>()?;
            let merged = batcher::merge_scores(n, batch, &per_batch);
            for (g, v) in merged.iter().enumerate() {
                if *v != g as f32 {
                    return Err(format!(
                        "candidate {g} scored {v} after coalescing"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_top_k_is_truly_maximal() {
    let gen = vec_of(usize_in(0, 10_000), 600);
    check("top_k maximal", &gen, 200, |scores_raw: &Vec<usize>| {
        if scores_raw.is_empty() {
            return Ok(());
        }
        let items: Vec<u32> = (0..scores_raw.len() as u32).collect();
        let scores: Vec<f32> =
            scores_raw.iter().map(|&s| s as f32 / 10_000.0).collect();
        let k = 1 + scores.len() / 3;
        let top = batcher::top_k(&items, &scores, k);
        // Sorted descending.
        for w in top.windows(2) {
            if w[0].1 < w[1].1 {
                return Err("not sorted".into());
            }
        }
        // Every excluded score <= the worst included score.
        let worst = top.last().unwrap().1;
        let included: std::collections::HashSet<u32> =
            top.iter().map(|(i, _)| *i).collect();
        for (i, &s) in scores.iter().enumerate() {
            if !included.contains(&(i as u32)) && s > worst {
                return Err(format!("excluded {s} > included {worst}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Consistent-hash router: stability under churn.
// ---------------------------------------------------------------------
#[test]
fn prop_router_remap_is_minimal() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let nodes = 2 + rng.below(14) as usize;
        let victim = rng.below(nodes as u64) as usize;
        (nodes, victim)
    });
    check("router minimal remap", &gen, 50, |&(nodes, victim)| {
        let mut r = Router::new(nodes, 64);
        let before: Vec<usize> = (0..2000u64).map(|k| r.route(k)).collect();
        r.remove_node(victim);
        for (k, &b) in before.iter().enumerate() {
            let after = r.route(k as u64);
            if b != victim && after != b {
                return Err(format!("key {k} moved {b}->{after}"));
            }
            if b == victim && after == victim {
                return Err("routed to removed node".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_router_add_node_only_steals_keys() {
    let gen = Gen::new(|rng: &mut Pcg64| 1 + rng.below(12) as usize);
    check("router add only steals", &gen, 50, |&nodes| {
        let mut r = Router::new(nodes, 64);
        let before: Vec<usize> = (0..2000u64).map(|k| r.route(k)).collect();
        r.add_node(nodes);
        // Lossless ring: every vnode of every node is present even when
        // positions collide (the (position, node) key keeps both).
        if r.ring_len() != (nodes + 1) * 64 {
            return Err(format!(
                "ring holds {} vnodes, want {}",
                r.ring_len(),
                (nodes + 1) * 64
            ));
        }
        let mut stolen = 0usize;
        for (k, &b) in before.iter().enumerate() {
            let after = r.route(k as u64);
            if after != b && after != nodes {
                return Err(format!(
                    "key {k} moved {b}->{after}, not to the new node"
                ));
            }
            if after == nodes {
                stolen += 1;
            }
        }
        // The new node takes a real share of roughly 1/(n+1).
        let fair = 2000 / (nodes + 1);
        if stolen == 0 || stolen > fair * 3 {
            return Err(format!(
                "new node stole {stolen} of 2000 keys (fair {fair})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_router_vnode_balance_bounds_shares() {
    let gen = Gen::new(|rng: &mut Pcg64| 2 + rng.below(7) as usize);
    check("router balance", &gen, 20, |&nodes| {
        let r = Router::new(nodes, 128);
        let samples = 20_000u64;
        let mut counts = vec![0usize; nodes];
        for k in 0..samples {
            counts[r.route(k)] += 1;
        }
        // 128 vnodes keep every node within a small constant factor of
        // the fair share (loose 3x bound: the property is "no node is
        // starved or doubly loaded", not a tight variance claim).
        let fair = samples as usize / nodes;
        for (node, &c) in counts.iter().enumerate() {
            if c < fair / 3 || c > fair * 3 {
                return Err(format!(
                    "node {node} owns {c} of {samples} keys \
                     (fair share {fair})"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// LRU: capacity bound + hit-after-insert.
// ---------------------------------------------------------------------
#[test]
fn prop_lru_capacity_and_recency() {
    let gen = vec_of(usize_in(0, 64), 400);
    check("lru bounded", &gen, 100, |keys: &Vec<usize>| {
        let cap = 16;
        let lru: ShardedLru<usize, usize> = ShardedLru::new(cap, 4);
        for (i, &k) in keys.iter().enumerate() {
            lru.insert(k, i);
            if lru.len() > cap {
                return Err(format!("len {} > cap {cap}", lru.len()));
            }
            // Just-inserted key must be present.
            if lru.get(&k).is_none() {
                return Err("just-inserted key missing".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// N2O: incremental upserts converge to the same state as a full build.
// ---------------------------------------------------------------------
#[test]
fn prop_n2o_incremental_equals_full() {
    let gen = vec_of(usize_in(0, 40), 200);
    check("n2o incremental == full", &gen, 60, |updates: &Vec<usize>| {
        let n = 40;
        let entry = |v: usize| N2oEntry {
            item_vec: vec![v as f32; 4],
            bea_w: vec![v as f32; 2],
            sign_packed: vec![v as u8],
        };
        // Path A: full build with the final values.
        let mut last: Vec<usize> = (0..n).collect();
        for (step, &id) in updates.iter().enumerate() {
            last[id] = 1000 + step;
        }
        let full = N2oTable::new(n, 4, 2, 8);
        full.swap_full(
            (0..n).map(|i| Some(entry(last[i]))).collect(),
            1,
        );
        // Path B: initial build + incremental upserts.
        let inc = N2oTable::new(n, 4, 2, 8);
        inc.swap_full((0..n).map(|i| Some(entry(i))).collect(), 1);
        for (step, &id) in updates.iter().enumerate() {
            inc.upsert(vec![(id as u32, entry(1000 + step))]);
        }
        let (sa, sb) = (full.snapshot(), inc.snapshot());
        for i in 0..n as u32 {
            if sa.get(i) != sb.get(i) {
                return Err(format!("row {i} diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Columnar N2O gather + arena-backed assembly: bitwise-identical to the
// row-based/owned reference for random worlds (ISSUE 4 tentpole pin).
// ---------------------------------------------------------------------
#[test]
fn prop_columnar_n2o_gather_matches_rowwise_reference() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let d = 1 + rng.below(16) as usize;
        let n_bridge = 1 + rng.below(8) as usize;
        let n_bits = 8 * (1 + rng.below(8) as usize);
        // Cross the 512-item chunk boundary often.
        let n_items = 1 + rng.below(1200) as usize;
        let seed = rng.next_u64();
        (d, n_bridge, n_bits, n_items, seed)
    });
    check(
        "columnar gather == rowwise",
        &gen,
        40,
        |&(d, n_bridge, n_bits, n_items, seed)| {
            let mut rng = Pcg64::new(seed);
            let pl = n_bits / 8;
            let table = N2oTable::new(n_items, d, n_bridge, n_bits);
            let mut present = Vec::new();
            let entries: Vec<Option<N2oEntry>> = (0..n_items)
                .map(|i| {
                    if rng.chance(0.85) {
                        present.push(i as u32);
                        Some(N2oEntry {
                            item_vec: (0..d).map(|_| rng.f32()).collect(),
                            bea_w: (0..n_bridge)
                                .map(|_| rng.f32())
                                .collect(),
                            sign_packed: (0..pl)
                                .map(|_| rng.below(256) as u8)
                                .collect(),
                        })
                    } else {
                        None
                    }
                })
                .collect();
            if present.is_empty() {
                return Ok(());
            }
            table.swap_full(entries.clone(), 1);
            let snap = table.snapshot();
            let arena = ArenaPool::new(4);

            // Random present-only subset, random padding.
            let k = 1 + rng.below(present.len().min(64) as u64) as usize;
            let items: Vec<u32> = (0..k)
                .map(|_| {
                    present[rng.below(present.len() as u64) as usize]
                })
                .collect();
            let batch = k + rng.below(8) as usize;

            // Row-wise reference: exactly the old per-row gather.
            let mut vecs = Vec::new();
            let mut ws = Vec::new();
            let mut packed = Vec::new();
            for &it in &items {
                let e = entries[it as usize].as_ref().unwrap();
                vecs.extend_from_slice(&e.item_vec);
                ws.extend_from_slice(&e.bea_w);
                packed.extend_from_slice(&e.sign_packed);
            }
            let last =
                entries[items[k - 1] as usize].as_ref().unwrap();
            for _ in k..batch {
                vecs.extend_from_slice(&last.item_vec);
                ws.extend_from_slice(&last.bea_w);
                packed.extend_from_slice(&last.sign_packed);
            }
            let mut plane = vec![0.0f32; batch * n_bits];
            for r in 0..batch {
                bits::unpack_to_pm1(
                    &packed[r * pl..(r + 1) * pl],
                    n_bits,
                    &mut plane[r * n_bits..(r + 1) * n_bits],
                );
            }

            let (v_o, w_o, s_o) = snap
                .assemble(&items, batch)
                .ok_or("assemble refused a present-only subset")?;
            let (v_p, w_p, s_p) = snap
                .assemble_in(&items, batch, &arena)
                .ok_or("assemble_in refused a present-only subset")?;
            if v_o.data() != &vecs[..] || w_o.data() != &ws[..] {
                return Err("columnar gather != rowwise".into());
            }
            if s_o.data() != &plane[..] {
                return Err("columnar plane != rowwise unpack".into());
            }
            if v_p != v_o || w_p != w_o || s_p != s_o {
                return Err("pooled assembly != owned assembly".into());
            }
            if !(v_p.is_pooled() && w_p.is_pooled() && s_p.is_pooled()) {
                return Err("assemble_in must use arena storage".into());
            }
            drop((v_p, w_p, s_p));
            if arena.outstanding() != 0 {
                return Err(format!(
                    "{} pooled buffers leaked",
                    arena.outstanding()
                ));
            }
            // A hole anywhere in the subset must refuse assembly.
            if let Some(hole) =
                (0..n_items as u32).find(|i| entries[*i as usize].is_none())
            {
                let mut with_hole = items.clone();
                with_hole[0] = hole;
                if snap.assemble(&with_hole, batch).is_some()
                    || snap
                        .assemble_in(&with_hole, batch, &arena)
                        .is_some()
                {
                    return Err("hole not detected".into());
                }
                if arena.outstanding() != 0 {
                    return Err("refused assembly leaked buffers".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pooled_item_assembly_matches_owned() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n = 1 + rng.below(24) as usize;
        let d = 1 + rng.below(32) as usize;
        let pad = rng.below(8) as usize;
        let seed = rng.next_u64();
        (n, d, pad, seed)
    });
    check(
        "pooled item batches == owned",
        &gen,
        80,
        |&(n, d, pad, seed)| {
            let mut rng = Pcg64::new(seed);
            let feats: Vec<ItemFeatures> = (0..n)
                .map(|i| ItemFeatures {
                    raw: (0..d).map(|_| rng.f32()).collect(),
                    mm: (0..d).map(|_| rng.f32()).collect(),
                    seq_emb: vec![0.0; 4],
                    category: i as u32 % 5,
                })
                .collect();
            let batch = n + pad;
            let arena = ArenaPool::new(4);
            let raw_o = assembly::item_raw_batch(&feats, batch);
            let raw_p = assembly::item_raw_batch_in(&feats, batch, &arena);
            let mm_o = assembly::item_mm_batch(&feats, batch);
            let mm_p = assembly::item_mm_batch_in(&feats, batch, &arena);
            if raw_o != raw_p || mm_o != mm_p {
                return Err("pooled batch != owned batch".into());
            }
            drop((raw_p, mm_p));
            if arena.outstanding() != 0 {
                return Err("pooled batches leaked".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Arena: accounting balances under arbitrary get/drop interleavings and
// the edge cases take the exact-capacity escape hatch.
// ---------------------------------------------------------------------
#[test]
fn prop_arena_accounting_balances() {
    let gen = vec_of(usize_in(0, 9000), 120);
    check("arena books balance", &gen, 60, |lens: &Vec<usize>| {
        let pool = ArenaPool::new(3);
        let mut held = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            let b = pool.get_zeroed(len);
            if len == 0 {
                if b.capacity() != 0 {
                    return Err("len 0 must not land in a class".into());
                }
            } else if b.len() != len {
                return Err(format!("got {} floats for {len}", b.len()));
            }
            if i % 3 == 0 {
                held.push(b);
            } // else: drop immediately
        }
        let live = held
            .iter()
            .filter(|b| b.capacity() > 0)
            .count() as u64;
        if pool.outstanding() != live {
            return Err(format!(
                "outstanding {} != live {live}",
                pool.outstanding()
            ));
        }
        drop(held);
        if pool.outstanding() != 0 {
            return Err("buffers leaked after drop".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// LSH: packed-LUT similarity == unpacked ±1 dot similarity, always.
// ---------------------------------------------------------------------
#[test]
fn prop_packed_similarity_equals_plane_dot() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n_bits = 8 * (1 + rng.below(16) as usize);
        let a: Vec<bool> = (0..n_bits).map(|_| rng.chance(0.5)).collect();
        let b: Vec<bool> = (0..n_bits).map(|_| rng.chance(0.5)).collect();
        (n_bits, a, b)
    });
    check("packed == plane", &gen, 300, |(n_bits, a, b)| {
        let pa = bits::pack_bits(a);
        let pb = bits::pack_bits(b);
        let packed = bits::lsh_similarity_packed(&pa, &pb, *n_bits);
        let mut fa = vec![0.0; *n_bits];
        let mut fb = vec![0.0; *n_bits];
        bits::unpack_to_pm1(&pa, *n_bits, &mut fa);
        bits::unpack_to_pm1(&pb, *n_bits, &mut fb);
        let dot: f32 = fa.iter().zip(&fb).map(|(x, y)| x * y).sum();
        let plane = (1.0 + dot / *n_bits as f32) / 2.0;
        if (packed - plane).abs() > 1e-6 {
            return Err(format!("{packed} != {plane}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Tier histogram: rows are distributions; matches the float binning.
// ---------------------------------------------------------------------
// ---------------------------------------------------------------------
// Durable snapshots (DESIGN.md §16): serialize -> restore is bitwise
// lossless, any corruption is rejected by the checksum, and
// put_if_not_exists races admit exactly one winner.
// ---------------------------------------------------------------------

/// Random-table generator shared by the snapshot properties: dims, a
/// size that often crosses the 512-item chunk boundary, and a seed.
fn snapshot_table_gen() -> Gen<(usize, usize, usize, usize, u64)> {
    Gen::new(|rng: &mut Pcg64| {
        let d = 1 + rng.below(12) as usize;
        let n_bridge = 1 + rng.below(6) as usize;
        let n_bits = 8 * (1 + rng.below(6) as usize);
        let n_items = 1 + rng.below(1400) as usize;
        let seed = rng.next_u64();
        (d, n_bridge, n_bits, n_items, seed)
    })
}

fn random_table(
    d: usize,
    n_bridge: usize,
    n_bits: usize,
    n_items: usize,
    seed: u64,
) -> N2oTable {
    let mut rng = Pcg64::new(seed);
    let pl = n_bits / 8;
    let table = N2oTable::new(n_items, d, n_bridge, n_bits);
    let entries: Vec<Option<N2oEntry>> = (0..n_items)
        .map(|_| {
            rng.chance(0.8).then(|| N2oEntry {
                item_vec: (0..d).map(|_| rng.f32()).collect(),
                bea_w: (0..n_bridge).map(|_| rng.f32()).collect(),
                sign_packed: (0..pl).map(|_| rng.below(256) as u8).collect(),
            })
        })
        .collect();
    table.swap_full(entries, 1 + seed % 9);
    table
}

#[test]
fn prop_snapshot_round_trip_is_bitwise_lossless() {
    check(
        "snapshot round trip",
        &snapshot_table_gen(),
        40,
        |&(d, n_bridge, n_bits, n_items, seed)| {
            let src = random_table(d, n_bridge, n_bits, n_items, seed);
            let ex = src.export();
            let bytes = encode_full(&ex, src.version_hint());
            let full = decode_full(&bytes, "prop")
                .map_err(|e| format!("decode: {e}"))?;
            let dst =
                N2oTable::new(full.n_items, full.d, full.n_bridge, full.n_bits);
            dst.restore(
                full.chunks,
                full.n_items,
                full.version,
                full.version_hint,
            );
            if state_digest(&dst.export()) != state_digest(&ex) {
                return Err("restored digest diverged".into());
            }
            if dst.version() != src.version()
                || dst.version_hint() != src.version_hint()
            {
                return Err("version sequence not resumed".into());
            }
            let (a, b) = (src.snapshot(), dst.snapshot());
            for i in 0..n_items as u32 {
                match (a.get(i), b.get(i)) {
                    (Some(x), Some(y)) => {
                        if x.to_entry() != y.to_entry() {
                            return Err(format!("row {i} diverged"));
                        }
                    }
                    (None, None) => {}
                    _ => return Err(format!("presence mismatch at {i}")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_snapshot_checksum_rejects_any_corruption() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n_items = 1 + rng.below(900) as usize;
        let seed = rng.next_u64();
        let pos_pick = rng.next_u64();
        let mask = 1 + rng.below(255) as u8;
        let truncate = rng.chance(0.5);
        (n_items, seed, pos_pick, mask, truncate)
    });
    check(
        "checksum catches corruption",
        &gen,
        60,
        |&(n_items, seed, pos_pick, mask, truncate)| {
            let src = random_table(3, 2, 16, n_items, seed);
            let bytes = encode_full(&src.export(), src.version_hint());
            let mangled = if truncate {
                bytes[..(pos_pick % bytes.len() as u64) as usize].to_vec()
            } else {
                let mut bad = bytes.clone();
                let at = (pos_pick % bytes.len() as u64) as usize;
                bad[at] ^= mask;
                bad
            };
            match decode_full(&mangled, "prop") {
                Err(_) => Ok(()),
                Ok(_) => Err(format!(
                    "corruption survived (truncate={truncate}, \
                     pos={pos_pick}, mask={mask:#04x})"
                )),
            }
        },
    );
}

#[test]
fn prop_put_if_not_exists_has_exactly_one_winner() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let racers = 2 + rng.below(7) as usize;
        let seed = rng.next_u64();
        (racers, seed)
    });
    check(
        "one create wins",
        &gen,
        20,
        |&(racers, seed)| {
            let dir = std::env::temp_dir().join(format!(
                "aif-propstore-{}-{seed:x}",
                std::process::id()
            ));
            let fs_store = FsStorage::new(&dir)
                .map_err(|e| format!("fs backend: {e}"))?;
            let backends: Vec<std::sync::Arc<dyn Storage>> = vec![
                std::sync::Arc::new(MemStorage::new()),
                std::sync::Arc::new(fs_store),
            ];
            let result = (|| {
                for store in &backends {
                    let barrier = std::sync::Arc::new(
                        std::sync::Barrier::new(racers),
                    );
                    let mut handles = Vec::new();
                    for who in 0..racers {
                        let store = std::sync::Arc::clone(store);
                        let barrier = std::sync::Arc::clone(&barrier);
                        handles.push(std::thread::spawn(move || {
                            barrier.wait();
                            store
                                .put_if_not_exists(
                                    "meta/race",
                                    format!("writer-{who}").as_bytes(),
                                )
                                .map(|won| (who, won))
                        }));
                    }
                    let outcomes: Vec<(usize, bool)> = handles
                        .into_iter()
                        .map(|h| {
                            h.join()
                                .map_err(|_| "racer panicked".to_string())?
                                .map_err(|e| e.to_string())
                        })
                        .collect::<Result<_, String>>()?;
                    let winners: Vec<usize> = outcomes
                        .iter()
                        .filter(|(_, won)| *won)
                        .map(|(who, _)| *who)
                        .collect();
                    if winners.len() != 1 {
                        return Err(format!(
                            "{} winners of {racers} racers",
                            winners.len()
                        ));
                    }
                    let stored = store
                        .get("meta/race")
                        .map_err(|e| e.to_string())?;
                    if stored != format!("writer-{}", winners[0]).into_bytes()
                    {
                        return Err(
                            "stored blob is not the winner's".into()
                        );
                    }
                }
                Ok(())
            })();
            let _ = std::fs::remove_dir_all(&dir);
            result
        },
    );
}

#[test]
fn prop_tier_histogram_is_distribution() {
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n_items = 1 + rng.below(20) as usize;
        let n_seq = 1 + rng.below(200) as usize;
        let bytes: Vec<u8> = (0..(n_items + n_seq) * 8)
            .map(|_| rng.below(256) as u8)
            .collect();
        (n_items, n_seq, bytes)
    });
    check("tier hist rows sum to 1", &gen, 100, |(n_items, n_seq, bytes)| {
        let (items, seq) = bytes.split_at(n_items * 8);
        let hist =
            aif::lsh::tier_histogram(items, *n_items, seq, *n_seq, 64, 8);
        for i in 0..*n_items {
            let s: f32 = hist[i * 8..(i + 1) * 8].iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("row {i} sums to {s}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// HTTP request parser (server::conn): framing is invariant under
// fragmentation, total on garbage, and bounded on unterminated heads —
// DESIGN.md §18.
// ---------------------------------------------------------------------

#[test]
fn prop_http_parser_invariant_under_fragmentation() {
    use aif::server::conn::RequestParser;
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n_reqs = 1 + rng.below(4) as usize;
        let mut stream: Vec<u8> = Vec::new();
        for _ in 0..n_reqs {
            let eol = if rng.chance(0.7) { "\r\n" } else { "\n" };
            let version = if rng.chance(0.25) { "1.0" } else { "1.1" };
            let body_len =
                if rng.chance(0.5) { rng.below(600) as usize } else { 0 };
            let mut head = format!(
                "POST /v1/x?u={} HTTP/{version}{eol}",
                rng.below(1000)
            );
            if rng.chance(0.5) {
                let pad = "p".repeat(rng.below(64) as usize);
                head += &format!("X-Pad: {pad}{eol}");
            }
            if body_len > 0 || rng.chance(0.3) {
                head += &format!("Content-Length: {body_len}{eol}");
            }
            head += eol;
            stream.extend_from_slice(head.as_bytes());
            for _ in 0..body_len {
                stream.push(rng.below(256) as u8);
            }
        }
        (n_reqs, stream, rng.next_u64())
    });
    check(
        "parser framing invariant under fragmentation",
        &gen,
        300,
        |(n_reqs, stream, seed)| {
            // Reference: the whole stream in one push.
            let mut whole = RequestParser::new();
            whole.push(stream);
            let mut reference = Vec::new();
            loop {
                match whole.next() {
                    Ok(Some(r)) => reference.push(r),
                    Ok(None) => break,
                    Err(e) => {
                        return Err(format!(
                            "well-formed stream refused: {} {}",
                            e.status, e.message
                        ))
                    }
                }
            }
            if reference.len() != *n_reqs {
                return Err(format!(
                    "{} requests parsed, {n_reqs} sent",
                    reference.len()
                ));
            }
            // Same stream, random 1..=7-byte fragments.
            let mut rng = Pcg64::new(*seed);
            let mut frag = RequestParser::new();
            let mut out = Vec::new();
            let mut i = 0;
            while i < stream.len() {
                let end = (i + 1 + rng.below(7) as usize).min(stream.len());
                frag.push(&stream[i..end]);
                i = end;
                loop {
                    match frag.next() {
                        Ok(Some(r)) => out.push(r),
                        Ok(None) => break,
                        Err(e) => {
                            return Err(format!(
                                "fragmented refused: {} {}",
                                e.status, e.message
                            ))
                        }
                    }
                }
            }
            if out != reference {
                return Err("fragmented parse diverged".into());
            }
            if frag.buffered() != 0 {
                return Err(format!(
                    "{} bytes left buffered",
                    frag.buffered()
                ));
            }
            if frag.parsed_requests() != *n_reqs as u64 {
                return Err("parsed_requests counter wrong".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_http_parser_never_panics_and_failure_is_terminal() {
    use aif::server::conn::RequestParser;
    let gen = Gen::new(|rng: &mut Pcg64| {
        let n_chunks = 1 + rng.below(12) as usize;
        let mut stream: Vec<u8> = Vec::new();
        for _ in 0..n_chunks {
            match rng.below(6) {
                0 => stream.extend_from_slice(b"GET / HTTP/1.1\r\n\r\n"),
                1 => stream.extend_from_slice(b"POST /x HTTP/9.9\r\n\r\n"),
                2 => stream.extend_from_slice(b"Content-Length: 5\r\n"),
                3 => stream.extend_from_slice(b"\r\n\r\n"),
                4 => stream.extend_from_slice(b"no colon header\r\n"),
                _ => {
                    for _ in 0..rng.below(40) {
                        stream.push(rng.below(256) as u8);
                    }
                }
            }
        }
        (stream, rng.next_u64())
    });
    check(
        "parser total on garbage, failure terminal",
        &gen,
        400,
        |(stream, seed)| {
            let mut rng = Pcg64::new(*seed);
            let mut p = RequestParser::new();
            let mut failed = None;
            let mut i = 0;
            'feed: while i < stream.len() {
                let end = (i + 1 + rng.below(16) as usize).min(stream.len());
                p.push(&stream[i..end]);
                i = end;
                loop {
                    match p.next() {
                        Ok(Some(_)) => {}
                        Ok(None) => break,
                        Err(e) => {
                            if !(400..=599).contains(&e.status) {
                                return Err(format!(
                                    "non-5xx/4xx status {}",
                                    e.status
                                ));
                            }
                            failed = Some(e.status);
                            break 'feed;
                        }
                    }
                }
            }
            if let Some(status) = failed {
                // A failed connection never revives, even on valid bytes.
                p.push(b"GET / HTTP/1.1\r\n\r\n");
                if p.next().is_ok() {
                    return Err(format!("parser revived after a {status}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Overload tiering controller (DESIGN.md §20): the decision core moves
// at most one rung per step, hysteresis makes the loop flap-free, and
// `guaranteed` traffic never observes a degraded tier — under arbitrary
// tick / pin / reload-resize sequences.
// ---------------------------------------------------------------------

fn overload_cfg_gen() -> Gen<(aif::config::OverloadConfig, usize)> {
    Gen::new(|rng: &mut Pcg64| {
        let degrade = 2 + rng.below(62) as usize;
        let recover = rng.below(degrade as u64 - 1) as usize;
        let cfg = aif::config::OverloadConfig {
            enabled: true,
            degrade_queue_depth: degrade,
            recover_queue_depth: recover,
            dwell_ms: rng.below(400),
            ..aif::config::OverloadConfig::default()
        };
        let n_tiers = 1 + rng.below(16) as usize;
        (cfg, n_tiers)
    })
}

#[test]
fn prop_overload_tier_moves_at_most_one_rung_in_signal_direction() {
    use aif::coordinator::overload::{
        overloaded, relaxed, step_tier, LoadSample,
    };
    let gen = Gen::new(|rng: &mut Pcg64| {
        let seed = rng.next_u64();
        let current = rng.below(20) as usize;
        let q = rng.below(128) as usize;
        let since = rng.below(800);
        (seed, current, q, since)
    });
    check(
        "overload step: one rung, right way",
        &gen,
        500,
        |&(seed, current, q, since)| {
            let (cfg, n_tiers) =
                (overload_cfg_gen().make)(&mut Pcg64::new(seed));
            let s = LoadSample {
                queue_depth: q,
                ..LoadSample::default()
            };
            let next = step_tier(&cfg, n_tiers, current, &s, since);
            let cur = current.min(n_tiers - 1);
            if next >= n_tiers {
                return Err(format!("tier {next} outside {n_tiers}-ladder"));
            }
            if next.abs_diff(cur) > 1 {
                return Err(format!("jumped {cur} -> {next}"));
            }
            if since < cfg.dwell_ms && next != cur {
                return Err("moved inside the dwell window".into());
            }
            if next > cur && !overloaded(&cfg, &s) {
                return Err("degraded without an overload signal".into());
            }
            if next < cur && !relaxed(&cfg, &s) {
                return Err("recovered while not relaxed".into());
            }
            // Hysteresis: the two trigger predicates never overlap.
            if overloaded(&cfg, &s) && relaxed(&cfg, &s) {
                return Err("overloaded and relaxed at once".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_overload_hysteresis_band_never_flaps() {
    use aif::coordinator::overload::{step_tier, LoadSample};
    let gen = Gen::new(|rng: &mut Pcg64| {
        let cfg_seed = rng.next_u64();
        let start = rng.below(20) as usize;
        let load_seed = rng.next_u64();
        (cfg_seed, start, load_seed)
    });
    check(
        "hysteresis band holds the tier",
        &gen,
        300,
        |&(cfg_seed, start, load_seed)| {
            let (mut cfg, n_tiers) =
                (overload_cfg_gen().make)(&mut Pcg64::new(cfg_seed));
            cfg.dwell_ms = 0; // the band alone must prevent movement
            if cfg.degrade_queue_depth - cfg.recover_queue_depth < 2 {
                return Ok(()); // empty open band
            }
            // 100 loads oscillating strictly INSIDE the band: with both
            // thresholds uncrossed, the tier must not move once —
            // distinct degrade/recover levels are exactly what kills
            // degrade->recover->degrade flapping.
            let mut rng = Pcg64::new(load_seed);
            let mut tier = start.min(n_tiers - 1);
            let first = tier;
            for _ in 0..100 {
                let span =
                    (cfg.degrade_queue_depth - cfg.recover_queue_depth - 1)
                        as u64;
                let q = cfg.recover_queue_depth
                    + 1
                    + rng.below(span) as usize;
                let s = LoadSample {
                    queue_depth: q,
                    ..LoadSample::default()
                };
                tier = step_tier(&cfg, n_tiers, tier, &s, 1_000);
                if tier != first {
                    return Err(format!(
                        "tier flapped {first} -> {tier} at q={q} inside \
                         ({}, {})",
                        cfg.recover_queue_depth, cfg.degrade_queue_depth
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_guaranteed_never_observes_a_degraded_tier() {
    use aif::config::SlaClass;
    use aif::coordinator::overload::{LoadSample, OverloadStats};
    let gen = Gen::new(|rng: &mut Pcg64| {
        let cfg_seed = rng.next_u64();
        let ops_seed = rng.next_u64();
        (cfg_seed, ops_seed)
    });
    check(
        "guaranteed pinned to tier 0",
        &gen,
        150,
        |&(cfg_seed, ops_seed)| {
            let (mut cfg, n_tiers) =
                (overload_cfg_gen().make)(&mut Pcg64::new(cfg_seed));
            cfg.dwell_ms = 0;
            let st = OverloadStats::new(n_tiers);
            let mut rng = Pcg64::new(ops_seed);
            for _ in 0..200 {
                // Arbitrary interleaving of controller ticks, admin
                // pins/unpins and reload-driven ladder resizes.
                match rng.below(5) {
                    0 | 1 => {
                        let s = LoadSample {
                            queue_depth: rng.below(128) as usize,
                            ..LoadSample::default()
                        };
                        st.tick(&cfg, &s);
                    }
                    2 => st.force_tier(Some(rng.below(20) as usize)),
                    3 => st.force_tier(None),
                    _ => st.set_n_tiers(1 + rng.below(16) as usize),
                }
                // THE invariant: nothing above degrades guaranteed.
                if st.tier_for(SlaClass::Guaranteed) != 0 {
                    return Err("guaranteed saw a degraded tier".into());
                }
                // And every class resolves inside the ladder, with
                // best-effort at least as degraded as degradable.
                let cap = st.n_tiers() - 1;
                let d = st.tier_for(SlaClass::Degradable);
                let b = st.tier_for(SlaClass::BestEffort);
                if d > cap || b > cap {
                    return Err(format!("tier outside ladder ({d}, {b})"));
                }
                if st.forced().is_none() && b < d {
                    return Err(format!(
                        "best-effort ({b}) less degraded than \
                         degradable ({d})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_unterminated_head_431s_before_twice_the_bound() {
    use aif::server::conn::{RequestParser, MAX_HEADER_BYTES};
    let gen = Gen::new(|rng: &mut Pcg64| 1 + rng.below(96) as usize);
    check("unterminated head refused at bound", &gen, 60, |&chunk| {
        let prefix: &[u8] = b"GET / HTTP/1.1\r\nX-Pad: ";
        let mut p = RequestParser::new();
        p.push(prefix);
        let mut pushed = prefix.len();
        let pad = vec![b'a'; chunk];
        loop {
            match p.next() {
                Ok(None) => {}
                Ok(Some(r)) => {
                    return Err(format!("parsed {:?}", r.target))
                }
                Err(e) if e.status == 431 => return Ok(()),
                Err(e) => {
                    return Err(format!("wrong status {}", e.status))
                }
            }
            if pushed > 2 * MAX_HEADER_BYTES {
                return Err("no 431 by twice the bound".into());
            }
            p.push(&pad);
            pushed += chunk;
        }
    });
}
