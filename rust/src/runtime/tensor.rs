//! Host tensor type — the hand-off currency between the coordinator's
//! assembly code and the PJRT runtime.  Everything the serving heads
//! consume is f32 (LSH signatures travel packed-u8 at rest and are unpacked
//! to ±1 planes at assembly; DESIGN.md §7).
//!
//! Data is `Arc`-backed: per-request tensors (seq_emb, seq_sign, …) are
//! shared across all mini-batch RTP calls of the request without copying.
//! Storage comes in two flavors (DESIGN.md §14): plain owned vectors, and
//! **arena-backed** buffers borrowed from a [`crate::cache::ArenaPool`]
//! via [`Tensor::from_pooled`] — when the last clone drops (i.e. when the
//! RTP call retires), the buffer returns to the pool instead of hitting
//! the allocator.  The two flavors are indistinguishable to consumers:
//! same `data()` slice, same equality, same literal conversion.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cache::{ArenaPool, PooledBuf};

#[derive(Debug, Clone)]
enum Storage {
    Owned(Arc<Vec<f32>>),
    Arena(Arc<PooledBuf>),
}

impl Storage {
    #[inline]
    fn as_slice(&self) -> &[f32] {
        match self {
            Storage::Owned(v) => v,
            Storage::Arena(b) => b,
        }
    }
}

/// Dense row-major f32 host tensor with cheap clones.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    data: Storage,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape
            && self.data.as_slice() == other.data.as_slice()
    }
}

impl AsRef<[f32]> for Tensor {
    fn as_ref(&self) -> &[f32] {
        self.data.as_slice()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Storage::Owned(Arc::new(data)),
        }
    }

    /// Wrap an arena buffer without copying; the buffer returns to its
    /// pool when the last clone of this tensor drops.
    pub fn from_pooled(shape: Vec<usize>, buf: PooledBuf) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), buf.len());
        Tensor {
            shape,
            data: Storage::Arena(Arc::new(buf)),
        }
    }

    /// Whether this tensor's storage came from an arena pool (tests pin
    /// the zero-copy path with this).
    pub fn is_pooled(&self) -> bool {
        matches!(self.data, Storage::Arena(_))
    }

    /// A tensor guaranteed to own its storage: arena-backed data is
    /// deep-copied onto the heap, owned data is shared (`Arc` clone).
    /// Long-lived holders (the cross-request user-state cache) go through
    /// this so they can never pin a pooled buffer.
    pub fn detached(&self) -> Tensor {
        match &self.data {
            Storage::Owned(_) => self.clone(),
            Storage::Arena(_) => {
                Tensor::new(self.shape.clone(), self.data().to_vec())
            }
        }
    }

    /// Run `fill` into either an arena-pooled or a fresh buffer of
    /// `shape`'s size and wrap it — THE single pooled-vs-owned dispatch
    /// every assembly path shares, which is what makes the two storages
    /// bitwise-identical by construction.
    pub(crate) fn build_with(
        arena: Option<&Arc<ArenaPool>>,
        shape: Vec<usize>,
        fill: impl FnOnce(&mut Vec<f32>),
    ) -> Tensor {
        let n: usize = shape.iter().product();
        match arena {
            Some(a) => {
                let mut buf = a.get(n);
                fill(&mut buf);
                Tensor::from_pooled(shape, buf)
            }
            None => {
                let mut v = Vec::with_capacity(n);
                fill(&mut v);
                Tensor::new(shape, v)
            }
        }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    pub fn scalar(v: f32) -> Self {
        Tensor::new(vec![], vec![v])
    }

    pub fn data(&self) -> &[f32] {
        self.data.as_slice()
    }

    pub fn len(&self) -> usize {
        self.data.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.as_slice().is_empty()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = *self.shape.last().expect("rank >= 1");
        &self.data.as_slice()[i * w..(i + 1) * w]
    }

    /// Same storage under a new shape (no copy — the data is `Arc`-backed).
    /// The element count must match.
    pub fn reshaped(&self, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(
            shape.iter().product::<usize>(),
            self.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        Tensor {
            shape,
            data: self.data.clone(),
        }
    }

    /// Approximate byte footprint (what the N2O/caching accounting reports).
    pub fn size_bytes(&self) -> usize {
        self.len() * 4 + self.shape.len() * 8
    }

    /// Convert to an XLA literal for execution.  Against the vendored
    /// stub this shares the tensor's `Arc`-backed storage — building the
    /// execution operands copies nothing, and an arena-pooled buffer
    /// stays out until the literal (i.e. the RTP call) drops.  Under the
    /// real `xla_extension` bindings (which copy at this host boundary),
    /// swap the body back to `Literal::vec1(self.data()).reshape(&dims)`.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(match &self.data {
            Storage::Owned(v) => xla::Literal::from_shared(
                dims,
                Arc::clone(v) as xla::SharedF32,
            ),
            Storage::Arena(b) => xla::Literal::from_shared(
                dims,
                Arc::clone(b) as xla::SharedF32,
            ),
        })
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let ty = shape.ty();
        if ty != xla::ElementType::F32 {
            bail!("expected F32 literal, got {ty:?}");
        }
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }

    /// Max |a-b| against another tensor (golden comparisons).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .as_slice()
            .iter()
            .zip(other.data.as_slice().iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::ArenaPool;

    #[test]
    fn rows_and_sizes() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.size_bytes(), 24 + 16);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(vec![2, 2], vec![1.5, -2.0, 0.0, 7.25]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![1., 2.5, 3.]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clone_shares_storage() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = a.clone();
        assert_eq!(a.data().as_ptr(), b.data().as_ptr());
    }

    #[test]
    fn reshaped_shares_storage() {
        let a = Tensor::new(vec![1, 4], vec![1., 2., 3., 4.]);
        let b = a.reshaped(vec![4]);
        assert_eq!(b.shape, vec![4]);
        assert_eq!(a.data().as_ptr(), b.data().as_ptr());
    }

    #[test]
    fn pooled_tensor_equals_owned_and_returns_on_last_drop() {
        let pool = ArenaPool::new(4);
        let mut buf = pool.get(4);
        buf.extend_from_slice(&[1., 2., 3., 4.]);
        let t = Tensor::from_pooled(vec![2, 2], buf);
        assert!(t.is_pooled());
        let owned = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(t, owned, "storage flavor is invisible to equality");
        assert_eq!(t.row(1), &[3., 4.]);
        // Clones + reshapes share the one pooled buffer.
        let c = t.clone();
        let r = t.reshaped(vec![4]);
        assert!(r.is_pooled());
        assert_eq!(
            pool.outstanding(),
            1,
            "clones do not multiply the pooled buffer"
        );
        drop(t);
        drop(c);
        assert_eq!(pool.outstanding(), 1, "still live via the reshape");
        drop(r);
        assert_eq!(pool.outstanding(), 0, "last drop returns the buffer");
    }

    #[test]
    fn pooled_literal_round_trip() {
        let pool = ArenaPool::new(4);
        let mut buf = pool.get(3);
        buf.extend_from_slice(&[1.5, -2.0, 7.25]);
        let t = Tensor::from_pooled(vec![3], buf);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
