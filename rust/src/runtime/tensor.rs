//! Host tensor type — the hand-off currency between the coordinator's
//! assembly code and the PJRT runtime.  Everything the serving heads
//! consume is f32 (LSH signatures travel packed-u8 at rest and are unpacked
//! to ±1 planes at assembly; DESIGN.md §7).
//!
//! Data is `Arc`-backed: per-request tensors (seq_emb, seq_sign, …) are
//! shared across all mini-batch RTP calls of the request without copying —
//! one of the allocation savings the Arena pool + two-phase design buys.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Dense row-major f32 host tensor with cheap clones.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Arc::new(data),
        }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    pub fn scalar(v: f32) -> Self {
        Tensor::new(vec![], vec![v])
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = *self.shape.last().expect("rank >= 1");
        &self.data[i * w..(i + 1) * w]
    }

    /// Same storage under a new shape (no copy — the data is `Arc`-backed).
    /// The element count must match.
    pub fn reshaped(&self, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        Tensor {
            shape,
            data: Arc::clone(&self.data),
        }
    }

    /// Approximate byte footprint (what the N2O/caching accounting reports).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4 + self.shape.len() * 8
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&dims)?)
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let ty = shape.ty();
        if ty != xla::ElementType::F32 {
            bail!("expected F32 literal, got {ty:?}");
        }
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }

    /// Max |a-b| against another tensor (golden comparisons).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_sizes() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.size_bytes(), 24 + 16);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(vec![2, 2], vec![1.5, -2.0, 0.0, 7.25]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![1., 2.5, 3.]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clone_shares_storage() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = a.clone();
        assert_eq!(a.data().as_ptr(), b.data().as_ptr());
    }

    #[test]
    fn reshaped_shares_storage() {
        let a = Tensor::new(vec![1, 4], vec![1., 2., 3., 4.]);
        let b = a.reshaped(vec![4]);
        assert_eq!(b.shape, vec![4]);
        assert_eq!(a.data().as_ptr(), b.data().as_ptr());
    }
}
