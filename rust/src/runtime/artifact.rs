//! AOT artifact manifest: the contract between `python/compile/aot.py` and
//! the rust serving stack.  Parses `artifacts/manifest.json` (dims, artifact
//! I/O signatures, world-table schemas, serving-variant registry, oracle
//! parameters, goldens) and loads the raw binary world tables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// One named tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One HLO artifact (tower or serving head).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Serving-variant registry entry (mirrors `python/compile/variants.py`).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub artifact: String,
    pub user: String,     // "cheap" | "attn_inline" | "async"
    pub item: String,     // "inline" | "nearline"
    pub bea: String,      // "none" | "bridge" | "full"
    pub din_sim: String,  // "none" | "lsh" | "mm" | "id"
    pub tier_sim: String,
    pub sim_cross: bool,
    pub sim_budget: f64,
}

impl VariantSpec {
    pub fn has_long(&self) -> bool {
        self.din_sim != "none" || self.tier_sim != "none"
    }
    pub fn needs_lsh(&self) -> bool {
        self.din_sim == "lsh" || self.tier_sim == "lsh"
    }
    pub fn needs_mm(&self) -> bool {
        self.din_sim == "mm" || self.tier_sim == "mm"
    }
    /// SimTier arrives precomputed from the serving engine (uint8 popcount
    /// path) when both long-term heads run on LSH similarity.
    pub fn tiers_precomputed(&self) -> bool {
        self.din_sim == "lsh" && self.tier_sim == "lsh"
    }
}

/// Oracle click-model parameters (the synthetic ground truth).
#[derive(Debug, Clone)]
pub struct Oracle {
    pub click_w: [f32; 3],
    pub click_b: f32,
    pub d_latent: usize,
}

/// Raw world table (f32 / u32 / u8) loaded from `tables/*.bin`.
#[derive(Debug, Clone)]
pub enum Table {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl Table {
    pub fn shape(&self) -> &[usize] {
        match self {
            Table::F32 { shape, .. }
            | Table::U32 { shape, .. }
            | Table::U8 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Table::F32 { data, .. } => data,
            _ => panic!("table is not f32"),
        }
    }

    pub fn as_u32(&self) -> &[u32] {
        match self {
            Table::U32 { data, .. } => data,
            _ => panic!("table is not u32"),
        }
    }

    pub fn as_u8(&self) -> &[u8] {
        match self {
            Table::U8 { data, .. } => data,
            _ => panic!("table is not u8"),
        }
    }

    /// Row `i` of a rank-2 f32 table.
    pub fn f32_row(&self, i: usize) -> &[f32] {
        let w = self.shape()[1];
        &self.as_f32()[i * w..(i + 1) * w]
    }

    pub fn u32_row(&self, i: usize) -> &[u32] {
        let w = self.shape()[1];
        &self.as_u32()[i * w..(i + 1) * w]
    }

    pub fn u8_row(&self, i: usize) -> &[u8] {
        let w = self.shape()[1];
        &self.as_u8()[i * w..(i + 1) * w]
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Table::F32 { data, .. } => data.len() * 4,
            Table::U32 { data, .. } => data.len() * 4,
            Table::U8 { data, .. } => data.len(),
        }
    }
}

/// Parsed manifest + lazily loaded tables.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: HashMap<String, usize>,
    pub batch: usize,
    pub l_long: usize,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub variants: HashMap<String, VariantSpec>,
    pub oracle: Oracle,
    pub raw: Value,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let raw = Value::parse(&text).context("parsing manifest.json")?;

        let mut dims = HashMap::new();
        for (k, v) in raw.req("dims").as_obj().unwrap().iter() {
            if let Some(n) = v.as_f64() {
                dims.insert(k.to_string(), n as usize);
            }
        }

        let mut artifacts = HashMap::new();
        for (name, spec) in raw.req("artifacts").as_obj().unwrap().iter() {
            let sig = |key: &str| -> Vec<TensorSig> {
                spec.req(key)
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|t| TensorSig {
                        name: t.req("name").as_str().unwrap().to_string(),
                        shape: t
                            .req("shape")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|d| d.as_usize().unwrap())
                            .collect(),
                    })
                    .collect()
            };
            artifacts.insert(
                name.to_string(),
                ArtifactSpec {
                    name: name.to_string(),
                    file: dir.join(spec.req("file").as_str().unwrap()),
                    inputs: sig("inputs"),
                    outputs: sig("outputs"),
                },
            );
        }

        let mut variants = HashMap::new();
        for (name, v) in raw.req("variants").as_obj().unwrap().iter() {
            variants.insert(
                name.to_string(),
                VariantSpec {
                    name: name.to_string(),
                    artifact: v.req("artifact").as_str().unwrap().into(),
                    user: v.req("user").as_str().unwrap().into(),
                    item: v.req("item").as_str().unwrap().into(),
                    bea: v.req("bea").as_str().unwrap().into(),
                    din_sim: v.req("din_sim").as_str().unwrap().into(),
                    tier_sim: v.req("tier_sim").as_str().unwrap().into(),
                    sim_cross: v.req("sim_cross").as_bool().unwrap(),
                    sim_budget: v.req("sim_budget").as_f64().unwrap(),
                },
            );
        }

        let o = raw.req("oracle");
        let w = o.req("click_w").as_arr().unwrap();
        let oracle = Oracle {
            click_w: [
                w[0].as_f64().unwrap() as f32,
                w[1].as_f64().unwrap() as f32,
                w[2].as_f64().unwrap() as f32,
            ],
            click_b: o.req("click_b").as_f64().unwrap() as f32,
            d_latent: o.req("d_latent").as_usize().unwrap(),
        };

        Ok(Manifest {
            batch: raw.req("batch").as_usize().unwrap(),
            l_long: raw.req("l_long").as_usize().unwrap(),
            dir,
            dims,
            artifacts,
            variants,
            oracle,
            raw,
        })
    }

    pub fn dim(&self, name: &str) -> usize {
        *self
            .dims
            .get(name)
            .unwrap_or_else(|| panic!("missing dim {name}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {name:?}"))
    }

    /// Load one world table from `tables/<name>.bin`.
    pub fn load_table(&self, name: &str) -> Result<Table> {
        let entry = self
            .raw
            .req("tables")
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown table {name:?}"))?;
        let file = self.dir.join(entry.req("file").as_str().unwrap());
        let shape: Vec<usize> = entry
            .req("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let n: usize = shape.iter().product();
        let bytes = std::fs::read(&file)
            .with_context(|| format!("reading table {file:?}"))?;
        let dtype = entry.req("dtype").as_str().unwrap();
        let table = match dtype {
            "f32" => {
                if bytes.len() != n * 4 {
                    bail!("table {name}: {} bytes, expected {}", bytes.len(), n * 4);
                }
                Table::F32 {
                    shape,
                    data: bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                }
            }
            "u32" => Table::U32 {
                shape,
                data: bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            "u8" => Table::U8 { shape, data: bytes },
            other => bail!("unsupported table dtype {other}"),
        };
        Ok(table)
    }

    /// Load a golden fixture tensor from `goldens/`.
    pub fn load_golden(&self, name: &str) -> Result<crate::runtime::Tensor> {
        let entry = self
            .raw
            .req("goldens")
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown golden {name:?}"))?;
        let file = self.dir.join(entry.req("file").as_str().unwrap());
        let shape: Vec<usize> = entry
            .req("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let bytes = std::fs::read(&file)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        anyhow::ensure!(data.len() == shape.iter().product::<usize>());
        Ok(crate::runtime::Tensor::new(shape, data))
    }

    /// Golden scalar (e.g. the fixture user id).
    pub fn golden_value(&self, name: &str) -> Result<usize> {
        Ok(self
            .raw
            .req("goldens")
            .get(name)
            .and_then(|v| v.get("value"))
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing golden value {name}"))?)
    }

    pub fn golden_values(&self, name: &str) -> Result<Vec<usize>> {
        Ok(self
            .raw
            .req("goldens")
            .get(name)
            .and_then(|v| v.get("values"))
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing golden values {name}"))?
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect())
    }
}
