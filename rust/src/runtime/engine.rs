//! PJRT execution engine: HLO text -> compile -> execute.
//!
//! One `Engine` owns one PJRT client plus the compiled executables it was
//! asked to load.  The `xla` crate's wrapper types are `!Send` (raw C
//! pointers), so an `Engine` lives and dies on one thread — `RtpPool`
//! (pool.rs) gives the coordinator a `Send` fleet interface on top.
//!
//! Interchange is HLO **text**: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifact::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Per-thread PJRT client + compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, LoadedArtifact>,
}

struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl Engine {
    /// CPU PJRT client with no artifacts loaded.
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact from the manifest (idempotent).
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().unwrap(),
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::debug!("compiled {name} in {:?}", t0.elapsed());
        self.executables
            .insert(name.to_string(), LoadedArtifact { exe, spec });
        Ok(())
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact. Inputs must match the manifest signature order;
    /// outputs come back in manifest output order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let loaded = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not loaded"))?;
        // Input validation: catching shape bugs here beats an opaque XLA
        // error inside the C library.
        anyhow::ensure!(
            inputs.len() == loaded.spec.inputs.len(),
            "{name}: got {} inputs, expected {}",
            inputs.len(),
            loaded.spec.inputs.len()
        );
        for (t, sig) in inputs.iter().zip(&loaded.spec.inputs) {
            anyhow::ensure!(
                t.shape == sig.shape,
                "{name}: input {:?} shape {:?} != manifest {:?}",
                sig.name,
                t.shape,
                sig.shape
            );
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = loaded.exe.execute::<xla::Literal>(&literals)?;
        // Lowered with return_tuple=True: single tuple output.
        let mut tuple = result[0][0].to_literal_sync()?;
        let elems = tuple.decompose_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for lit in &elems {
            out.push(Tensor::from_literal(lit)?);
        }
        anyhow::ensure!(
            out.len() == loaded.spec.outputs.len(),
            "{name}: got {} outputs, expected {}",
            out.len(),
            loaded.spec.outputs.len()
        );
        Ok(out)
    }

    /// Convenience: execute and return the single output.
    pub fn execute1(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let mut out = self.execute(name, inputs)?;
        anyhow::ensure!(out.len() == 1, "{name}: expected 1 output");
        Ok(out.pop().unwrap())
    }
}
