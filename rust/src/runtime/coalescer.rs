//! Cross-request dynamic micro-batching between the Merger and the RTP
//! fleet.
//!
//! The pre-rank phase splits each candidate set into mini-batches "for
//! separate and parallel model inference" (paper §1), but one request's
//! final partial batch still pays a full padded head execution.  Under
//! concurrent traffic the fleet therefore runs many small, padded
//! executions instead of a few full ones.  The [`BatchCoalescer`] fixes
//! that at the dispatch layer:
//!
//! * per-request head-execution **jobs** ([`HeadJob`]) queue per artifact;
//! * jobs targeting the same artifact **coalesce across requests** into
//!   one execution of the multi-user (`*_mu`) head flavor, packing up to
//!   `max_rows` real rows from up to `max_slots` requests (the `_mu`
//!   artifact gathers each row's user context by the `row_user` operand);
//! * a queue **flushes** when full or when its oldest job has waited
//!   `window`; a job whose deadline budget is nearly spent **bypasses**
//!   the window and forces an immediate flush;
//! * the merged score tensor is **scattered** back to per-request reply
//!   channels by row range — `coordinator::batcher::pack_jobs` is the
//!   single source of truth for the gather/scatter offsets (property-
//!   tested in `rust/tests/prop_invariants.rs`);
//! * **shutdown drains**: dropping the coalescer executes everything
//!   still queued before joining, so no reply channel is ever dropped
//!   (pinned by `rust/tests/coalescer_stress.rs`).
//!
//! The coalescer is generic over a [`HeadExecutor`] (implemented by
//! [`super::RtpPool`]) so the concurrency tests drive it with a
//! deterministic in-process executor and no artifacts.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::tensor::Tensor;
use crate::cache::ArenaPool;
use crate::coordinator::batcher::pack_jobs;
use crate::metrics::CoalesceStats;
use crate::util::threadpool::ThreadPool;

/// Something that can run a head artifact asynchronously.  `RtpPool`
/// implements this; tests substitute a deterministic in-process executor.
pub trait HeadExecutor: Send + Sync + 'static {
    fn execute_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>>;
}

/// One per-request head-execution job.
pub struct HeadJob {
    /// The coalesced (`*_mu`) artifact this job targets.
    pub artifact: String,
    /// Real (unpadded) row count; must be `<= max_rows`.
    pub rows: usize,
    /// Row-aligned inputs, `[>= rows, ...]` each — only the first `rows`
    /// first-axis rows are read, so padded tensors are fine.
    pub row_inputs: Vec<Tensor>,
    /// Request-level inputs in slot shape (no leading slot axis): the
    /// merged execution stacks one slot per job.
    pub user_inputs: Vec<Tensor>,
    /// Absolute deadline; a job submitted with less than `bypass_margin`
    /// remaining skips the coalescing window.
    pub deadline: Option<Instant>,
    pub reply: Sender<Result<JobScores>>,
}

/// What a job gets back.
#[derive(Debug, Clone)]
pub struct JobScores {
    /// Exactly `rows` scores, in the job's row order.
    pub scores: Vec<f32>,
    /// Queue dwell between submit and dispatch.
    pub queue_wait: Duration,
    /// Real rows in the merged execution that served this job.
    pub coalesced_rows: usize,
    /// Jobs merged into that execution (1 = no coalescing happened).
    pub coalesced_jobs: usize,
}

#[derive(Debug, Clone)]
pub struct CoalescerConfig {
    /// Artifact batch: every merged execution pads to this many rows.
    pub exec_rows: usize,
    /// Real-row pack cap per execution (`<= exec_rows`).
    pub max_rows: usize,
    /// User slots per execution (the `_mu` artifact's `U`).
    pub max_slots: usize,
    /// Max queue dwell before a forced flush.
    pub window: Duration,
    /// Jobs with less remaining deadline budget than this skip the wait.
    pub bypass_margin: Duration,
}

enum Msg {
    Job(HeadJob),
    Shutdown,
}

/// The scheduler: one dispatch thread owning per-artifact queues, plus a
/// small scatter pool that waits on RTP replies and fans scores back out.
pub struct BatchCoalescer {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    cfg: CoalescerConfig,
}

impl BatchCoalescer {
    pub fn new(
        executor: Arc<dyn HeadExecutor>,
        cfg: CoalescerConfig,
        stats: Arc<CoalesceStats>,
    ) -> BatchCoalescer {
        Self::with_arena(executor, cfg, stats, None)
    }

    /// Like [`Self::new`], but merged `_mu` executions assemble into
    /// arena-pooled buffers (the zero-copy path); the buffers return to
    /// the pool when the merged RTP call retires.
    pub fn with_arena(
        executor: Arc<dyn HeadExecutor>,
        cfg: CoalescerConfig,
        stats: Arc<CoalesceStats>,
        arena: Option<Arc<ArenaPool>>,
    ) -> BatchCoalescer {
        assert!(cfg.max_rows >= 1 && cfg.max_rows <= cfg.exec_rows);
        assert!(cfg.max_slots >= 1);
        let (tx, rx) = channel::<Msg>();
        let cfg2 = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("aif-coalescer".into())
            .spawn(move || {
                Dispatcher {
                    cfg: cfg2,
                    executor,
                    stats,
                    arena,
                    scatter: ThreadPool::new(4),
                    queues: HashMap::new(),
                }
                .run(rx)
            })
            .expect("spawn coalescer");
        BatchCoalescer {
            tx,
            handle: Some(handle),
            cfg,
        }
    }

    pub fn config(&self) -> &CoalescerConfig {
        &self.cfg
    }

    /// Enqueue a job.  Replies always arrive — immediately with an error
    /// for malformed jobs or a dead scheduler, via the scatter path
    /// otherwise.
    pub fn submit(&self, job: HeadJob) {
        if job.rows == 0 {
            let _ = job.reply.send(Ok(JobScores {
                scores: Vec::new(),
                queue_wait: Duration::ZERO,
                coalesced_rows: 0,
                coalesced_jobs: 0,
            }));
            return;
        }
        if job.rows > self.cfg.max_rows {
            let _ = job.reply.send(Err(anyhow!(
                "job of {} rows exceeds max_coalesced_batch {}",
                job.rows,
                self.cfg.max_rows
            )));
            return;
        }
        if let Err(std::sync::mpsc::SendError(Msg::Job(job))) =
            self.tx.send(Msg::Job(job))
        {
            let _ = job
                .reply
                .send(Err(anyhow!("coalescer dispatch thread is gone")));
        }
    }
}

impl Drop for BatchCoalescer {
    /// Drain, then join: every queued job executes (or errors) before the
    /// coalescer is gone.
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Pending {
    job: HeadJob,
    enqueued: Instant,
}

struct Dispatcher {
    cfg: CoalescerConfig,
    executor: Arc<dyn HeadExecutor>,
    stats: Arc<CoalesceStats>,
    /// Merged-input assembly buffers come from here when set.
    arena: Option<Arc<ArenaPool>>,
    scatter: ThreadPool,
    queues: HashMap<String, VecDeque<Pending>>,
}

impl Dispatcher {
    fn run(mut self, rx: Receiver<Msg>) {
        loop {
            let msg = match self.next_flush_at() {
                Some(at) => {
                    let now = Instant::now();
                    if at <= now {
                        self.flush_expired(now);
                        continue;
                    }
                    match rx.recv_timeout(at - now) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => {
                            self.flush_expired(Instant::now());
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => Msg::Shutdown,
                    }
                }
                None => match rx.recv() {
                    Ok(m) => m,
                    Err(_) => Msg::Shutdown,
                },
            };
            match msg {
                Msg::Job(job) => {
                    let bypass = job.deadline.is_some_and(|d| {
                        d.saturating_duration_since(Instant::now())
                            <= self.cfg.bypass_margin
                    });
                    let artifact = job.artifact.clone();
                    self.queues.entry(artifact.clone()).or_default().push_back(
                        Pending {
                            job,
                            enqueued: Instant::now(),
                        },
                    );
                    if bypass {
                        self.stats
                            .bypass_jobs
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    // Full executions always leave now; a bypass flushes
                    // the whole queue (riders merge in for free).
                    self.flush(&artifact, bypass);
                }
                Msg::Shutdown => break,
            }
        }
        // Drain everything still queued so no reply channel is dropped.
        let artifacts: Vec<String> = self.queues.keys().cloned().collect();
        for a in artifacts {
            self.flush(&a, true);
        }
        // `self.scatter` drops here, joining in-flight scatter tasks.
    }

    /// Earliest `enqueued + window` over all queued jobs.
    fn next_flush_at(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front().map(|p| p.enqueued + self.cfg.window))
            .min()
    }

    fn flush_expired(&mut self, now: Instant) {
        let expired: Vec<String> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                q.front()
                    .is_some_and(|p| now >= p.enqueued + self.cfg.window)
            })
            .map(|(a, _)| a.clone())
            .collect();
        for a in expired {
            self.flush(&a, true);
        }
    }

    /// Emit merged executions for one artifact queue.  Without `force`,
    /// only full packs (closed by the row or slot cap) leave; the
    /// remainder keeps waiting on its window.  With `force`, the queue
    /// drains completely.
    fn flush(&mut self, artifact: &str, force: bool) {
        loop {
            let Some(queue) = self.queues.get_mut(artifact) else {
                return;
            };
            if queue.is_empty() {
                self.queues.remove(artifact);
                return;
            }
            let rows: Vec<usize> = queue.iter().map(|p| p.job.rows).collect();
            let plan =
                pack_jobs(&rows, self.cfg.max_rows, self.cfg.max_slots);
            let first = &plan[0];
            let first_rows: usize = first.iter().map(|s| s.rows).sum();
            let full = plan.len() > 1
                || first_rows == self.cfg.max_rows
                || first.len() == self.cfg.max_slots;
            if !force && !full {
                return;
            }
            let pack: Vec<Pending> =
                queue.drain(..first.len()).collect();
            self.execute_pack(artifact, pack);
        }
    }

    /// Merge one pack into a single execution and hand scatter-back to
    /// the scatter pool.
    fn execute_pack(&self, artifact: &str, pack: Vec<Pending>) {
        let now = Instant::now();
        let rows_total: usize = pack.iter().map(|p| p.job.rows).sum();
        let waits: Vec<Duration> = pack
            .iter()
            .map(|p| now.saturating_duration_since(p.enqueued))
            .collect();
        for w in &waits {
            self.stats.queue_wait.record(*w);
        }
        self.stats.record_execution(
            pack.len() as u64,
            rows_total as u64,
            self.cfg.exec_rows as u64,
        );
        let inputs = match merge_inputs(
            &pack,
            self.cfg.exec_rows,
            self.cfg.max_slots,
            self.arena.as_ref(),
        ) {
            Ok(t) => t,
            Err(e) => {
                let msg = format!("{e:#}");
                for p in pack {
                    let _ = p.job.reply.send(Err(anyhow!("{msg}")));
                }
                return;
            }
        };
        let rx = self.executor.execute_async(artifact, inputs);
        let n_jobs = pack.len();
        self.scatter.spawn(move || {
            let result = rx
                .recv()
                .map_err(|_| anyhow!("RTP worker dropped the reply"))
                .and_then(|r| r);
            match result {
                Ok(outs) => scatter_back(pack, waits, outs, rows_total, n_jobs),
                Err(e) => {
                    let msg = format!("coalesced execution failed: {e:#}");
                    for p in pack {
                        let _ = p.job.reply.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        });
    }
}

/// Slice the merged score tensor back out by row range.
fn scatter_back(
    pack: Vec<Pending>,
    waits: Vec<Duration>,
    outs: Vec<Tensor>,
    rows_total: usize,
    n_jobs: usize,
) {
    let scores = match outs.first() {
        Some(t) if t.len() >= rows_total => t,
        Some(t) => {
            let msg = format!(
                "merged execution returned {} scores for {rows_total} rows",
                t.len()
            );
            for p in pack {
                let _ = p.job.reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
        None => {
            for p in pack {
                let _ = p
                    .job
                    .reply
                    .send(Err(anyhow!("merged execution returned no output")));
            }
            return;
        }
    };
    let data = scores.data();
    let mut offset = 0;
    for (p, wait) in pack.into_iter().zip(waits) {
        let rows = p.job.rows;
        let _ = p.job.reply.send(Ok(JobScores {
            scores: data[offset..offset + rows].to_vec(),
            queue_wait: wait,
            coalesced_rows: rows_total,
            coalesced_jobs: n_jobs,
        }));
        offset += rows;
    }
}

/// Build the merged `_mu` input list: per-request tensors stacked into
/// user slots (padded to the artifact's fixed `max_slots` by repeating
/// the last job's slot — compiled artifacts are static-shaped), row-
/// aligned tensors concatenated by real rows (padded to `exec_rows` by
/// repeating the last real row), plus the row→slot index operand last.
/// With `arena` set, every merged operand assembles into a pooled buffer
/// that returns to the pool when the merged RTP call retires.
fn merge_inputs(
    pack: &[Pending],
    exec_rows: usize,
    max_slots: usize,
    arena: Option<&Arc<ArenaPool>>,
) -> Result<Vec<Tensor>> {
    let first = &pack[0].job;
    let n_user = first.user_inputs.len();
    let n_row = first.row_inputs.len();
    let n_slots = pack.len();
    anyhow::ensure!(n_slots <= max_slots, "pack exceeds max_slots");

    // ---- validation pass (before any buffer is taken) -------------------
    for p in pack.iter().skip(1) {
        anyhow::ensure!(
            p.job.user_inputs.len() == n_user
                && p.job.row_inputs.len() == n_row,
            "jobs for one artifact disagree on input arity"
        );
    }
    for i in 0..n_user {
        let slot_shape = &first.user_inputs[i].shape;
        for p in pack {
            anyhow::ensure!(
                &p.job.user_inputs[i].shape == slot_shape,
                "user input {i}: slot shape {:?} != {:?}",
                p.job.user_inputs[i].shape,
                slot_shape
            );
        }
    }
    let mut rows_total = 0usize;
    for p in pack {
        rows_total += p.job.rows;
    }
    anyhow::ensure!(rows_total <= exec_rows, "pack exceeds exec_rows");
    for i in 0..n_row {
        let t0 = &first.row_inputs[i];
        anyhow::ensure!(
            !t0.shape.is_empty() && t0.shape[0] >= first.rows,
            "row input {i}: shape {:?} has fewer rows than the job",
            t0.shape
        );
        for p in pack {
            let t = &p.job.row_inputs[i];
            anyhow::ensure!(
                t.shape[1..] == t0.shape[1..] && t.shape[0] >= p.job.rows,
                "row input {i}: shape {:?} incompatible with {:?}",
                t.shape,
                t0.shape
            );
        }
    }

    // ---- fill pass (infallible) -----------------------------------------
    let mut inputs = Vec::with_capacity(n_user + n_row + 1);

    // User slots: [max_slots, slot shape...]; unused slots repeat the
    // last job's slot (padding rows' row_user points there too).
    for i in 0..n_user {
        let slot_shape = first.user_inputs[i].shape.clone();
        let slot_len: usize = slot_shape.iter().product();
        let mut shape = vec![max_slots];
        shape.extend_from_slice(&slot_shape);
        inputs.push(Tensor::build_with(arena, shape, |data| {
            for p in pack {
                data.extend_from_slice(p.job.user_inputs[i].data());
            }
            let last = (n_slots - 1) * slot_len;
            for _ in n_slots..max_slots {
                data.extend_from_within(last..last + slot_len);
            }
        }));
    }

    // Row-aligned inputs: the first `rows` rows of each job, padded to
    // `exec_rows` with the last real row.
    for i in 0..n_row {
        let t0 = &first.row_inputs[i];
        let width: usize = t0.shape[1..].iter().product::<usize>().max(1);
        let mut shape = vec![exec_rows];
        shape.extend_from_slice(&t0.shape[1..]);
        inputs.push(Tensor::build_with(arena, shape, |data| {
            for p in pack {
                data.extend_from_slice(
                    &p.job.row_inputs[i].data()[..p.job.rows * width],
                );
            }
            let last = (rows_total - 1) * width;
            for _ in rows_total..exec_rows {
                data.extend_from_within(last..last + width);
            }
        }));
    }

    // row_user: slot index per row; padding rows point at the last slot.
    inputs.push(Tensor::build_with(arena, vec![exec_rows], |row_user| {
        for (slot, p) in pack.iter().enumerate() {
            row_user
                .extend(std::iter::repeat(slot as f32).take(p.job.rows));
        }
        while row_user.len() < exec_rows {
            row_user.push((n_slots - 1) as f32);
        }
    }));
    Ok(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(
        artifact: &str,
        user_val: f32,
        rows: &[f32],
        deadline: Option<Instant>,
    ) -> (HeadJob, Receiver<Result<JobScores>>) {
        let (tx, rx) = channel();
        (
            HeadJob {
                artifact: artifact.into(),
                rows: rows.len(),
                row_inputs: vec![Tensor::new(
                    vec![rows.len(), 1],
                    rows.to_vec(),
                )],
                user_inputs: vec![Tensor::new(vec![1], vec![user_val])],
                deadline,
                reply: tx,
            },
            rx,
        )
    }

    /// Deterministic mu-gather executor: score[r] =
    /// user[row_user[r]] * 1000 + row_val[r].
    struct GatherExec;

    impl HeadExecutor for GatherExec {
        fn execute_async(
            &self,
            _artifact: &str,
            inputs: Vec<Tensor>,
        ) -> Receiver<Result<Vec<Tensor>>> {
            let (tx, rx) = channel();
            let users = inputs[0].data();
            let rows = inputs[1].data();
            let idx = inputs[2].data();
            let scores: Vec<f32> = rows
                .iter()
                .zip(idx.iter())
                .map(|(&v, &s)| users[s as usize] * 1000.0 + v)
                .collect();
            let n = scores.len();
            let _ = tx.send(Ok(vec![Tensor::new(vec![n], scores)]));
            rx
        }
    }

    fn coalescer(window_ms: u64, max_rows: usize, slots: usize) -> (
        BatchCoalescer,
        Arc<CoalesceStats>,
    ) {
        let stats = Arc::new(CoalesceStats::default());
        let c = BatchCoalescer::new(
            Arc::new(GatherExec),
            CoalescerConfig {
                exec_rows: max_rows,
                max_rows,
                max_slots: slots,
                window: Duration::from_millis(window_ms),
                bypass_margin: Duration::from_millis(2),
            },
            Arc::clone(&stats),
        );
        (c, stats)
    }

    #[test]
    fn two_jobs_coalesce_within_the_window() {
        let (c, stats) = coalescer(400, 8, 4);
        let (j1, r1) = job("a", 1.0, &[1.0, 2.0], None);
        let (j2, r2) = job("a", 2.0, &[5.0], None);
        c.submit(j1);
        c.submit(j2);
        let s1 = r1.recv().unwrap().unwrap();
        let s2 = r2.recv().unwrap().unwrap();
        assert_eq!(s1.scores, vec![1001.0, 1002.0]);
        assert_eq!(s2.scores, vec![2005.0]);
        assert_eq!(s1.coalesced_jobs, 2, "merged into one execution");
        assert_eq!(s1.coalesced_rows, 3);
        assert_eq!(
            stats
                .executions
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        drop(c);
    }

    #[test]
    fn full_pack_flushes_before_the_window() {
        let (c, _) = coalescer(60_000, 3, 4);
        let (j1, r1) = job("a", 1.0, &[1.0, 2.0], None);
        let (j2, r2) = job("a", 2.0, &[5.0], None);
        let t0 = Instant::now();
        c.submit(j1);
        c.submit(j2);
        assert_eq!(r1.recv().unwrap().unwrap().scores, vec![1001.0, 1002.0]);
        assert_eq!(r2.recv().unwrap().unwrap().scores, vec![2005.0]);
        assert!(t0.elapsed() < Duration::from_secs(30), "no window wait");
    }

    #[test]
    fn deadline_bypass_skips_the_window() {
        let (c, stats) = coalescer(60_000, 8, 4);
        let t0 = Instant::now();
        let (j, r) =
            job("a", 3.0, &[7.0], Some(Instant::now()));
        c.submit(j);
        let s = r.recv().unwrap().unwrap();
        assert_eq!(s.scores, vec![3007.0]);
        assert!(t0.elapsed() < Duration::from_secs(30));
        assert_eq!(
            stats
                .bypass_jobs
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn artifacts_never_mix() {
        let (c, stats) = coalescer(100, 8, 4);
        let (ja, ra) = job("a", 1.0, &[1.0], None);
        let (jb, rb) = job("b", 2.0, &[1.0], None);
        c.submit(ja);
        c.submit(jb);
        assert_eq!(ra.recv().unwrap().unwrap().coalesced_jobs, 1);
        assert_eq!(rb.recv().unwrap().unwrap().coalesced_jobs, 1);
        assert_eq!(
            stats
                .executions
                .load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    /// Compiled artifacts are static-shaped: every merged execution must
    /// arrive padded to exactly [max_slots, ...] user slots and
    /// [exec_rows, ...] rows, regardless of how many jobs coalesced —
    /// `Engine::execute` hard-rejects anything else.
    struct StaticShapeExec {
        exec_rows: usize,
        slots: usize,
    }

    impl HeadExecutor for StaticShapeExec {
        fn execute_async(
            &self,
            _artifact: &str,
            inputs: Vec<Tensor>,
        ) -> Receiver<Result<Vec<Tensor>>> {
            let (tx, rx) = channel();
            assert_eq!(inputs[0].shape, vec![self.slots, 1], "user slots");
            assert_eq!(inputs[1].shape, vec![self.exec_rows, 1], "rows");
            assert_eq!(inputs[2].shape, vec![self.exec_rows], "row_user");
            let idx = inputs[2].data();
            assert!(
                idx.iter().all(|&s| (s as usize) < self.slots),
                "row_user points inside the slot range"
            );
            let users = inputs[0].data();
            let rows = inputs[1].data();
            let scores: Vec<f32> = rows
                .iter()
                .zip(idx.iter())
                .map(|(&v, &s)| users[s as usize] * 1000.0 + v)
                .collect();
            let n = scores.len();
            let _ = tx.send(Ok(vec![Tensor::new(vec![n], scores)]));
            rx
        }
    }

    #[test]
    fn merged_inputs_keep_the_artifact_static_shapes() {
        // 2 jobs into a 5-slot / 16-row artifact: slots and rows both
        // need padding; scores still come back exact.
        let stats = Arc::new(CoalesceStats::default());
        let c = BatchCoalescer::new(
            Arc::new(StaticShapeExec {
                exec_rows: 16,
                slots: 5,
            }),
            CoalescerConfig {
                exec_rows: 16,
                max_rows: 16,
                max_slots: 5,
                window: Duration::from_millis(200),
                bypass_margin: Duration::from_millis(1),
            },
            stats,
        );
        let (j1, r1) = job("a", 1.0, &[1.0, 2.0, 3.0], None);
        let (j2, r2) = job("a", 2.0, &[7.0], None);
        c.submit(j1);
        c.submit(j2);
        assert_eq!(
            r1.recv().unwrap().unwrap().scores,
            vec![1001.0, 1002.0, 1003.0]
        );
        assert_eq!(r2.recv().unwrap().unwrap().scores, vec![2007.0]);
    }

    #[test]
    fn oversized_and_empty_jobs_reply_immediately() {
        let (c, _) = coalescer(60_000, 2, 4);
        let (j, r) = job("a", 1.0, &[1.0, 2.0, 3.0], None);
        c.submit(j);
        assert!(r.recv().unwrap().is_err(), "3 rows > max 2");
        let (j, r) = job("a", 1.0, &[], None);
        c.submit(j);
        assert!(r.recv().unwrap().unwrap().scores.is_empty());
    }

    #[test]
    fn drop_drains_queued_jobs() {
        let (c, _) = coalescer(60_000, 64, 8);
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                let (j, r) =
                    job("a", i as f32, &[i as f32 + 0.5], None);
                c.submit(j);
                r
            })
            .collect();
        drop(c); // must flush, not abandon
        for (i, r) in rxs.into_iter().enumerate() {
            let s = r.recv().expect("reply delivered on shutdown").unwrap();
            assert_eq!(s.scores, vec![i as f32 * 1000.0 + i as f32 + 0.5]);
        }
    }
}
