//! Runtime: PJRT client wrapper executing AOT artifacts (`artifacts/*.hlo.txt`).
//!
//! `Engine` is the single-thread compile+execute core; `RtpPool` is the
//! `Send` fleet interface the coordinator uses; `Manifest` is the contract
//! with the python AOT path; `Tensor` is the host-side currency.

pub mod artifact;
pub mod coalescer;
pub mod engine;
pub mod pool;
pub mod tensor;

pub use artifact::{Manifest, Table, VariantSpec};
pub use coalescer::{
    BatchCoalescer, CoalescerConfig, HeadExecutor, HeadJob, JobScores,
};
pub use engine::Engine;
pub use pool::RtpPool;
pub use tensor::Tensor;
