//! RtpPool — the "real-time prediction platform" fleet (paper §3.1).
//!
//! The `xla` wrapper types are `!Send`, so each worker thread owns a full
//! [`Engine`] (its own PJRT client + compiled executables) and requests are
//! dispatched over channels.  That is not a workaround so much as the
//! production topology: the paper's Merger talks to an RTP *cluster*, and
//! per-worker executable replicas are exactly how such fleets are deployed.
//!
//! Beyond execution, the fleet supports **hot artifact loading**
//! ([`RtpPool::ensure_artifacts`]): the multi-scenario registry registers
//! new scenarios at runtime, and each worker compiles the missing
//! executables on demand — a failed compile fails the registration, never
//! the fleet.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use super::artifact::Manifest;
use super::engine::Engine;
use super::tensor::Tensor;
use crate::util::threadpool::WorkerSet;

/// One scoring call to the fleet.
pub struct RtpRequest {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
    pub reply: Sender<Result<Vec<Tensor>>>,
}

/// Fleet message: execute an artifact, or compile one into this worker.
enum RtpMsg {
    Exec(RtpRequest),
    Load {
        artifact: String,
        reply: Sender<Result<()>>,
    },
}

/// Fleet of PJRT workers with replicated executables.
pub struct RtpPool {
    workers: WorkerSet<RtpMsg>,
    n_workers: usize,
    /// Artifacts every worker has compiled (startup set + hot loads).
    /// The lock also serializes concurrent `ensure_artifacts` calls.
    loaded: Mutex<HashSet<String>>,
    /// Executions dispatched per artifact — the ground truth the
    /// user-reuse bench and stress tests gate on ("exactly one
    /// `user_tower` call per (user, epoch)").  Steady state is a shared
    /// read lock + one relaxed atomic add: concurrent mini-batch
    /// dispatchers never serialize here (the write lock is taken only on
    /// an artifact's FIRST dispatch).
    exec_counts: RwLock<HashMap<String, AtomicU64>>,
}

impl RtpPool {
    /// Spin up `n_workers`, each compiling every artifact in `artifacts`.
    /// Compilation failures surface as panics during startup (fail fast —
    /// a worker that cannot serve must not join the fleet).  Artifacts
    /// needed later hot-load through [`RtpPool::ensure_artifacts`], where
    /// failures are recoverable errors instead.
    pub fn new(
        manifest: Arc<Manifest>,
        artifacts: Vec<String>,
        n_workers: usize,
    ) -> RtpPool {
        let loaded = Mutex::new(artifacts.iter().cloned().collect());
        let startup = artifacts;
        let manifest2 = Arc::clone(&manifest);
        let workers = WorkerSet::new(
            n_workers,
            move |i| {
                let mut engine = Engine::new()
                    .unwrap_or_else(|e| panic!("worker {i}: {e:#}"));
                for name in &startup {
                    engine
                        .load(&manifest2, name)
                        .unwrap_or_else(|e| panic!("worker {i}: {e:#}"));
                }
                engine
            },
            move |engine: &mut Engine, msg: RtpMsg| match msg {
                RtpMsg::Exec(req) => {
                    let RtpRequest {
                        artifact,
                        inputs,
                        reply,
                    } = req;
                    let result = engine.execute(&artifact, &inputs);
                    // Drop the inputs BEFORE replying: arena-backed
                    // operand buffers are back in the pool by the time
                    // the caller observes the scores (the accounting
                    // tests assert outstanding == 0 post-response).
                    drop(inputs);
                    // Receiver may have given up (timeout) — that's fine.
                    let _ = reply.send(result);
                }
                RtpMsg::Load { artifact, reply } => {
                    let _ = reply.send(engine.load(&manifest, &artifact));
                }
            },
        );
        RtpPool {
            workers,
            n_workers,
            loaded,
            exec_counts: RwLock::new(HashMap::new()),
        }
    }

    /// Count one dispatched execution of `artifact`.  Shared read lock +
    /// relaxed atomic on the steady-state path (no allocation, no
    /// exclusion between concurrent dispatchers); the key string is only
    /// cloned — under the write lock — on the artifact's first dispatch.
    fn note_exec(&self, artifact: &str) {
        {
            let counts = self.exec_counts.read().unwrap();
            if let Some(c) = counts.get(artifact) {
                c.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        self.exec_counts
            .write()
            .unwrap()
            .entry(artifact.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Executions dispatched for one artifact since startup.
    pub fn executions_of(&self, artifact: &str) -> u64 {
        self.exec_counts
            .read()
            .unwrap()
            .get(artifact)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Compile any of `names` not yet resident into EVERY worker (hot
    /// scenario registration).  Blocks until all workers reply; a compile
    /// failure on any worker fails the call (the fleet keeps serving its
    /// previously loaded set — `Engine::load` is idempotent, so a retry
    /// after fixing the artifact is safe).
    pub fn ensure_artifacts(&self, names: &[String]) -> Result<()> {
        let mut loaded = self.loaded.lock().unwrap();
        let missing: Vec<String> = names
            .iter()
            .filter(|n| !loaded.contains(n.as_str()))
            .cloned()
            .collect();
        if missing.is_empty() {
            return Ok(());
        }
        let mut replies = Vec::with_capacity(missing.len() * self.n_workers);
        for w in 0..self.n_workers {
            for name in &missing {
                let (tx, rx) = channel();
                self.workers.submit_to(
                    w,
                    RtpMsg::Load {
                        artifact: name.clone(),
                        reply: tx,
                    },
                );
                replies.push(rx);
            }
        }
        for rx in replies {
            rx.recv().map_err(|_| {
                anyhow::anyhow!("RTP worker died during artifact load")
            })??;
        }
        for name in missing {
            loaded.insert(name);
        }
        Ok(())
    }

    /// Whether every worker has `name` compiled.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.loaded.lock().unwrap().contains(name)
    }

    /// Fire a call and return the reply channel (the async half of the
    /// Merger's two-phase interaction).
    pub fn call_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        self.note_exec(artifact);
        let (tx, rx) = channel();
        self.workers.submit(RtpMsg::Exec(RtpRequest {
            artifact: artifact.to_string(),
            inputs,
            reply: tx,
        }));
        rx
    }

    /// Same, pinned to a worker (consistent-hash routing, §3.4).
    pub fn call_async_on(
        &self,
        worker: usize,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        self.note_exec(artifact);
        let (tx, rx) = channel();
        self.workers.submit_to(
            worker,
            RtpMsg::Exec(RtpRequest {
                artifact: artifact.to_string(),
                inputs,
                reply: tx,
            }),
        );
        rx
    }

    /// Blocking call.
    pub fn call(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.call_async(artifact, inputs)
            .recv()
            .map_err(|_| anyhow::anyhow!("RTP worker dropped the reply"))?
    }

    /// Blocking call expecting a single output tensor.
    pub fn call1(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Tensor> {
        let mut out = self.call(artifact, inputs)?;
        anyhow::ensure!(out.len() == 1, "{artifact}: expected 1 output");
        Ok(out.pop().unwrap())
    }
}

/// The fleet is the production executor behind the cross-request
/// [`super::BatchCoalescer`].
impl super::coalescer::HeadExecutor for RtpPool {
    fn execute_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        self.call_async(artifact, inputs)
    }
}
