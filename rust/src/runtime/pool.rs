//! RtpPool — the "real-time prediction platform" fleet (paper §3.1).
//!
//! The `xla` wrapper types are `!Send`, so each worker thread owns a full
//! [`Engine`] (its own PJRT client + compiled executables) and requests are
//! dispatched over channels.  That is not a workaround so much as the
//! production topology: the paper's Merger talks to an RTP *cluster*, and
//! per-worker executable replicas are exactly how such fleets are deployed.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::Result;

use super::artifact::Manifest;
use super::engine::Engine;
use super::tensor::Tensor;
use crate::util::threadpool::WorkerSet;

/// One scoring call to the fleet.
pub struct RtpRequest {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
    pub reply: Sender<Result<Vec<Tensor>>>,
}

/// Fleet of PJRT workers with replicated executables.
pub struct RtpPool {
    workers: WorkerSet<RtpRequest>,
    n_workers: usize,
}

impl RtpPool {
    /// Spin up `n_workers`, each compiling every artifact in `artifacts`.
    /// Compilation failures surface as panics during startup (fail fast —
    /// a worker that cannot serve must not join the fleet).
    pub fn new(
        manifest: Arc<Manifest>,
        artifacts: Vec<String>,
        n_workers: usize,
    ) -> RtpPool {
        let workers = WorkerSet::new(
            n_workers,
            move |i| {
                let mut engine = Engine::new()
                    .unwrap_or_else(|e| panic!("worker {i}: {e:#}"));
                for name in &artifacts {
                    engine
                        .load(&manifest, name)
                        .unwrap_or_else(|e| panic!("worker {i}: {e:#}"));
                }
                engine
            },
            |engine: &mut Engine, req: RtpRequest| {
                let result = engine.execute(&req.artifact, &req.inputs);
                // Receiver may have given up (timeout) — that's fine.
                let _ = req.reply.send(result);
            },
        );
        RtpPool { workers, n_workers }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Fire a call and return the reply channel (the async half of the
    /// Merger's two-phase interaction).
    pub fn call_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        let (tx, rx) = channel();
        self.workers.submit(RtpRequest {
            artifact: artifact.to_string(),
            inputs,
            reply: tx,
        });
        rx
    }

    /// Same, pinned to a worker (consistent-hash routing, §3.4).
    pub fn call_async_on(
        &self,
        worker: usize,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        let (tx, rx) = channel();
        self.workers.submit_to(
            worker,
            RtpRequest {
                artifact: artifact.to_string(),
                inputs,
                reply: tx,
            },
        );
        rx
    }

    /// Blocking call.
    pub fn call(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.call_async(artifact, inputs)
            .recv()
            .map_err(|_| anyhow::anyhow!("RTP worker dropped the reply"))?
    }

    /// Blocking call expecting a single output tensor.
    pub fn call1(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Tensor> {
        let mut out = self.call(artifact, inputs)?;
        anyhow::ensure!(out.len() == 1, "{artifact}: expected 1 output");
        Ok(out.pop().unwrap())
    }
}

/// The fleet is the production executor behind the cross-request
/// [`super::BatchCoalescer`].
impl super::coalescer::HeadExecutor for RtpPool {
    fn execute_async(
        &self,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Receiver<Result<Vec<Tensor>>> {
        self.call_async(artifact, inputs)
    }
}
