//! LSH signature construction + similarity (paper Eq.5-7, §4.2).
//!
//! Signatures are built once per item from the frozen multi-modal
//! embeddings (sign random projection with the shared `W_hash`), stored
//! **packed uint8** in the N2O table (storage/transport — the paper's
//! uint8 index table), and unpacked to ±1 f32 planes only at mini-batch
//! assembly for the MXU-friendly HLO (DESIGN.md §7).

use crate::runtime::{Table, Tensor};
use crate::util::bits;

/// Signature builder over a fixed `W_hash` [d_lsh_bits, d_mm].
pub struct Hasher {
    pub n_bits: usize,
    d_mm: usize,
    w_hash: Vec<f32>, // row-major [n_bits, d_mm]
}

impl Hasher {
    pub fn from_table(w_hash: &Table) -> Hasher {
        let shape = w_hash.shape();
        Hasher {
            n_bits: shape[0],
            d_mm: shape[1],
            w_hash: w_hash.as_f32().to_vec(),
        }
    }

    pub fn packed_len(&self) -> usize {
        self.n_bits.div_ceil(8)
    }

    /// Eq.(5): packed signature of one multi-modal embedding.
    pub fn sign(&self, mm: &[f32]) -> Vec<u8> {
        debug_assert_eq!(mm.len(), self.d_mm);
        let bits: Vec<bool> = (0..self.n_bits)
            .map(|b| {
                let row = &self.w_hash[b * self.d_mm..(b + 1) * self.d_mm];
                let dot: f32 = row.iter().zip(mm).map(|(w, x)| w * x).sum();
                dot >= 0.0
            })
            .collect();
        bits::pack_bits(&bits)
    }

    /// Batch signing into a contiguous packed matrix [n, packed_len].
    pub fn sign_rows(&self, mm: &Table) -> Vec<u8> {
        let n = mm.shape()[0];
        let mut out = Vec::with_capacity(n * self.packed_len());
        for i in 0..n {
            out.extend_from_slice(&self.sign(mm.f32_row(i)));
        }
        out
    }
}

/// Unpack a set of packed signatures into a ±1 plane tensor [n, n_bits].
pub fn unpack_plane(packed: &[u8], n: usize, n_bits: usize) -> Tensor {
    let mut data = Vec::new();
    unpack_plane_into(packed, n, n_bits, &mut data);
    Tensor::new(vec![n, n_bits], data)
}

/// [`unpack_plane`] into a caller-provided buffer (cleared first) — the
/// arena-backed assembly path writes straight into pooled storage.
pub fn unpack_plane_into(
    packed: &[u8],
    n: usize,
    n_bits: usize,
    out: &mut Vec<f32>,
) {
    let pl = n_bits.div_ceil(8);
    out.clear();
    out.resize(n * n_bits, 0.0);
    for i in 0..n {
        bits::unpack_to_pm1(
            &packed[i * pl..(i + 1) * pl],
            n_bits,
            &mut out[i * n_bits..(i + 1) * n_bits],
        );
    }
}

/// Rust-side reference similarity between two packed signature matrices —
/// used by tests and the Table-3 complexity bench (the serving path runs
/// this inside the HLO).
pub fn similarity_matrix(
    a: &[u8],
    n_a: usize,
    b: &[u8],
    n_b: usize,
    n_bits: usize,
) -> Vec<f32> {
    let pl = n_bits.div_ceil(8);
    let mut out = vec![0.0f32; n_a * n_b];
    for i in 0..n_a {
        let ra = &a[i * pl..(i + 1) * pl];
        for j in 0..n_b {
            let rb = &b[j * pl..(j + 1) * pl];
            out[i * n_b + j] = bits::lsh_similarity_packed(ra, rb, n_bits);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hasher() -> Hasher {
        // 16-bit hash over d_mm=4, fixed weights.
        let w: Vec<f32> = (0..16 * 4)
            .map(|i| ((i * 37 + 11) % 19) as f32 - 9.0)
            .collect();
        Hasher {
            n_bits: 16,
            d_mm: 4,
            w_hash: w,
        }
    }

    #[test]
    fn sign_is_deterministic_and_packed() {
        let h = hasher();
        let s1 = h.sign(&[0.3, -1.0, 0.7, 0.2]);
        let s2 = h.sign(&[0.3, -1.0, 0.7, 0.2]);
        assert_eq!(s1, s2);
        assert_eq!(s1.len(), 2);
    }

    #[test]
    fn similar_inputs_similar_signatures() {
        let h = hasher();
        let a = h.sign(&[1.0, 0.5, -0.3, 0.8]);
        let b = h.sign(&[1.01, 0.49, -0.31, 0.82]); // tiny perturbation
        let c = h.sign(&[-1.0, -0.5, 0.3, -0.8]); // antipode
        let sim_ab = crate::util::bits::lsh_similarity_packed(&a, &b, 16);
        let sim_ac = crate::util::bits::lsh_similarity_packed(&a, &c, 16);
        assert!(sim_ab > 0.9, "{sim_ab}");
        assert!(sim_ac < 0.1, "{sim_ac}");
    }

    #[test]
    fn unpack_plane_matches_packed_similarity() {
        let h = hasher();
        let sigs: Vec<Vec<u8>> = (0..3)
            .map(|i| h.sign(&[i as f32, 1.0 - i as f32, 0.5, -0.5]))
            .collect();
        let flat: Vec<u8> = sigs.concat();
        let plane = unpack_plane(&flat, 3, 16);
        // ±1 dot similarity == packed XNOR similarity.
        for i in 0..3 {
            for j in 0..3 {
                let dot: f32 = plane.row(i).iter().zip(plane.row(j))
                    .map(|(x, y)| x * y).sum();
                let sim_plane = (1.0 + dot / 16.0) / 2.0;
                let sim_packed = crate::util::bits::lsh_similarity_packed(
                    &sigs[i], &sigs[j], 16);
                assert!((sim_plane - sim_packed).abs() < 1e-6);
            }
        }
    }
}

/// SimTier histogram (Eq.9) computed the paper's way (§4.2): packed uint8
/// signatures, XNOR + PopulationCount, integer tier binning — the serving-
/// engine half of the LSH split (HLO keeps DIN's matmuls).  Returns a
/// row-major [n_items, n_tiers] histogram normalized by `n_seq`.
///
/// Exactly matches the float path: tier = clip(floor(sim*N), 0, N-1) with
/// sim = matches/n_bits, and matches*N/n_bits is exact integer arithmetic.
pub fn tier_histogram(
    item_packed: &[u8],
    n_items: usize,
    seq_packed: &[u8],
    n_seq: usize,
    n_bits: usize,
    n_tiers: usize,
) -> Vec<f32> {
    let mut out = Vec::new();
    tier_histogram_into(
        item_packed,
        n_items,
        seq_packed,
        n_seq,
        n_bits,
        n_tiers,
        &mut out,
    );
    out
}

/// [`tier_histogram`] into a caller-provided buffer (cleared first) — the
/// arena-backed assembly path writes straight into pooled storage.
#[allow(clippy::too_many_arguments)]
pub fn tier_histogram_into(
    item_packed: &[u8],
    n_items: usize,
    seq_packed: &[u8],
    n_seq: usize,
    n_bits: usize,
    n_tiers: usize,
    out: &mut Vec<f32>,
) {
    let pl = n_bits.div_ceil(8);
    out.clear();
    out.resize(n_items * n_tiers, 0.0);
    let inv = 1.0 / n_seq as f32;
    // Tier lookup table over match counts (the paper's 1x256-style LUT,
    // sized n_bits+1 here).
    let tier_of: Vec<u8> = (0..=n_bits)
        .map(|m| (((m * n_tiers) / n_bits).min(n_tiers - 1)) as u8)
        .collect();
    if n_bits == 64 && n_tiers <= 16 {
        // Hot path: one signature == one u64 word.  Pre-convert both sides
        // once so the O(n_items * n_seq) loop is xor+popcount+LUT only.
        let to_words = |packed: &[u8], n: usize| -> Vec<u64> {
            (0..n)
                .map(|k| {
                    u64::from_le_bytes(
                        packed[k * 8..(k + 1) * 8].try_into().unwrap(),
                    )
                })
                .collect()
        };
        let wi = to_words(item_packed, n_items);
        let ws = to_words(seq_packed, n_seq);
        for (i, &a) in wi.iter().enumerate() {
            let mut counts = [0u32; 16];
            for &b in &ws {
                let matches = (!(a ^ b)).count_ones() as usize;
                counts[tier_of[matches] as usize] += 1;
            }
            let row = &mut out[i * n_tiers..(i + 1) * n_tiers];
            for (o, c) in row.iter_mut().zip(&counts) {
                *o = *c as f32 * inv;
            }
        }
        return;
    }
    for i in 0..n_items {
        let ri = &item_packed[i * pl..(i + 1) * pl];
        let row = &mut out[i * n_tiers..(i + 1) * n_tiers];
        let mut counts = vec![0u32; n_tiers];
        for j in 0..n_seq {
            let rj = &seq_packed[j * pl..(j + 1) * pl];
            let matches =
                crate::util::bits::xnor_matches_hw(ri, rj, n_bits) as usize;
            counts[tier_of[matches] as usize] += 1;
        }
        for (o, c) in row.iter_mut().zip(&counts) {
            *o = *c as f32 * inv;
        }
    }
}
