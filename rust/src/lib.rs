//! # AIF — Asynchronous Inference Framework for Cost-Effective Pre-Ranking
//!
//! Rust L3 coordinator of the three-layer reproduction (DESIGN.md):
//! the Merger request lifecycle, online-asynchronous user-side inference
//! overlapped with retrieval, nearline N2O item-side computation, SIM
//! pre-caching, mini-batch pre-rank scheduling and the sequential baseline —
//! all executing AOT-compiled JAX/Pallas HLO artifacts through PJRT.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! python invocation, and everything in this crate serves from `artifacts/`.

pub mod util;
pub mod config;
pub mod runtime;
pub mod features;
pub mod retrieval;
pub mod lsh;
pub mod cache;
pub mod nearline;
pub mod storage;
pub mod coordinator;
pub mod metrics;
pub mod workload;
pub mod server;
