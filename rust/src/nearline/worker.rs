//! Nearline worker: update-triggered item-side computation (paper §3.2).
//!
//! Runs the `item_tower` artifact over item batches on the RTP fleet and
//! writes N2O rows.  A **full build** covers the whole catalog (model
//! checkpoint update trigger) using "offline high-priority CPU resources,
//! utilizing highly concurrent processes" — here, many in-flight RTP calls.
//! **Incremental** builds recompute only the touched items (feature update
//! / new item trigger, via the message queue).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::n2o::{N2oEntry, N2oTable};
use crate::features::World;
use crate::lsh::Hasher;
use crate::runtime::{RtpPool, Tensor};

pub struct NearlineWorker {
    pub rtp: Arc<RtpPool>,
    pub world: Arc<World>,
    pub hasher: Arc<Hasher>,
    pub table: Arc<N2oTable>,
    pub batch: usize,
    /// Checkpoint barrier (DESIGN.md §16): when set, the generation swap
    /// at the end of `full_build` is serialized against checkpoint
    /// capture, so a snapshot never straddles a swap.  The u64 counts
    /// barrier crossings (observability only).
    barrier: Option<Arc<Mutex<u64>>>,
}

impl NearlineWorker {
    pub fn new(
        rtp: Arc<RtpPool>,
        world: Arc<World>,
        hasher: Arc<Hasher>,
        table: Arc<N2oTable>,
        batch: usize,
    ) -> Self {
        NearlineWorker {
            rtp,
            world,
            hasher,
            table,
            batch,
            barrier: None,
        }
    }

    /// Serialize generation swaps against checkpoint capture.
    pub fn with_barrier(mut self, barrier: Arc<Mutex<u64>>) -> Self {
        self.barrier = Some(barrier);
        self
    }

    fn item_raw_tensor(&self, items: &[u32]) -> Tensor {
        let d = self.world.items_raw.shape()[1];
        let mut data = Vec::with_capacity(self.batch * d);
        for &i in items {
            data.extend_from_slice(self.world.items_raw.f32_row(i as usize));
        }
        for _ in items.len()..self.batch {
            data.extend_from_slice(
                self.world
                    .items_raw
                    .f32_row(items[items.len() - 1] as usize),
            );
        }
        Tensor::new(vec![self.batch, d], data)
    }

    /// Compute N2O rows for a chunk of items (one item_tower execution).
    fn compute_chunk(&self, items: &[u32]) -> Result<Vec<(u32, N2oEntry)>> {
        let input = self.item_raw_tensor(items);
        let out = self.rtp.call("item_tower", vec![input])?;
        let (item_vec, bea_w) = (&out[0], &out[1]);
        let mut rows = Vec::with_capacity(items.len());
        for (k, &id) in items.iter().enumerate() {
            rows.push((
                id,
                N2oEntry {
                    item_vec: item_vec.row(k).to_vec(),
                    bea_w: bea_w.row(k).to_vec(),
                    sign_packed: self
                        .hasher
                        .sign(self.world.items_mm.f32_row(id as usize)),
                },
            ));
        }
        Ok(rows)
    }

    /// Full catalog rebuild -> atomic generation swap.  Issues up to
    /// `n_inflight` RTP calls concurrently (the fleet has that many
    /// workers), keeping the build "timely" as §3.4 requires.
    pub fn full_build(&self, new_version: u64) -> Result<FullBuildReport> {
        let t0 = Instant::now();
        let n = self.world.n_items;
        let ids: Vec<u32> = (0..n as u32).collect();
        let chunks: Vec<&[u32]> = ids.chunks(self.batch).collect();

        let n_inflight = self.rtp.n_workers().max(1);
        let mut entries: Vec<Option<N2oEntry>> = vec![None; n];
        let mut executions = 0usize;
        // Pipeline the chunks through the fleet: keep n_inflight calls
        // outstanding, writing rows as replies land.
        let mut pending = std::collections::VecDeque::new();
        let mut next = 0usize;
        while next < chunks.len() || !pending.is_empty() {
            while pending.len() < n_inflight && next < chunks.len() {
                let chunk = chunks[next];
                let input = self.item_raw_tensor(chunk);
                let rx = self.rtp.call_async("item_tower", vec![input]);
                pending.push_back((chunk, rx));
                next += 1;
            }
            let (chunk, rx) = pending.pop_front().unwrap();
            let out = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("RTP worker dropped reply"))??;
            executions += 1;
            for (k, &id) in chunk.iter().enumerate() {
                entries[id as usize] = Some(N2oEntry {
                    item_vec: out[0].row(k).to_vec(),
                    bea_w: out[1].row(k).to_vec(),
                    sign_packed: self
                        .hasher
                        .sign(self.world.items_mm.f32_row(id as usize)),
                });
            }
        }
        // Swap under the checkpoint barrier (if any): the checkpointer
        // holds the same mutex across its capture, so a manifest is
        // always entirely-before or entirely-after this swap.  Lock
        // order is barrier -> generation lock, and the checkpointer only
        // ever pins (read-locks) the generation — no deadlock.
        match &self.barrier {
            Some(b) => {
                let mut crossings = b.lock().unwrap();
                *crossings += 1;
                self.table.swap_full(entries, new_version);
            }
            None => self.table.swap_full(entries, new_version),
        }
        Ok(FullBuildReport {
            n_items: n,
            executions,
            elapsed: t0.elapsed(),
            table_bytes: self.table.size_bytes(),
        })
    }

    /// Incremental update for specific items (message-queue trigger).
    pub fn incremental(&self, items: &[u32]) -> Result<usize> {
        let mut updated = 0;
        for chunk in items.chunks(self.batch) {
            let rows = self.compute_chunk(chunk)?;
            updated += rows.len();
            self.table.upsert(rows);
        }
        Ok(updated)
    }
}

#[derive(Debug)]
pub struct FullBuildReport {
    pub n_items: usize,
    pub executions: usize,
    pub elapsed: std::time::Duration,
    pub table_bytes: usize,
}
