//! Nearline worker: update-triggered item-side computation (paper §3.2).
//!
//! Runs the `item_tower` artifact over item batches on the RTP fleet and
//! writes N2O rows.  A **full build** covers the whole catalog (model
//! checkpoint update trigger) using "offline high-priority CPU resources,
//! utilizing highly concurrent processes" — here, many in-flight RTP calls.
//! **Incremental** builds recompute only the touched items (feature update
//! / new item trigger, via the message queue).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::n2o::{CompactReport, N2oEntry, N2oTable};
use super::queue::{IncrementalReport, UpdateApplier};
use crate::features::World;
use crate::lsh::Hasher;
use crate::runtime::{RtpPool, Tensor};

pub struct NearlineWorker {
    pub rtp: Arc<RtpPool>,
    pub world: Arc<World>,
    pub hasher: Arc<Hasher>,
    pub table: Arc<N2oTable>,
    pub batch: usize,
    /// Checkpoint barrier (DESIGN.md §16): when set, the generation swap
    /// at the end of `full_build` is serialized against checkpoint
    /// capture, so a snapshot never straddles a swap.  The u64 counts
    /// barrier crossings (observability only).
    barrier: Option<Arc<Mutex<u64>>>,
    /// Fault injection (tests/benches): each pending count makes one
    /// upcoming item_tower chunk computation fail, exercising the
    /// queue's retry path without touching the RTP fleet.
    inject_failures: AtomicU64,
}

impl NearlineWorker {
    pub fn new(
        rtp: Arc<RtpPool>,
        world: Arc<World>,
        hasher: Arc<Hasher>,
        table: Arc<N2oTable>,
        batch: usize,
    ) -> Self {
        NearlineWorker {
            rtp,
            world,
            hasher,
            table,
            batch,
            barrier: None,
            inject_failures: AtomicU64::new(0),
        }
    }

    /// Serialize generation swaps against checkpoint capture.
    pub fn with_barrier(mut self, barrier: Arc<Mutex<u64>>) -> Self {
        self.barrier = Some(barrier);
        self
    }

    /// Make the next `n` incremental chunk computations fail (tests).
    pub fn inject_failures(&self, n: u64) {
        self.inject_failures.fetch_add(n, Ordering::Relaxed);
    }

    fn take_injected_failure(&self) -> bool {
        self.inject_failures
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                v.checked_sub(1)
            })
            .is_ok()
    }

    fn item_raw_tensor(&self, items: &[u32]) -> Tensor {
        assert!(
            !items.is_empty(),
            "item_raw_tensor needs at least one item to pad from"
        );
        let d = self.world.items_raw.shape()[1];
        let mut data = Vec::with_capacity(self.batch * d);
        for &i in items {
            data.extend_from_slice(self.world.items_raw.f32_row(i as usize));
        }
        // Pad short batches by repeating the last real row.
        let last = items[items.len() - 1] as usize;
        let pad = self.world.items_raw.f32_row(last);
        for _ in items.len()..self.batch {
            data.extend_from_slice(pad);
        }
        Tensor::new(vec![self.batch, d], data)
    }

    /// Append N2O rows decoded from one item_tower output.
    fn push_rows(
        &self,
        items: &[u32],
        out: &[Tensor],
        rows: &mut Vec<(u32, N2oEntry)>,
    ) {
        let (item_vec, bea_w) = (&out[0], &out[1]);
        for (k, &id) in items.iter().enumerate() {
            rows.push((
                id,
                N2oEntry {
                    item_vec: item_vec.row(k).to_vec(),
                    bea_w: bea_w.row(k).to_vec(),
                    sign_packed: self
                        .hasher
                        .sign(self.world.items_mm.f32_row(id as usize)),
                },
            ));
        }
    }

    /// Full catalog rebuild -> atomic generation swap.  Issues up to
    /// `n_inflight` RTP calls concurrently (the fleet has that many
    /// workers), keeping the build "timely" as §3.4 requires.
    pub fn full_build(&self, new_version: u64) -> Result<FullBuildReport> {
        let t0 = Instant::now();
        let n = self.world.n_items;
        let ids: Vec<u32> = (0..n as u32).collect();
        let chunks: Vec<&[u32]> = ids.chunks(self.batch).collect();

        let n_inflight = self.rtp.n_workers().max(1);
        let mut entries: Vec<Option<N2oEntry>> = vec![None; n];
        let mut executions = 0usize;
        // Pipeline the chunks through the fleet: keep n_inflight calls
        // outstanding, writing rows as replies land.
        let mut pending = std::collections::VecDeque::new();
        let mut next = 0usize;
        while next < chunks.len() || !pending.is_empty() {
            while pending.len() < n_inflight && next < chunks.len() {
                let chunk = chunks[next];
                let input = self.item_raw_tensor(chunk);
                let rx = self.rtp.call_async("item_tower", vec![input]);
                pending.push_back((chunk, rx));
                next += 1;
            }
            let (chunk, rx) = pending.pop_front().unwrap();
            let out = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("RTP worker dropped reply"))??;
            executions += 1;
            for (k, &id) in chunk.iter().enumerate() {
                entries[id as usize] = Some(N2oEntry {
                    item_vec: out[0].row(k).to_vec(),
                    bea_w: out[1].row(k).to_vec(),
                    sign_packed: self
                        .hasher
                        .sign(self.world.items_mm.f32_row(id as usize)),
                });
            }
        }
        // Swap under the checkpoint barrier (if any): the checkpointer
        // holds the same mutex across its capture, so a manifest is
        // always entirely-before or entirely-after this swap.  Lock
        // order is barrier -> generation lock, and the checkpointer only
        // ever pins (read-locks) the generation — no deadlock.
        match &self.barrier {
            Some(b) => {
                let mut crossings = b.lock().unwrap();
                *crossings += 1;
                self.table.swap_full(entries, new_version);
            }
            None => self.table.swap_full(entries, new_version),
        }
        Ok(FullBuildReport {
            n_items: n,
            executions,
            elapsed: t0.elapsed(),
            table_bytes: self.table.size_bytes(),
        })
    }

    /// Incremental update for specific items (message-queue trigger).
    ///
    /// Computation is pipelined through the RTP fleet like `full_build`
    /// (up to `n_workers` chunks in flight), then every successful row is
    /// written in ONE maintenance-counted `N2oTable` upsert — one write
    /// lock per drained queue batch, however many chunks it spans.
    /// Failed chunks don't abort the batch: their ids come back in
    /// [`IncrementalReport::failed`] for the queue to retry, while the
    /// successful rows are already visible.  `incremental(&[])` is a
    /// no-op.
    pub fn incremental(&self, items: &[u32]) -> IncrementalReport {
        if items.is_empty() {
            return IncrementalReport::default();
        }
        let chunks: Vec<&[u32]> = items.chunks(self.batch).collect();
        let n_inflight = self.rtp.n_workers().max(1);
        let mut rows: Vec<(u32, N2oEntry)> = Vec::with_capacity(items.len());
        let mut failed: Vec<u32> = Vec::new();
        let mut last_error: Option<String> = None;
        let mut pending = std::collections::VecDeque::new();
        let mut next = 0usize;
        while next < chunks.len() || !pending.is_empty() {
            while pending.len() < n_inflight && next < chunks.len() {
                let chunk = chunks[next];
                next += 1;
                if self.take_injected_failure() {
                    failed.extend_from_slice(chunk);
                    last_error = Some("injected RTP failure".into());
                    continue;
                }
                let input = self.item_raw_tensor(chunk);
                let rx = self.rtp.call_async("item_tower", vec![input]);
                pending.push_back((chunk, rx));
            }
            let Some((chunk, rx)) = pending.pop_front() else {
                continue;
            };
            match rx.recv() {
                Ok(Ok(out)) => self.push_rows(chunk, &out, &mut rows),
                Ok(Err(e)) => {
                    failed.extend_from_slice(chunk);
                    last_error = Some(format!("{e:#}"));
                }
                Err(_) => {
                    failed.extend_from_slice(chunk);
                    last_error = Some("RTP worker dropped reply".into());
                }
            }
        }
        let applied = rows.len();
        if !rows.is_empty() {
            self.table.upsert_maintenance(rows);
        }
        IncrementalReport {
            applied,
            failed,
            last_error,
        }
    }
}

impl UpdateApplier for NearlineWorker {
    fn apply_incremental(&self, items: &[u32]) -> IncrementalReport {
        self.incremental(items)
    }

    fn apply_full(&self, version: u64) -> Result<()> {
        self.full_build(version).map(|_| ())
    }

    fn compact(&self) -> Option<CompactReport> {
        Some(self.table.compact())
    }
}

#[derive(Debug)]
pub struct FullBuildReport {
    pub n_items: usize,
    pub executions: usize,
    pub elapsed: std::time::Duration,
    pub table_bytes: usize,
}
