//! Incremental message queue (paper §4.2 "Update Methods": "we employ an
//! incremental message queue that dynamically processes updates, enabling
//! seamless integration of new entries without recalculating existing
//! signatures") — production-shaped (DESIGN.md §17).
//!
//! Topology: producers publish [`UpdateEvent`]s into a **bounded** two-lane
//! store (a hot lane for items the serving path marked popular via
//! [`ItemHeat`], a cold lane for the rest) guarded by one mutex and two
//! condvars.  A background drain thread takes the first event with a
//! blocking wait, lingers on a **condvar timeout against the batch
//! deadline** (no busy-wait) to batch bursts, coalesces duplicate ids, and
//! applies the whole drained batch through ONE [`UpdateApplier`] call —
//! which for the real worker means one `N2oTable` write lock per batch.
//!
//! Guarantees:
//! - **Bounded**: at most `queue_capacity` pending item ids; `publish`
//!   blocks or rejects (configurable [`BackpressurePolicy`]) when full.
//!   An event larger than the whole capacity is admitted alone when the
//!   queue is empty, so a misconfigured producer stalls instead of
//!   deadlocking.
//! - **Lossless**: failed batches are requeued (front of the hot lane,
//!   original enqueue timestamp, bounded by `retry_limit`); only
//!   exhausted retries increment `failed_updates` — nothing disappears
//!   with just a log line.  Shutdown drains every pending event before
//!   the thread exits (mirroring the coalescer's drain-on-drop).
//! - **Subsumption**: a pending `ModelSwap` takes priority and, on
//!   success, absorbs every incremental event that was enqueued before
//!   the build started (the full build recomputed them); events arriving
//!   *during* the build stay queued.
//! - **Observable**: depth/drop/retry counters, an enqueue-to-visible
//!   staleness histogram, `oldest_pending_ms`, and a per-item
//!   `updated_at` watermark, all surfaced through `/metrics`.

use std::collections::{BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::Result;

use super::heat::ItemHeat;
use super::n2o::CompactReport;
use crate::config::{BackpressurePolicy, NearlineConfig};
use crate::metrics::Histogram;
use crate::util::json::Object;

/// Nearline update triggers.
#[derive(Debug, Clone)]
pub enum UpdateEvent {
    /// Item feature change or brand-new item.
    ItemFeatures(Vec<u32>),
    /// Model checkpoint update -> full rebuild to `version`.
    ModelSwap { version: u64 },
    /// Drain & stop.
    Shutdown,
}

/// What [`UpdateQueue::publish`] did with the event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishOutcome {
    Enqueued,
    /// Dropped by the `Reject` backpressure policy (counted in
    /// `rejected_items`).
    Rejected,
    /// The queue is shutting down; the event was not accepted.
    Closed,
}

/// Result of applying one drained incremental batch.
#[derive(Debug, Default)]
pub struct IncrementalReport {
    /// Rows actually written to the table.
    pub applied: usize,
    /// Item ids whose computation failed (candidates for requeue).
    pub failed: Vec<u32>,
    pub last_error: Option<String>,
}

/// The queue's downstream: how drained work is applied.  The real
/// implementation is `NearlineWorker`; tests substitute a mock so queue
/// semantics are checkable without artifacts or an RTP fleet.
pub trait UpdateApplier: Send + Sync {
    /// Apply one coalesced batch of item ids.  Partial failure is
    /// reported, not thrown: successfully computed rows must already be
    /// written when this returns.
    fn apply_incremental(&self, items: &[u32]) -> IncrementalReport;
    /// Full rebuild to `version` (ModelSwap trigger).
    fn apply_full(&self, version: u64) -> Result<()>;
    /// Periodic chunk compaction (cadence: `compact_every` batches).
    fn compact(&self) -> Option<CompactReport> {
        None
    }
}

/// Per-item `updated_at` watermark (unix ms), grown on demand.  `0`
/// means "never updated through the queue".
#[derive(Default)]
pub struct Watermarks {
    slots: RwLock<Vec<AtomicU64>>,
}

impl Watermarks {
    fn note(&self, ids: &[u32], now_ms: u64) {
        let need = match ids.iter().max() {
            Some(&m) => m as usize + 1,
            None => return,
        };
        {
            let r = self.slots.read().unwrap();
            if r.len() >= need {
                for &i in ids {
                    r[i as usize].store(now_ms, Ordering::Relaxed);
                }
                return;
            }
        }
        let mut w = self.slots.write().unwrap();
        while w.len() < need {
            w.push(AtomicU64::new(0));
        }
        for &i in ids {
            w[i as usize].store(now_ms, Ordering::Relaxed);
        }
    }

    /// When `id` was last made visible by the queue (unix ms).
    pub fn updated_at_ms(&self, id: u32) -> Option<u64> {
        let r = self.slots.read().unwrap();
        match r.get(id as usize).map(|s| s.load(Ordering::Relaxed)) {
            Some(0) | None => None,
            Some(ms) => Some(ms),
        }
    }

    /// (items with a watermark, oldest unix ms, newest unix ms).
    pub fn summary(&self) -> (usize, u64, u64) {
        let r = self.slots.read().unwrap();
        let mut n = 0usize;
        let (mut oldest, mut newest) = (u64::MAX, 0u64);
        for s in r.iter() {
            let v = s.load(Ordering::Relaxed);
            if v > 0 {
                n += 1;
                oldest = oldest.min(v);
                newest = newest.max(v);
            }
        }
        if n == 0 {
            (0, 0, 0)
        } else {
            (n, oldest, newest)
        }
    }
}

/// Queue counters (all relaxed atomics; written by producers and the
/// drain thread, read by `/metrics`).
#[derive(Default)]
pub struct QueueStats {
    pub enqueued_events: AtomicU64,
    pub enqueued_items: AtomicU64,
    /// Items routed to the priority lane at publish time.
    pub hot_items: AtomicU64,
    pub rejected_items: AtomicU64,
    /// Publishes that had to wait under the `Block` policy.
    pub blocked_publishes: AtomicU64,
    pub peak_depth_items: AtomicU64,
    /// Duplicate ids merged away by batch coalescing.
    pub coalesced_items: AtomicU64,
    pub applied_items: AtomicU64,
    pub applied_batches: AtomicU64,
    pub full_rebuilds: AtomicU64,
    pub failed_full_builds: AtomicU64,
    /// Incremental items absorbed by a successful full rebuild.
    pub subsumed_items: AtomicU64,
    pub retried_batches: AtomicU64,
    pub requeued_items: AtomicU64,
    /// Items lost after exhausting `retry_limit` — the "never silently
    /// discarded" counter.
    pub failed_updates: AtomicU64,
    pub compactions: AtomicU64,
    pub compact_bytes_reclaimed: AtomicU64,
    /// Enqueue-to-visible latency of applied batches.
    pub apply_latency: Histogram,
    /// Per-item `updated_at` watermark.
    pub watermarks: Watermarks,
}

/// One pending `ItemFeatures` event.
struct Pending {
    ids: Vec<u32>,
    at: Instant,
    attempts: u32,
}

struct Lanes {
    hot: VecDeque<Pending>,
    cold: VecDeque<Pending>,
    /// Coalesced pending ModelSwap: (target version, enqueued at,
    /// attempts).
    swap: Option<(u64, Instant, u32)>,
    /// Pending item ids across both lanes (the bounded quantity).
    depth_items: usize,
    /// Earliest enqueue time of the batch currently being applied (kept
    /// so `oldest_pending_ms` covers in-flight work too).
    in_flight_since: Option<Instant>,
    shutdown: bool,
}

impl Lanes {
    fn has_work(&self) -> bool {
        !self.hot.is_empty() || !self.cold.is_empty() || self.swap.is_some()
    }

    fn oldest_at(&self) -> Option<Instant> {
        [
            self.hot.front().map(|p| p.at),
            self.cold.front().map(|p| p.at),
            self.swap.map(|(_, at, _)| at),
            self.in_flight_since,
        ]
        .into_iter()
        .flatten()
        .min()
    }
}

struct Shared {
    state: Mutex<Lanes>,
    /// Signaled on enqueue and shutdown (drain thread waits here).
    not_empty: Condvar,
    /// Signaled when capacity frees up (blocked producers wait here).
    not_full: Condvar,
    /// Signaled when the queue goes idle (for `flush`).
    idle: Condvar,
    cfg: NearlineConfig,
    heat: Option<Arc<ItemHeat>>,
    stats: Arc<QueueStats>,
}

/// Work taken from the lanes by the drain thread.
enum Work {
    Swap {
        version: u64,
        at: Instant,
        attempts: u32,
        /// Lane cuts (event counts) at build start: on success, this many
        /// events are popped as subsumed.
        cut_hot: usize,
        cut_cold: usize,
    },
    Incremental {
        ids: Vec<u32>,
        /// (enqueue time, attempts) of every contributing event.
        events: Vec<(Instant, u32)>,
        earliest: Instant,
        max_attempts: u32,
    },
}

pub struct UpdateQueue {
    shared: Arc<Shared>,
    handle: Mutex<Option<JoinHandle<()>>>,
    pub stats: Arc<QueueStats>,
}

fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

impl UpdateQueue {
    /// Backward-compatible constructor: given batching knobs, everything
    /// else (capacity, policy, retries) keeps [`NearlineConfig`] defaults
    /// and no heat signal is wired (all items ride the cold lane).
    pub fn start(
        applier: Arc<dyn UpdateApplier>,
        max_batch: usize,
        linger: Duration,
    ) -> UpdateQueue {
        let cfg = NearlineConfig {
            max_batch,
            linger_ms: linger.as_secs_f64() * 1e3,
            ..NearlineConfig::default()
        };
        Self::start_with(applier, cfg, None)
    }

    pub fn start_with(
        applier: Arc<dyn UpdateApplier>,
        mut cfg: NearlineConfig,
        heat: Option<Arc<ItemHeat>>,
    ) -> UpdateQueue {
        cfg.queue_capacity = cfg.queue_capacity.max(1);
        cfg.max_batch = cfg.max_batch.max(1);
        let stats = Arc::new(QueueStats::default());
        let shared = Arc::new(Shared {
            state: Mutex::new(Lanes {
                hot: VecDeque::new(),
                cold: VecDeque::new(),
                swap: None,
                depth_items: 0,
                in_flight_since: None,
                shutdown: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            idle: Condvar::new(),
            cfg,
            heat,
            stats: Arc::clone(&stats),
        });
        let sh = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("aif-nearline-queue".into())
            .spawn(move || drain_loop(&sh, applier.as_ref()))
            .expect("spawn nearline queue");
        UpdateQueue {
            shared,
            handle: Mutex::new(Some(handle)),
            stats,
        }
    }

    pub fn publish(&self, event: UpdateEvent) -> PublishOutcome {
        let sh = &self.shared;
        match event {
            UpdateEvent::Shutdown => {
                self.begin_shutdown();
                PublishOutcome::Enqueued
            }
            UpdateEvent::ModelSwap { version } => {
                let mut st = sh.state.lock().unwrap();
                if st.shutdown {
                    return PublishOutcome::Closed;
                }
                // Coalesce to the max requested version (building an
                // older checkpoint would be wasted work).
                st.swap = Some(match st.swap.take() {
                    Some((v, at, att)) => (v.max(version), at, att),
                    None => (version, Instant::now(), 0),
                });
                sh.stats.enqueued_events.fetch_add(1, Ordering::Relaxed);
                sh.not_empty.notify_all();
                PublishOutcome::Enqueued
            }
            UpdateEvent::ItemFeatures(ids) => {
                if ids.is_empty() {
                    return PublishOutcome::Enqueued; // no-op by contract
                }
                let n = ids.len();
                let mut st = sh.state.lock().unwrap();
                if st.shutdown {
                    return PublishOutcome::Closed;
                }
                let mut waited = false;
                // Oversized events (n > capacity) are admitted alone
                // when the queue is empty: blocking forever on capacity
                // that can never exist would deadlock the producer.
                while st.depth_items > 0
                    && st.depth_items + n > sh.cfg.queue_capacity
                {
                    match sh.cfg.policy {
                        BackpressurePolicy::Reject => {
                            sh.stats
                                .rejected_items
                                .fetch_add(n as u64, Ordering::Relaxed);
                            return PublishOutcome::Rejected;
                        }
                        BackpressurePolicy::Block => {
                            if !waited {
                                waited = true;
                                sh.stats.blocked_publishes.fetch_add(1, Ordering::Relaxed);
                            }
                            st = sh.not_full.wait(st).unwrap();
                            if st.shutdown {
                                return PublishOutcome::Closed;
                            }
                        }
                    }
                }
                let at = Instant::now();
                let (hot, cold) = match (&sh.heat, sh.cfg.hot_min_touches) {
                    (Some(h), thr) if thr > 0 => {
                        ids.into_iter().partition(|&id| h.is_hot(id, thr))
                    }
                    _ => (Vec::new(), ids),
                };
                let n_hot = hot.len();
                if !hot.is_empty() {
                    st.hot.push_back(Pending { ids: hot, at, attempts: 0 });
                }
                if !cold.is_empty() {
                    st.cold.push_back(Pending {
                        ids: cold,
                        at,
                        attempts: 0,
                    });
                }
                st.depth_items += n;
                sh.stats.enqueued_events.fetch_add(1, Ordering::Relaxed);
                sh.stats
                    .enqueued_items
                    .fetch_add(n as u64, Ordering::Relaxed);
                sh.stats
                    .hot_items
                    .fetch_add(n_hot as u64, Ordering::Relaxed);
                sh.stats
                    .peak_depth_items
                    .fetch_max(st.depth_items as u64, Ordering::Relaxed);
                sh.not_empty.notify_all();
                PublishOutcome::Enqueued
            }
        }
    }

    /// Pending item ids across both lanes (excludes the in-flight batch).
    pub fn depth(&self) -> usize {
        self.shared.state.lock().unwrap().depth_items
    }

    /// Age of the oldest pending (or in-flight) work, milliseconds.
    pub fn oldest_pending_ms(&self) -> f64 {
        let st = self.shared.state.lock().unwrap();
        st.oldest_at()
            .map(|at| at.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }

    /// When `id` was last made visible by the queue (unix ms).
    pub fn updated_at_ms(&self, id: u32) -> Option<u64> {
        self.stats.watermarks.updated_at_ms(id)
    }

    /// Block until every pending event has been applied (tests/benches;
    /// returns immediately once the queue is idle).
    pub fn flush(&self) {
        let tick = Duration::from_millis(50);
        let mut st = self.shared.state.lock().unwrap();
        while st.has_work() || st.in_flight_since.is_some() {
            let (g, _) = self.shared.idle.wait_timeout(st, tick).unwrap();
            st = g;
        }
    }

    fn begin_shutdown(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Drain pending events, stop the thread, and join it.  Idempotent;
    /// usable through an `Arc` (unlike the consuming [`Self::shutdown`]).
    pub fn stop(&self) {
        self.begin_shutdown();
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    pub fn shutdown(self) {
        self.stop();
    }

    /// Counters + gauges for `/metrics` (one short lock for the gauges).
    pub fn stats_snapshot(&self) -> Object {
        let s = &self.stats;
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut o = Object::new();
        let (depth, oldest_ms) = {
            let st = self.shared.state.lock().unwrap();
            (
                st.depth_items,
                st.oldest_at()
                    .map(|at| at.elapsed().as_secs_f64() * 1e3)
                    .unwrap_or(0.0),
            )
        };
        o.insert("depth_items", depth);
        o.insert("oldest_pending_ms", oldest_ms);
        o.insert("capacity_items", self.shared.cfg.queue_capacity);
        o.insert(
            "policy",
            match self.shared.cfg.policy {
                BackpressurePolicy::Block => "block",
                BackpressurePolicy::Reject => "reject",
            },
        );
        o.insert("enqueued_events", ld(&s.enqueued_events));
        o.insert("enqueued_items", ld(&s.enqueued_items));
        o.insert("hot_items", ld(&s.hot_items));
        o.insert("rejected_items", ld(&s.rejected_items));
        o.insert("blocked_publishes", ld(&s.blocked_publishes));
        o.insert("peak_depth_items", ld(&s.peak_depth_items));
        o.insert("coalesced_items", ld(&s.coalesced_items));
        o.insert("applied_items", ld(&s.applied_items));
        o.insert("applied_batches", ld(&s.applied_batches));
        o.insert("full_rebuilds", ld(&s.full_rebuilds));
        o.insert("failed_full_builds", ld(&s.failed_full_builds));
        o.insert("subsumed_items", ld(&s.subsumed_items));
        o.insert("retried_batches", ld(&s.retried_batches));
        o.insert("requeued_items", ld(&s.requeued_items));
        o.insert("failed_updates", ld(&s.failed_updates));
        o.insert("compactions", ld(&s.compactions));
        o.insert("compact_bytes_reclaimed", ld(&s.compact_bytes_reclaimed));
        let mut lat = Object::new();
        lat.insert("count", s.apply_latency.count());
        lat.insert("mean_ms", s.apply_latency.mean() * 1e3);
        lat.insert("p99_ms", s.apply_latency.percentile(99.0) * 1e3);
        lat.insert("max_ms", s.apply_latency.max() * 1e3);
        o.insert("apply_latency", lat);
        let (n, oldest, newest) = s.watermarks.summary();
        let now = unix_ms();
        let mut wm = Object::new();
        wm.insert("items_updated", n);
        wm.insert(
            "oldest_update_age_ms",
            if n == 0 { 0 } else { now.saturating_sub(oldest) },
        );
        wm.insert(
            "newest_update_age_ms",
            if n == 0 { 0 } else { now.saturating_sub(newest) },
        );
        o.insert("updated_at", wm);
        o
    }
}

impl Drop for UpdateQueue {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Take the next unit of work.  Swap first (it subsumes); otherwise pop
/// hot-lane events, then cold, coalescing ids until `max_batch`.
fn take_work(st: &mut Lanes, max_batch: usize, stats: &QueueStats) -> Work {
    if let Some((version, at, attempts)) = st.swap.take() {
        st.in_flight_since = Some(at);
        return Work::Swap {
            version,
            at,
            attempts,
            cut_hot: st.hot.len(),
            cut_cold: st.cold.len(),
        };
    }
    let mut ids: BTreeSet<u32> = BTreeSet::new();
    let mut events: Vec<(Instant, u32)> = Vec::new();
    let mut popped_items = 0usize;
    let mut earliest: Option<Instant> = None;
    let mut max_attempts = 0u32;
    while ids.len() < max_batch {
        let p = match st.hot.pop_front().or_else(|| st.cold.pop_front()) {
            Some(p) => p,
            None => break,
        };
        popped_items += p.ids.len();
        ids.extend(&p.ids);
        earliest = Some(earliest.map_or(p.at, |e: Instant| e.min(p.at)));
        max_attempts = max_attempts.max(p.attempts);
        events.push((p.at, p.attempts));
    }
    st.depth_items -= popped_items;
    let unique = ids.len();
    stats
        .coalesced_items
        .fetch_add((popped_items - unique) as u64, Ordering::Relaxed);
    let earliest = earliest.unwrap_or_else(Instant::now);
    st.in_flight_since = Some(earliest);
    Work::Incremental {
        ids: ids.into_iter().collect(),
        events,
        earliest,
        max_attempts,
    }
}

fn drain_loop(sh: &Shared, applier: &dyn UpdateApplier) {
    let stats = &sh.stats;
    let mut batches_since_compact = 0u64;
    loop {
        // Wait for work (or exit once shutdown has drained everything).
        let work = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.has_work() {
                    break;
                }
                if st.shutdown {
                    sh.idle.notify_all();
                    return;
                }
                st = sh.not_empty.wait(st).unwrap();
            }
            // Linger for batching: a timed condvar wait against the batch
            // deadline (not a sleep loop), cut short by a filling batch,
            // a pending swap, or shutdown (which drains at full speed).
            let linger =
                Duration::from_secs_f64(sh.cfg.linger_ms.max(0.0) / 1e3);
            if !st.shutdown && !linger.is_zero() && st.swap.is_none() {
                let deadline = Instant::now() + linger;
                while st.depth_items < sh.cfg.max_batch
                    && st.swap.is_none()
                    && !st.shutdown
                {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (g, t) = sh
                        .not_empty
                        .wait_timeout(st, deadline - now)
                        .unwrap();
                    st = g;
                    if t.timed_out() {
                        break;
                    }
                }
            }
            take_work(&mut st, sh.cfg.max_batch, stats)
        };

        match work {
            Work::Swap { version, at, attempts, cut_hot, cut_cold } => {
                match applier.apply_full(version) {
                    Ok(()) => {
                        stats.full_rebuilds.fetch_add(1, Ordering::Relaxed);
                        stats.apply_latency.record(at.elapsed());
                        let now = unix_ms();
                        let mut subsumed: Vec<u32> = Vec::new();
                        let mut st = sh.state.lock().unwrap();
                        // The rebuild recomputed the whole catalog, so
                        // every event enqueued before it started is done.
                        for _ in 0..cut_hot {
                            if let Some(p) = st.hot.pop_front() {
                                st.depth_items -= p.ids.len();
                                subsumed.extend(p.ids);
                            }
                        }
                        for _ in 0..cut_cold {
                            if let Some(p) = st.cold.pop_front() {
                                st.depth_items -= p.ids.len();
                                subsumed.extend(p.ids);
                            }
                        }
                        st.in_flight_since = None;
                        drop(st);
                        stats
                            .subsumed_items
                            .fetch_add(subsumed.len() as u64, Ordering::Relaxed);
                        stats.watermarks.note(&subsumed, now);
                    }
                    Err(e) => {
                        let mut st = sh.state.lock().unwrap();
                        st.in_flight_since = None;
                        if attempts < sh.cfg.retry_limit {
                            stats.retried_batches.fetch_add(1, Ordering::Relaxed);
                            // Keep the original timestamp: staleness is
                            // measured from first enqueue.
                            st.swap = Some(match st.swap.take() {
                                Some((v, _, a)) => {
                                    (v.max(version), at, a.max(attempts + 1))
                                }
                                None => (version, at, attempts + 1),
                            });
                            log::warn!(
                                "nearline full build failed \
                                 (attempt {}): {e:#}",
                                attempts + 1
                            );
                        } else {
                            stats.failed_full_builds.fetch_add(1, Ordering::Relaxed);
                            log::error!(
                                "nearline full build to version {version} \
                                 abandoned after {} attempts: {e:#}",
                                attempts + 1
                            );
                        }
                    }
                }
            }
            Work::Incremental { ids, events, earliest, max_attempts } => {
                if ids.is_empty() {
                    let mut st = sh.state.lock().unwrap();
                    st.in_flight_since = None;
                    continue;
                }
                let report = applier.apply_incremental(&ids);
                let failed: BTreeSet<u32> =
                    report.failed.iter().copied().collect();
                let applied: Vec<u32> = ids
                    .iter()
                    .copied()
                    .filter(|id| !failed.contains(id))
                    .collect();
                if !applied.is_empty() {
                    stats
                        .applied_items
                        .fetch_add(applied.len() as u64, Ordering::Relaxed);
                    stats.applied_batches.fetch_add(1, Ordering::Relaxed);
                    stats.watermarks.note(&applied, unix_ms());
                    for (at, _) in &events {
                        stats.apply_latency.record(at.elapsed());
                    }
                    batches_since_compact += 1;
                }
                if !failed.is_empty() {
                    let attempts = max_attempts + 1;
                    if attempts > sh.cfg.retry_limit {
                        stats
                            .failed_updates
                            .fetch_add(failed.len() as u64, Ordering::Relaxed);
                        log::error!(
                            "nearline incremental abandoned {} items \
                             after {attempts} attempts: {}",
                            failed.len(),
                            report
                                .last_error
                                .as_deref()
                                .unwrap_or("unknown error")
                        );
                    } else {
                        let failed: Vec<u32> = failed.into_iter().collect();
                        let n = failed.len();
                        stats.retried_batches.fetch_add(1, Ordering::Relaxed);
                        stats.requeued_items.fetch_add(n as u64, Ordering::Relaxed);
                        log::warn!(
                            "nearline incremental requeueing {n} items \
                             (attempt {attempts}): {}",
                            report
                                .last_error
                                .as_deref()
                                .unwrap_or("unknown error")
                        );
                        let mut st = sh.state.lock().unwrap();
                        // Front of the hot lane, original timestamp:
                        // retries are the oldest work we hold.  Requeue
                        // bypasses capacity — losing data to our own
                        // bound would defeat the retry.
                        st.hot.push_front(Pending {
                            ids: failed,
                            at: earliest,
                            attempts,
                        });
                        st.depth_items += n;
                    }
                }
                let mut st = sh.state.lock().unwrap();
                st.in_flight_since = None;
                drop(st);

                // Maintenance cadence: compaction + heat decay.
                if sh.cfg.compact_every > 0
                    && batches_since_compact >= sh.cfg.compact_every
                {
                    batches_since_compact = 0;
                    if let Some(r) = applier.compact() {
                        stats.compactions.fetch_add(1, Ordering::Relaxed);
                        stats
                            .compact_bytes_reclaimed
                            .fetch_add(r.bytes_reclaimed as u64, Ordering::Relaxed);
                    }
                    if let Some(h) = &sh.heat {
                        h.decay();
                    }
                }
            }
        }

        // Capacity freed / possibly idle: wake producers and flushers.
        let st = sh.state.lock().unwrap();
        let idle = !st.has_work() && st.in_flight_since.is_none();
        drop(st);
        sh.not_full.notify_all();
        if idle {
            sh.idle.notify_all();
        }
    }
}
