//! Incremental message queue (paper §4.2 "Update Methods": "we employ an
//! incremental message queue that dynamically processes updates, enabling
//! seamless integration of new entries without recalculating existing
//! signatures").
//!
//! A background thread drains events with batching (up to `max_batch` or
//! `linger`), coalesces duplicate item ids, and applies them through the
//! [`NearlineWorker`].

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::worker::NearlineWorker;

/// Nearline update triggers.
#[derive(Debug, Clone)]
pub enum UpdateEvent {
    /// Item feature change or brand-new item.
    ItemFeatures(Vec<u32>),
    /// Model checkpoint update -> full rebuild to `version`.
    ModelSwap { version: u64 },
    /// Drain & stop.
    Shutdown,
}

pub struct UpdateQueue {
    tx: Sender<UpdateEvent>,
    handle: Option<JoinHandle<()>>,
    pub incremental_updates: Arc<AtomicU64>,
    pub full_rebuilds: Arc<AtomicU64>,
}

impl UpdateQueue {
    pub fn start(
        worker: Arc<NearlineWorker>,
        max_batch: usize,
        linger: Duration,
    ) -> UpdateQueue {
        let (tx, rx) = channel::<UpdateEvent>();
        let incremental_updates = Arc::new(AtomicU64::new(0));
        let full_rebuilds = Arc::new(AtomicU64::new(0));
        let inc = Arc::clone(&incremental_updates);
        let full = Arc::clone(&full_rebuilds);
        let handle = std::thread::Builder::new()
            .name("aif-nearline-queue".into())
            .spawn(move || {
                let mut stop = false;
                while !stop {
                    // Block for the first event.
                    let first = match rx.recv() {
                        Ok(e) => e,
                        Err(_) => break,
                    };
                    let mut items: BTreeSet<u32> = BTreeSet::new();
                    let mut model_swap: Option<u64> = None;
                    let mut absorb = |e: UpdateEvent,
                                      items: &mut BTreeSet<u32>,
                                      stop: &mut bool| {
                        match e {
                            UpdateEvent::ItemFeatures(ids) => {
                                items.extend(ids);
                            }
                            UpdateEvent::ModelSwap { version } => {
                                model_swap = Some(
                                    model_swap.map_or(version, |v| {
                                        v.max(version)
                                    }),
                                );
                            }
                            UpdateEvent::Shutdown => *stop = true,
                        }
                    };
                    absorb(first, &mut items, &mut stop);
                    // Linger to batch bursts.
                    let deadline = Instant::now() + linger;
                    while items.len() < max_batch && !stop {
                        match rx.try_recv() {
                            Ok(e) => absorb(e, &mut items, &mut stop),
                            Err(TryRecvError::Empty) => {
                                if Instant::now() >= deadline {
                                    break;
                                }
                                std::thread::sleep(Duration::from_micros(
                                    200,
                                ));
                            }
                            Err(TryRecvError::Disconnected) => {
                                stop = true;
                            }
                        }
                    }
                    // A model swap subsumes incremental work.
                    if let Some(version) = model_swap {
                        if let Err(e) = worker.full_build(version) {
                            log::error!("nearline full build failed: {e:#}");
                        } else {
                            full.fetch_add(1, Ordering::Relaxed);
                        }
                    } else if !items.is_empty() {
                        let ids: Vec<u32> = items.into_iter().collect();
                        match worker.incremental(&ids) {
                            Ok(n) => {
                                inc.fetch_add(n as u64, Ordering::Relaxed);
                            }
                            Err(e) => log::error!(
                                "nearline incremental failed: {e:#}"
                            ),
                        }
                    }
                }
            })
            .expect("spawn nearline queue");
        UpdateQueue {
            tx,
            handle: Some(handle),
            incremental_updates,
            full_rebuilds,
        }
    }

    pub fn publish(&self, event: UpdateEvent) {
        let _ = self.tx.send(event);
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(UpdateEvent::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for UpdateQueue {
    fn drop(&mut self) {
        let _ = self.tx.send(UpdateEvent::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
