//! N2O index table — the nearline item-side result store (paper §3.2/§3.4).
//!
//! Holds, per item: the compressed item vector (Eq.4), the BEA item-side
//! attention weights (Alg.1 step 3) and the packed LSH signature (Eq.5).
//! Supports **full** rebuilds (model update -> new generation, atomic swap)
//! and **incremental** updates (item feature changes / new items -> row
//! upserts), mirroring the paper's "index table for N2O that supports
//! both full and incremental updates ... updated synchronously whenever the
//! original item feature index table undergoes full or incremental updates".
//!
//! Storage is **columnar** (DESIGN.md §14): one generation holds
//! contiguous `item_vec` / `bea_w` / `sign_packed` matrices indexed by
//! item id, split into fixed-size column chunks, each behind its own
//! `Arc`.  Candidate gathers are `copy_from_slice` out of flat memory —
//! no per-row `Vec`s exist anywhere — and an incremental upsert
//! copy-on-writes only the touched chunks: untouched chunks are shared by
//! pointer between the old and new generation.  A request pins one
//! [`N2oSnapshot`] (one lock acquisition, counted) and gathers all its
//! mini-batches from that immutable view.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::cache::ArenaPool;
use crate::runtime::Tensor;
use crate::util::bits;

/// Items per column chunk (the copy-on-write granularity of `upsert`,
/// and the unit of snapshot/delta serialization in `storage::snapshot`).
pub const N2O_CHUNK: usize = 512;

/// One item's nearline-computed row — the upsert/rebuild currency.  The
/// table stores rows columnar; this owned form only exists at the
/// nearline-worker boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct N2oEntry {
    pub item_vec: Vec<f32>,
    pub bea_w: Vec<f32>,
    pub sign_packed: Vec<u8>,
}

impl N2oEntry {
    pub fn size_bytes(&self) -> usize {
        self.item_vec.len() * 4 + self.bea_w.len() * 4 + self.sign_packed.len()
    }
}

/// Borrowed view of one item's row inside a generation's column chunks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct N2oRow<'a> {
    pub item_vec: &'a [f32],
    pub bea_w: &'a [f32],
    pub sign_packed: &'a [u8],
}

impl N2oRow<'_> {
    /// Owned copy (tests / debugging; the serving path never needs one).
    pub fn to_entry(&self) -> N2oEntry {
        N2oEntry {
            item_vec: self.item_vec.to_vec(),
            bea_w: self.bea_w.to_vec(),
            sign_packed: self.sign_packed.to_vec(),
        }
    }
}

/// One columnar chunk of up to [`N2O_CHUNK`] items.
#[derive(Debug, Clone)]
struct Chunk {
    item_vec: Vec<f32>,   // [N2O_CHUNK, d] row-major
    bea_w: Vec<f32>,      // [N2O_CHUNK, n_bridge]
    sign_packed: Vec<u8>, // [N2O_CHUNK, pl]
    present: Vec<bool>,   // [N2O_CHUNK]
}

impl Chunk {
    fn empty(d: usize, n_bridge: usize, pl: usize) -> Chunk {
        Chunk {
            item_vec: vec![0.0; N2O_CHUNK * d],
            bea_w: vec![0.0; N2O_CHUNK * n_bridge],
            sign_packed: vec![0; N2O_CHUNK * pl],
            present: vec![false; N2O_CHUNK],
        }
    }

    fn write(
        &mut self,
        off: usize,
        e: &N2oEntry,
        d: usize,
        n_bridge: usize,
        pl: usize,
    ) {
        assert_eq!(e.item_vec.len(), d, "item_vec width mismatch");
        assert_eq!(e.bea_w.len(), n_bridge, "bea_w width mismatch");
        assert_eq!(e.sign_packed.len(), pl, "sign_packed width mismatch");
        self.item_vec[off * d..(off + 1) * d].copy_from_slice(&e.item_vec);
        self.bea_w[off * n_bridge..(off + 1) * n_bridge]
            .copy_from_slice(&e.bea_w);
        self.sign_packed[off * pl..(off + 1) * pl]
            .copy_from_slice(&e.sign_packed);
        self.present[off] = true;
    }
}

/// One immutable generation: chunked columnar matrices.
#[derive(Debug)]
struct Generation {
    chunks: Vec<Arc<Chunk>>,
    n_items: usize,
    version: u64,
}

/// Versioned, concurrently readable N2O table.
pub struct N2oTable {
    inner: RwLock<Arc<Generation>>,
    pub d: usize,
    pub n_bridge: usize,
    pub n_bits: usize,
    pub reads: AtomicU64,
    pub stale_reads: AtomicU64,
    /// Every acquisition of the generation lock (read or write).  The
    /// zero-copy contract is ONE per served request — the snapshot pin —
    /// asserted by the hot-path stress test.
    pub lock_acquisitions: AtomicU64,
    /// Subset of `lock_acquisitions` taken by maintenance paths that are
    /// NOT on behalf of a request: checkpoint exports, snapshot restores
    /// and delta replays.  `lock_acquisitions - maintenance_lock_acquisitions`
    /// is the request-attributable count, which lets the warm-restart
    /// bench assert the one-lock-per-request budget while a checkpointer
    /// runs concurrently.
    pub maintenance_lock_acquisitions: AtomicU64,
    /// Lock-free mirror of the current generation's version, kept in sync
    /// by `swap_full`.  The user-state cache folds this into its epoch on
    /// EVERY request, which must not cost a lock (the hot path's budget
    /// is one N2O lock per request: the snapshot pin).
    version_hint: AtomicU64,
}

impl N2oTable {
    pub fn new(n_items: usize, d: usize, n_bridge: usize, n_bits: usize) -> Self {
        let pl = n_bits.div_ceil(8);
        let n_chunks = n_items.div_ceil(N2O_CHUNK).max(1);
        let empty = Arc::new(Chunk::empty(d, n_bridge, pl));
        N2oTable {
            inner: RwLock::new(Arc::new(Generation {
                // All-absent chunks share ONE zeroed allocation until a
                // write materializes them.
                chunks: vec![empty; n_chunks],
                n_items,
                version: 0,
            })),
            d,
            n_bridge,
            n_bits,
            reads: AtomicU64::new(0),
            stale_reads: AtomicU64::new(0),
            lock_acquisitions: AtomicU64::new(0),
            maintenance_lock_acquisitions: AtomicU64::new(0),
            version_hint: AtomicU64::new(0),
        }
    }

    fn packed_len(&self) -> usize {
        self.n_bits.div_ceil(8)
    }

    /// Pin the current generation (counted lock acquisition).
    fn read_gen(&self) -> Arc<Generation> {
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        Arc::clone(&self.inner.read().unwrap())
    }

    pub fn version(&self) -> u64 {
        self.read_gen().version
    }

    pub fn n_items(&self) -> usize {
        self.read_gen().n_items
    }

    /// Atomic full swap to a new generation (model update trigger).
    pub fn swap_full(&self, entries: Vec<Option<N2oEntry>>, version: u64) {
        let (d, n_bridge, pl) = (self.d, self.n_bridge, self.packed_len());
        let n_items = entries.len();
        let n_chunks = n_items.div_ceil(N2O_CHUNK).max(1);
        // All-absent ranges share ONE zeroed chunk (like `new`/`upsert`
        // extension), so a sparse rebuild doesn't resident-allocate a
        // zero-filled chunk per 512 absent items.
        let empty = Arc::new(Chunk::empty(d, n_bridge, pl));
        let mut chunks = Vec::with_capacity(n_chunks);
        for ci in 0..n_chunks {
            let base = ci * N2O_CHUNK;
            let mut chunk: Option<Chunk> = None;
            for off in 0..N2O_CHUNK.min(n_items - base) {
                if let Some(e) = &entries[base + off] {
                    chunk
                        .get_or_insert_with(|| {
                            Chunk::empty(d, n_bridge, pl)
                        })
                        .write(off, e, d, n_bridge, pl);
                }
            }
            chunks.push(match chunk {
                Some(c) => Arc::new(c),
                None => Arc::clone(&empty),
            });
        }
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.inner.write().unwrap();
        assert!(
            version > guard.version,
            "full swap must advance the version ({} -> {version})",
            guard.version
        );
        *guard = Arc::new(Generation {
            chunks,
            n_items,
            version,
        });
        // Published while the write lock is held, so the hint can never
        // lag behind a generation a reader could already observe.
        self.version_hint.store(version, Ordering::Release);
    }

    /// Current generation version without touching the lock (incremental
    /// upserts keep the version, so only `swap_full` moves this).
    pub fn version_hint(&self) -> u64 {
        self.version_hint.load(Ordering::Acquire)
    }

    /// Incremental upsert into the current generation (item feature update
    /// / new item from the message queue).  Copy-on-write at chunk
    /// granularity: only the chunks holding touched rows are cloned; the
    /// rest are shared by `Arc` with the previous generation, and readers
    /// holding the old snapshot are unaffected either way.
    pub fn upsert(&self, rows: Vec<(u32, N2oEntry)>) {
        self.upsert_rows(rows, false)
    }

    /// [`Self::upsert`] counted as a MAINTENANCE lock acquisition.  The
    /// streaming update queue applies its drained batches through this,
    /// so `lock_acquisitions - maintenance_lock_acquisitions` stays equal
    /// to the served-request count while churn runs concurrently.
    pub fn upsert_maintenance(&self, rows: Vec<(u32, N2oEntry)>) {
        self.upsert_rows(rows, true)
    }

    fn upsert_rows(&self, rows: Vec<(u32, N2oEntry)>, maintenance: bool) {
        if rows.is_empty() {
            return;
        }
        let (d, n_bridge, pl) = (self.d, self.n_bridge, self.packed_len());
        // Validate BEFORE taking the write lock: a malformed row must
        // panic the producer, not poison the generation lock and take
        // every future request on the table down with it (swap_full
        // likewise runs its width asserts pre-lock, in chunk building).
        for (id, e) in &rows {
            assert_eq!(e.item_vec.len(), d, "item {id}: item_vec width");
            assert_eq!(e.bea_w.len(), n_bridge, "item {id}: bea_w width");
            assert_eq!(e.sign_packed.len(), pl, "item {id}: sign width");
        }
        let max_id = rows.iter().map(|(i, _)| *i as usize).max().unwrap();
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        if maintenance {
            self.maintenance_lock_acquisitions
                .fetch_add(1, Ordering::Relaxed);
        }
        let mut guard = self.inner.write().unwrap();
        let mut chunks = guard.chunks.clone(); // Arc pointers only
        let mut n_items = guard.n_items;
        if max_id >= n_items {
            n_items = max_id + 1; // new items extend the table
            let empty = Arc::new(Chunk::empty(d, n_bridge, pl));
            while chunks.len() * N2O_CHUNK < n_items {
                chunks.push(Arc::clone(&empty));
            }
        }
        for (id, e) in &rows {
            let (ci, off) = (*id as usize / N2O_CHUNK, *id as usize % N2O_CHUNK);
            // First touch of a shared chunk deep-copies it; further rows
            // into the same chunk write in place.
            Arc::make_mut(&mut chunks[ci]).write(off, e, d, n_bridge, pl);
        }
        *guard = Arc::new(Generation {
            chunks,
            n_items,
            version: guard.version,
        });
    }

    /// Snapshot handle for consistent multi-row reads within one request.
    /// This is the request's ONE lock acquisition on the table.
    pub fn snapshot(&self) -> N2oSnapshot {
        self.reads.fetch_add(1, Ordering::Relaxed);
        N2oSnapshot {
            generation: self.read_gen(),
            d: self.d,
            n_bridge: self.n_bridge,
            n_bits: self.n_bits,
        }
    }

    /// Total resident bytes (the §5.3 storage comparison numerator).
    /// Columnar generations allocate whole chunks, so this counts the
    /// footprint of each DISTINCT chunk allocation (absent ranges share
    /// one zeroed chunk by `Arc` — counted once, like the memory is).
    pub fn size_bytes(&self) -> usize {
        let g = self.read_gen();
        let row = self.d * 4 + self.n_bridge * 4 + self.packed_len();
        let chunk_bytes = N2O_CHUNK * row + N2O_CHUNK; // + present flags
        let mut seen = std::collections::HashSet::new();
        g.chunks
            .iter()
            .filter(|c| seen.insert(Arc::as_ptr(c)))
            .count()
            * chunk_bytes
    }

    pub fn coverage(&self) -> f64 {
        let g = self.read_gen();
        let have: usize = g
            .chunks
            .iter()
            .map(|c| c.present.iter().filter(|&&p| p).count())
            .sum();
        have as f64 / g.n_items.max(1) as f64
    }

    /// Pin the current generation for serialization (checkpointing).
    /// Chunks are exposed in stable ascending item-id order, so two
    /// exports of the same generation serialize byte-identically.
    /// Counted as a MAINTENANCE lock acquisition: it shows up in
    /// `lock_acquisitions` (nothing touches the lock uncounted) but also
    /// in `maintenance_lock_acquisitions`, so request-budget assertions
    /// can subtract it out.
    pub fn export(&self) -> N2oExport {
        self.maintenance_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        N2oExport {
            generation: self.read_gen(),
            d: self.d,
            n_bridge: self.n_bridge,
            n_bits: self.n_bits,
        }
    }

    /// Install a deserialized generation (warm boot).  Unlike
    /// [`Self::swap_full`] this accepts `version == current` so a
    /// restore into a fresh table (version 0) or an idempotent re-restore
    /// is legal, and it resumes the epoch sequence by restoring the
    /// persisted `version_hint` instead of resetting it — a reset would
    /// silently un-invalidate `UserStateCache` entries keyed on the
    /// composed epoch.  `None` chunks are all-absent and share one zeroed
    /// allocation, like `new`/`swap_full`.
    pub fn restore(
        &self,
        chunks: Vec<Option<RestoredChunk>>,
        n_items: usize,
        version: u64,
        version_hint: u64,
    ) {
        let (d, n_bridge, pl) = (self.d, self.n_bridge, self.packed_len());
        assert!(
            chunks.len() * N2O_CHUNK >= n_items && !chunks.is_empty(),
            "restore: {} chunks cannot hold {} items",
            chunks.len(),
            n_items
        );
        assert!(
            version_hint >= version,
            "restore: version_hint {version_hint} behind version {version}"
        );
        let empty = Arc::new(Chunk::empty(d, n_bridge, pl));
        let chunks: Vec<Arc<Chunk>> = chunks
            .into_iter()
            .map(|rc| match rc {
                Some(rc) => Arc::new(rc.into_chunk(d, n_bridge, pl)),
                None => Arc::clone(&empty),
            })
            .collect();
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.maintenance_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.inner.write().unwrap();
        assert!(
            version >= guard.version,
            "restore must not rewind the version ({} -> {version})",
            guard.version
        );
        *guard = Arc::new(Generation {
            chunks,
            n_items,
            version,
        });
        self.version_hint.store(version_hint, Ordering::Release);
    }

    /// Apply per-chunk patches from a delta file (warm-boot replay).
    /// Keeps the generation version (deltas are keyed by the base full
    /// snapshot's version, like `upsert` keeps the version); extends the
    /// table when the delta grew it.
    pub fn patch_chunks(
        &self,
        n_items: usize,
        patches: Vec<(usize, RestoredChunk)>,
    ) {
        let (d, n_bridge, pl) = (self.d, self.n_bridge, self.packed_len());
        let patches: Vec<(usize, Arc<Chunk>)> = patches
            .into_iter()
            .map(|(ci, rc)| (ci, Arc::new(rc.into_chunk(d, n_bridge, pl))))
            .collect();
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.maintenance_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.inner.write().unwrap();
        let mut chunks = guard.chunks.clone(); // Arc pointers only
        let n_items = n_items.max(guard.n_items);
        let empty = Arc::new(Chunk::empty(d, n_bridge, pl));
        while chunks.len() * N2O_CHUNK < n_items {
            chunks.push(Arc::clone(&empty));
        }
        for (ci, chunk) in patches {
            assert!(ci < chunks.len(), "patch chunk {ci} out of range");
            chunks[ci] = chunk;
        }
        *guard = Arc::new(Generation {
            chunks,
            n_items,
            version: guard.version,
        });
    }

    /// Re-deduplicate all-absent chunks (maintenance-counted write lock).
    ///
    /// Long-running upsert streams fragment a generation: every table
    /// extension (`upsert` past the end, `patch_chunks`, `restore`)
    /// allocates its OWN zeroed chunk for the absent tail, so a process
    /// that keeps appending sparse ids accumulates distinct all-zero
    /// allocations that `size_bytes` (and the memory) pay for.  Compaction
    /// rewrites every all-absent chunk to point at ONE shared zeroed
    /// allocation.  Present chunks keep their exact `Arc` pointers — the
    /// checkpointer's `Arc::ptr_eq` delta diffing still sees them as
    /// unchanged — and the generation version does not move.  Absent rows
    /// are never readable (`get`/`assemble` check `present`), so swapping
    /// which zeroed allocation backs them is invisible to readers; old
    /// snapshots pin the old chunks until they drop, so reclamation is
    /// eventual, not immediate.
    pub fn compact(&self) -> CompactReport {
        let (d, n_bridge, pl) = (self.d, self.n_bridge, self.packed_len());
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.maintenance_lock_acquisitions
            .fetch_add(1, Ordering::Relaxed);
        let mut guard = self.inner.write().unwrap();
        let distinct = |cs: &[Arc<Chunk>]| {
            let mut seen = std::collections::HashSet::new();
            cs.iter().filter(|c| seen.insert(Arc::as_ptr(c))).count()
        };
        let distinct_before = distinct(&guard.chunks);
        let mut chunks = guard.chunks.clone(); // Arc pointers only
        // The first all-absent chunk becomes the canonical zero chunk;
        // every other all-absent chunk is redirected to it.
        let mut zero: Option<Arc<Chunk>> = None;
        let mut changed = false;
        for c in chunks.iter_mut() {
            if c.present.iter().any(|&p| p) {
                continue;
            }
            match &zero {
                None => zero = Some(Arc::clone(c)),
                Some(z) => {
                    if !Arc::ptr_eq(c, z) {
                        *c = Arc::clone(z);
                        changed = true;
                    }
                }
            }
        }
        let distinct_after = distinct(&chunks);
        if changed {
            *guard = Arc::new(Generation {
                chunks,
                n_items: guard.n_items,
                version: guard.version,
            });
        }
        let row = d * 4 + n_bridge * 4 + pl;
        let chunk_bytes = N2O_CHUNK * row + N2O_CHUNK;
        CompactReport {
            chunks: guard.chunks.len(),
            distinct_before,
            distinct_after,
            bytes_reclaimed: (distinct_before - distinct_after) * chunk_bytes,
        }
    }

    /// One maintenance-counted pin answering every `/metrics` question
    /// about the table, so stats polling never perturbs the
    /// request-attributable lock count.
    pub fn table_stats(&self) -> TableStats {
        self.maintenance_lock_acquisitions
            .fetch_add(1, Ordering::Relaxed);
        let g = self.read_gen();
        let mut seen = std::collections::HashSet::new();
        let distinct = g
            .chunks
            .iter()
            .filter(|c| seen.insert(Arc::as_ptr(c)))
            .count();
        let row = self.d * 4 + self.n_bridge * 4 + self.packed_len();
        let chunk_bytes = N2O_CHUNK * row + N2O_CHUNK;
        let present: usize = g
            .chunks
            .iter()
            .map(|c| c.present.iter().filter(|&&p| p).count())
            .sum();
        TableStats {
            version: g.version,
            n_items: g.n_items,
            chunks: g.chunks.len(),
            distinct_chunks: distinct,
            resident_bytes: distinct * chunk_bytes,
            coverage: present as f64 / g.n_items.max(1) as f64,
        }
    }
}

/// What [`N2oTable::compact`] did (counts are generation-chunk pointers;
/// reclamation of the old allocations is eventual — pinned snapshots keep
/// them alive until dropped).
#[derive(Debug, Clone, Copy)]
pub struct CompactReport {
    pub chunks: usize,
    pub distinct_before: usize,
    pub distinct_after: usize,
    pub bytes_reclaimed: usize,
}

/// Point-in-time table facts from one maintenance-counted pin.
#[derive(Debug, Clone, Copy)]
pub struct TableStats {
    pub version: u64,
    pub n_items: usize,
    pub chunks: usize,
    pub distinct_chunks: usize,
    pub resident_bytes: usize,
    pub coverage: f64,
}

/// Immutable view of one generation.
pub struct N2oSnapshot {
    generation: Arc<Generation>,
    d: usize,
    n_bridge: usize,
    n_bits: usize,
}

impl N2oSnapshot {
    pub fn version(&self) -> u64 {
        self.generation.version
    }

    /// Borrowed row view into the columnar chunks (no copy, no alloc).
    pub fn get(&self, item: u32) -> Option<N2oRow<'_>> {
        let i = item as usize;
        if i >= self.generation.n_items {
            return None;
        }
        let (ci, off) = (i / N2O_CHUNK, i % N2O_CHUNK);
        let c = &self.generation.chunks[ci];
        if !c.present[off] {
            return None;
        }
        let pl = self.n_bits.div_ceil(8);
        Some(N2oRow {
            item_vec: &c.item_vec[off * self.d..(off + 1) * self.d],
            bea_w: &c.bea_w
                [off * self.n_bridge..(off + 1) * self.n_bridge],
            sign_packed: &c.sign_packed[off * pl..(off + 1) * pl],
        })
    }

    /// Gather the head inputs for `items` into caller-provided flat
    /// buffers, padded to `batch` rows by repeating the last item:
    /// `vecs [batch*d]`, `ws [batch*n_bridge]`, `plane [batch*n_bits]`
    /// (±1 f32, unpacked straight from the packed column — no
    /// intermediate packed concatenation is built).  Returns None if any
    /// item is missing from this generation.
    fn gather_into(
        &self,
        items: &[u32],
        batch: usize,
        vecs: &mut Vec<f32>,
        ws: &mut Vec<f32>,
        plane: &mut Vec<f32>,
    ) -> Option<()> {
        assert!(!items.is_empty() && items.len() <= batch);
        vecs.clear();
        vecs.reserve(batch * self.d);
        ws.clear();
        ws.reserve(batch * self.n_bridge);
        plane.clear();
        plane.resize(batch * self.n_bits, 0.0);
        for (r, &it) in items.iter().enumerate() {
            let row = self.get(it)?;
            vecs.extend_from_slice(row.item_vec);
            ws.extend_from_slice(row.bea_w);
            bits::unpack_to_pm1(
                row.sign_packed,
                self.n_bits,
                &mut plane[r * self.n_bits..(r + 1) * self.n_bits],
            );
        }
        // Padding repeats the last real row.
        let last = self.get(items[items.len() - 1])?;
        for r in items.len()..batch {
            vecs.extend_from_slice(last.item_vec);
            ws.extend_from_slice(last.bea_w);
            bits::unpack_to_pm1(
                last.sign_packed,
                self.n_bits,
                &mut plane[r * self.n_bits..(r + 1) * self.n_bits],
            );
        }
        Some(())
    }

    /// Assemble the pre-rank head inputs for a mini-batch of items, padded
    /// to `batch` rows: (item_vec [B,D], bea_w [B,n], item_sign [B,bits]).
    /// Returns None if any item is missing from this generation (caller
    /// falls back to inline computation or errors).
    pub fn assemble(
        &self,
        items: &[u32],
        batch: usize,
    ) -> Option<(Tensor, Tensor, Tensor)> {
        self.assemble_opt(items, batch, None)
    }

    /// [`Self::assemble`] into arena-pooled tensors — the zero-copy hot
    /// path.  Bitwise-identical output (property-tested); the buffers
    /// return to `arena` when the RTP call retires.
    pub fn assemble_in(
        &self,
        items: &[u32],
        batch: usize,
        arena: &Arc<ArenaPool>,
    ) -> Option<(Tensor, Tensor, Tensor)> {
        self.assemble_opt(items, batch, Some(arena))
    }

    /// The single pooled-vs-owned dispatch behind [`Self::assemble`] /
    /// [`Self::assemble_in`] (call sites with an `Option` in hand use
    /// this directly).
    pub fn assemble_opt(
        &self,
        items: &[u32],
        batch: usize,
        arena: Option<&Arc<ArenaPool>>,
    ) -> Option<(Tensor, Tensor, Tensor)> {
        match arena {
            Some(a) => {
                let mut vecs = a.get(batch * self.d);
                let mut ws = a.get(batch * self.n_bridge);
                let mut plane = a.get(batch * self.n_bits);
                self.gather_into(
                    items, batch, &mut vecs, &mut ws, &mut plane,
                )?;
                Some((
                    Tensor::from_pooled(vec![batch, self.d], vecs),
                    Tensor::from_pooled(vec![batch, self.n_bridge], ws),
                    Tensor::from_pooled(vec![batch, self.n_bits], plane),
                ))
            }
            None => {
                let mut vecs = Vec::new();
                let mut ws = Vec::new();
                let mut plane = Vec::new();
                self.gather_into(
                    items, batch, &mut vecs, &mut ws, &mut plane,
                )?;
                Some((
                    Tensor::new(vec![batch, self.d], vecs),
                    Tensor::new(vec![batch, self.n_bridge], ws),
                    Tensor::new(vec![batch, self.n_bits], plane),
                ))
            }
        }
    }
}

/// Pinned generation view for serialization.  Iteration over
/// [`Self::chunk`] 0..n_chunks is the table's stable order: ascending
/// item id, `N2O_CHUNK` items per chunk.
pub struct N2oExport {
    generation: Arc<Generation>,
    d: usize,
    n_bridge: usize,
    n_bits: usize,
}

/// Borrowed columnar view of one chunk, exactly as resident in memory.
#[derive(Clone, Copy)]
pub struct N2oChunkView<'a> {
    pub item_vec: &'a [f32],
    pub bea_w: &'a [f32],
    pub sign_packed: &'a [u8],
    pub present: &'a [bool],
}

impl N2oChunkView<'_> {
    pub fn any_present(&self) -> bool {
        self.present.iter().any(|&p| p)
    }
}

impl N2oExport {
    pub fn version(&self) -> u64 {
        self.generation.version
    }

    pub fn n_items(&self) -> usize {
        self.generation.n_items
    }

    pub fn n_chunks(&self) -> usize {
        self.generation.chunks.len()
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.d, self.n_bridge, self.n_bits)
    }

    pub fn chunk(&self, i: usize) -> N2oChunkView<'_> {
        let c = &self.generation.chunks[i];
        N2oChunkView {
            item_vec: &c.item_vec,
            bea_w: &c.bea_w,
            sign_packed: &c.sign_packed,
            present: &c.present,
        }
    }

    /// True when chunk `i` is the SAME allocation in both exports
    /// (copy-on-write upserts share untouched chunks by `Arc`).  The
    /// checkpointer uses this to emit per-chunk deltas: only chunks whose
    /// pointer changed since the last published snapshot are rewritten.
    pub fn chunk_shared_with(&self, other: &N2oExport, i: usize) -> bool {
        match (self.generation.chunks.get(i), other.generation.chunks.get(i)) {
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Owned columnar chunk deserialized from a snapshot or delta file —
/// the restore-side mirror of [`N2oChunkView`].
pub struct RestoredChunk {
    pub item_vec: Vec<f32>,
    pub bea_w: Vec<f32>,
    pub sign_packed: Vec<u8>,
    pub present: Vec<bool>,
}

impl RestoredChunk {
    fn into_chunk(self, d: usize, n_bridge: usize, pl: usize) -> Chunk {
        assert_eq!(self.item_vec.len(), N2O_CHUNK * d, "item_vec size");
        assert_eq!(self.bea_w.len(), N2O_CHUNK * n_bridge, "bea_w size");
        assert_eq!(self.sign_packed.len(), N2O_CHUNK * pl, "sign size");
        assert_eq!(self.present.len(), N2O_CHUNK, "present size");
        Chunk {
            item_vec: self.item_vec,
            bea_w: self.bea_w,
            sign_packed: self.sign_packed,
            present: self.present,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(v: f32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![v; 4],
            bea_w: vec![v; 2],
            sign_packed: vec![0b1010_0101],
        }
    }

    #[test]
    fn full_swap_advances_version() {
        let t = N2oTable::new(4, 4, 2, 8);
        assert_eq!(t.version(), 0);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        assert_eq!(t.version(), 1);
        assert_eq!(t.coverage(), 1.0);
    }

    #[test]
    #[should_panic(expected = "advance")]
    fn full_swap_rejects_stale_version() {
        let t = N2oTable::new(2, 4, 2, 8);
        t.swap_full(vec![None, None], 3);
        t.swap_full(vec![None, None], 2);
    }

    #[test]
    fn snapshot_is_isolated_from_upserts() {
        let t = N2oTable::new(3, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 3], 1);
        let snap = t.snapshot();
        t.upsert(vec![(0, entry(9.0))]);
        // Old snapshot still sees the old row.
        assert_eq!(snap.get(0).unwrap().item_vec[0], 1.0);
        // New snapshot sees the update.
        assert_eq!(t.snapshot().get(0).unwrap().item_vec[0], 9.0);
    }

    #[test]
    fn upsert_extends_for_new_items() {
        let t = N2oTable::new(2, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 2], 1);
        t.upsert(vec![(5, entry(2.0))]); // new item id beyond table
        assert_eq!(t.n_items(), 6);
        assert_eq!(t.snapshot().get(5).unwrap().item_vec[0], 2.0);
        // Ids between the old bound and the new row are absent, not junk.
        assert!(t.snapshot().get(3).is_none());
    }

    #[test]
    fn upsert_extends_across_chunk_boundaries() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        let far = (2 * N2O_CHUNK + 7) as u32;
        t.upsert(vec![(far, entry(3.0))]);
        assert_eq!(t.n_items(), far as usize + 1);
        let snap = t.snapshot();
        assert_eq!(snap.get(far).unwrap().item_vec[0], 3.0);
        assert_eq!(snap.get(0).unwrap().item_vec[0], 1.0);
        assert!(snap.get(N2O_CHUNK as u32).is_none());
    }

    #[test]
    fn upsert_copies_only_touched_chunks() {
        let n = 3 * N2O_CHUNK;
        let t = N2oTable::new(n, 4, 2, 8);
        t.swap_full((0..n).map(|_| Some(entry(1.0))).collect(), 1);
        let before = t.snapshot();
        t.upsert(vec![(0, entry(2.0))]); // touches chunk 0 only
        let after = t.snapshot();
        // Untouched chunks are the SAME allocation (shared by Arc) —
        // copy-on-write at chunk granularity.
        assert!(!std::ptr::eq(
            before.generation.chunks[0].as_ref(),
            after.generation.chunks[0].as_ref()
        ));
        for ci in 1..3 {
            assert!(
                std::ptr::eq(
                    before.generation.chunks[ci].as_ref(),
                    after.generation.chunks[ci].as_ref()
                ),
                "chunk {ci} must be shared, not copied"
            );
        }
    }

    /// Entry whose item_vec encodes (writer tag, item id) so readers can
    /// tell exactly which write produced a row.
    fn tagged(tag: f32, id: u32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![tag, id as f32, 0.0, 0.0],
            bea_w: vec![tag; 2],
            sign_packed: vec![id as u8],
        }
    }

    #[test]
    fn upserts_after_swap_are_never_lost() {
        // Deterministic phase ordering via barriers: pre-swap upserts,
        // the atomic generation swap, post-swap upserts.  The final table
        // must carry every post-swap row — "no lost rows across the
        // swap" — and the swap must wipe pre-swap rows wholesale (a full
        // rebuild recomputes everything).
        use std::sync::Barrier;
        let n = 64usize;
        let t = Arc::new(N2oTable::new(n, 4, 2, 8));
        t.swap_full((0..n).map(|i| Some(tagged(0.0, i as u32))).collect(), 1);

        let barrier = Arc::new(Barrier::new(2));
        let writer = {
            let t = Arc::clone(&t);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                for i in 0..n as u32 {
                    t.upsert(vec![(i, tagged(1.0, i))]); // pre-swap
                }
                barrier.wait(); // swapper goes
                barrier.wait(); // swap done
                for i in 0..n as u32 {
                    t.upsert(vec![(i, tagged(3.0, i))]); // post-swap
                }
            })
        };
        barrier.wait();
        t.swap_full(
            (0..n).map(|i| Some(tagged(2.0, i as u32))).collect(),
            2,
        );
        barrier.wait();
        writer.join().unwrap();

        assert_eq!(t.version(), 2);
        let snap = t.snapshot();
        for i in 0..n as u32 {
            let e = snap.get(i).expect("no holes after the swap");
            assert_eq!(
                e.item_vec[0], 3.0,
                "item {i}: post-swap upsert was lost"
            );
            assert_eq!(e.item_vec[1], i as f32);
        }
    }

    #[test]
    fn concurrent_upserts_racing_full_rebuild_stay_consistent() {
        // Chaos phase: writers upsert while another thread swaps to a new
        // generation; readers snapshot continuously.  Invariants that
        // must hold under ANY interleaving: versions never decrease, rows
        // are never torn (tag and id always agree), and no row is ever
        // missing.
        let n = 32usize;
        let t = Arc::new(N2oTable::new(n, 4, 2, 8));
        t.swap_full((0..n).map(|i| Some(tagged(0.0, i as u32))).collect(), 1);

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for w in 0..2u32 {
            let t = Arc::clone(&t);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let mut round = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    for i in (w % 2..n as u32).step_by(2) {
                        t.upsert(vec![(i, tagged(1.0 + round as f32, i))]);
                    }
                    round += 1;
                }
            }));
        }
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut last_version = 0u64;
                    let mut checked = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = t.snapshot();
                        assert!(
                            snap.version() >= last_version,
                            "version moved backwards: {} -> {}",
                            last_version,
                            snap.version()
                        );
                        last_version = snap.version();
                        for i in 0..n as u32 {
                            let e = snap
                                .get(i)
                                .expect("row vanished mid-flight");
                            // Rows swap atomically: the id channel always
                            // matches, whatever generation or writer won.
                            assert_eq!(e.item_vec[1], i as f32);
                            assert_eq!(e.sign_packed[0], i as u8);
                            checked += 1;
                        }
                    }
                    checked
                })
            })
            .collect();

        // Two racing generation swaps while the writers hammer away.
        for v in 2..4u64 {
            std::thread::sleep(std::time::Duration::from_millis(20));
            t.swap_full(
                (0..n).map(|i| Some(tagged(100.0, i as u32))).collect(),
                v,
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        for r in readers {
            assert!(r.join().unwrap() > 0, "readers actually ran");
        }
        assert_eq!(t.version(), 3);
        // Coverage never regressed: every row still present.
        assert_eq!(t.coverage(), 1.0);
    }

    #[test]
    fn assemble_pads_and_unpacks() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)), Some(entry(2.0)), None, None], 1);
        let snap = t.snapshot();
        let (v, w, s) = snap.assemble(&[0, 1], 3).unwrap();
        assert_eq!(v.shape, vec![3, 4]);
        assert_eq!(w.shape, vec![3, 2]);
        assert_eq!(s.shape, vec![3, 8]);
        assert_eq!(v.row(2), v.row(1), "padding repeats last row");
        // 0b1010_0101 little-endian bit order -> +1,-1,+1,-1,-1,+1,-1,+1
        assert_eq!(s.row(0), &[1., -1., 1., -1., -1., 1., -1., 1.]);
        // Missing item -> None.
        assert!(snap.assemble(&[0, 2], 2).is_none());
    }

    #[test]
    fn assemble_in_is_pooled_and_bitwise_identical() {
        let t = N2oTable::new(8, 4, 2, 8);
        t.swap_full(
            (0..8).map(|i| Some(tagged(0.5, i as u32))).collect(),
            1,
        );
        let arena = ArenaPool::new(8);
        let snap = t.snapshot();
        let owned = snap.assemble(&[1, 4, 6], 5).unwrap();
        let pooled = snap.assemble_in(&[1, 4, 6], 5, &arena).unwrap();
        assert!(pooled.0.is_pooled() && pooled.1.is_pooled());
        assert_eq!(owned.0, pooled.0);
        assert_eq!(owned.1, pooled.1);
        assert_eq!(owned.2, pooled.2);
        drop(pooled);
        assert_eq!(arena.outstanding(), 0, "buffers returned on drop");
        // A missing item must not leak the partially filled buffers.
        let t2 = N2oTable::new(4, 4, 2, 8);
        t2.swap_full(vec![Some(entry(1.0)), None, None, None], 1);
        assert!(t2
            .snapshot()
            .assemble_in(&[0, 1], 2, &arena)
            .is_none());
        assert_eq!(arena.outstanding(), 0);
    }

    #[test]
    fn one_lock_acquisition_per_snapshot() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        let before = t.lock_acquisitions.load(Ordering::Relaxed);
        let snap = t.snapshot();
        // Gathers and row reads run on the pinned generation: no further
        // lock traffic however many mini-batches a request assembles.
        for _ in 0..10 {
            let _ = snap.assemble(&[0, 1, 2, 3], 4).unwrap();
            let _ = snap.get(2).unwrap();
        }
        assert_eq!(
            t.lock_acquisitions.load(Ordering::Relaxed),
            before + 1,
            "one lock acquisition per request-pinned snapshot"
        );
    }

    #[test]
    fn export_counts_as_maintenance_lock() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        let total = t.lock_acquisitions.load(Ordering::Relaxed);
        let maint = t.maintenance_lock_acquisitions.load(Ordering::Relaxed);
        let _ex = t.export();
        assert_eq!(t.lock_acquisitions.load(Ordering::Relaxed), total + 1);
        assert_eq!(
            t.maintenance_lock_acquisitions.load(Ordering::Relaxed),
            maint + 1,
            "export must be attributable to maintenance"
        );
    }

    #[test]
    fn export_shares_untouched_chunks_across_upsert() {
        let n = 2 * N2O_CHUNK;
        let t = N2oTable::new(n, 4, 2, 8);
        t.swap_full((0..n).map(|_| Some(entry(1.0))).collect(), 1);
        let before = t.export();
        t.upsert(vec![(0, entry(2.0))]);
        let after = t.export();
        assert!(!before.chunk_shared_with(&after, 0));
        assert!(before.chunk_shared_with(&after, 1));
    }

    #[test]
    fn restore_resumes_version_hint_sequence() {
        let src = N2oTable::new(4, 4, 2, 8);
        src.swap_full(vec![Some(entry(1.0)); 4], 7);
        let ex = src.export();
        let dst = N2oTable::new(4, 4, 2, 8);
        let chunks = (0..ex.n_chunks())
            .map(|i| {
                let c = ex.chunk(i);
                Some(RestoredChunk {
                    item_vec: c.item_vec.to_vec(),
                    bea_w: c.bea_w.to_vec(),
                    sign_packed: c.sign_packed.to_vec(),
                    present: c.present.to_vec(),
                })
            })
            .collect();
        dst.restore(chunks, ex.n_items(), ex.version(), src.version_hint());
        assert_eq!(dst.version(), 7);
        assert_eq!(dst.version_hint(), 7, "epoch sequence resumes");
        assert_eq!(
            dst.snapshot().get(2).unwrap().to_entry(),
            src.snapshot().get(2).unwrap().to_entry()
        );
        // A subsequent rebuild continues past the restored version.
        dst.swap_full(vec![Some(entry(3.0)); 4], 8);
        assert_eq!(dst.version_hint(), 8);
    }

    #[test]
    fn patch_chunks_applies_delta_and_extends() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 3);
        let pl = 1;
        let mut patched = Chunk::empty(4, 2, pl);
        patched.write(1, &entry(9.0), 4, 2, pl);
        let rc = RestoredChunk {
            item_vec: patched.item_vec.clone(),
            bea_w: patched.bea_w.clone(),
            sign_packed: patched.sign_packed.clone(),
            present: patched.present.clone(),
        };
        // Patch chunk 2 with a larger n_items: extends through chunk 2.
        t.patch_chunks(2 * N2O_CHUNK + 10, vec![(2, rc)]);
        assert_eq!(t.version(), 3, "delta replay keeps the version");
        assert_eq!(t.n_items(), 2 * N2O_CHUNK + 10);
        let snap = t.snapshot();
        let id = (2 * N2O_CHUNK + 1) as u32;
        assert_eq!(snap.get(id).unwrap().item_vec[0], 9.0);
        assert!(snap.get((2 * N2O_CHUNK) as u32).is_none());
        assert_eq!(snap.get(0).unwrap().item_vec[0], 1.0);
    }

    #[test]
    fn maintenance_upsert_is_maintenance_counted() {
        let t = N2oTable::new(4, 4, 2, 8);
        let base = t.lock_acquisitions.load(Ordering::Relaxed);
        let base_m = t.maintenance_lock_acquisitions.load(Ordering::Relaxed);
        t.upsert_maintenance(vec![(0, entry(1.0))]);
        assert_eq!(t.lock_acquisitions.load(Ordering::Relaxed), base + 1);
        assert_eq!(
            t.maintenance_lock_acquisitions.load(Ordering::Relaxed),
            base_m + 1,
            "queue-driven upserts must not count against the request budget"
        );
        // The legacy path stays request-attributable.
        t.upsert(vec![(1, entry(2.0))]);
        assert_eq!(t.lock_acquisitions.load(Ordering::Relaxed), base + 2);
        assert_eq!(
            t.maintenance_lock_acquisitions.load(Ordering::Relaxed),
            base_m + 1
        );
    }

    #[test]
    fn sparse_extension_fragments_and_compact_rededups() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        // Each extending upsert allocates its own zeroed tail chunk, so a
        // long sparse append stream fragments the generation.
        let k_rounds = 12u32;
        for k in 1..=k_rounds {
            let id = 2 * k * N2O_CHUNK as u32;
            t.upsert(vec![(id, entry(k as f32))]);
        }
        let stats = t.table_stats();
        assert!(
            stats.distinct_chunks > 4,
            "expected fragmentation, got {} distinct chunks",
            stats.distinct_chunks
        );
        let bytes_before = t.size_bytes();

        let report = t.compact();
        assert_eq!(report.distinct_before, stats.distinct_chunks);
        assert!(report.distinct_after < report.distinct_before);
        assert!(report.bytes_reclaimed > 0);
        // Exactly one zero allocation remains: distinct = present chunks
        // (chunk 0 + one per written id) + 1 shared zero chunk.
        assert_eq!(report.distinct_after, k_rounds as usize + 2);
        assert!(t.size_bytes() < bytes_before);

        // Content and version are untouched.
        assert_eq!(t.version(), 1);
        let snap = t.snapshot();
        assert_eq!(snap.get(0).unwrap().item_vec[0], 1.0);
        for k in 1..=k_rounds {
            let id = 2 * k * N2O_CHUNK as u32;
            assert_eq!(snap.get(id).unwrap().item_vec[0], k as f32);
            assert!(snap.get(id - 1).is_none(), "absent rows stay absent");
        }
        // Idempotent: a second compaction finds nothing to reclaim.
        let again = t.compact();
        assert_eq!(again.bytes_reclaimed, 0);
        assert_eq!(again.distinct_after, report.distinct_after);
    }

    #[test]
    fn compact_preserves_present_chunk_pointers() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        t.upsert(vec![(4 * N2O_CHUNK as u32, entry(2.0))]);
        t.upsert(vec![(8 * N2O_CHUNK as u32, entry(3.0))]);
        let before = t.export();
        t.compact();
        let after = t.export();
        assert_eq!(before.n_chunks(), after.n_chunks());
        for ci in [0usize, 4, 8] {
            // Present chunks keep their exact allocation: the checkpoint
            // delta differ (Arc::ptr_eq) must see them as unchanged.
            assert!(
                before.chunk_shared_with(&after, ci),
                "compaction must not reallocate present chunk {ci}"
            );
        }
    }
}
