//! N2O index table — the nearline item-side result store (paper §3.2/§3.4).
//!
//! Holds, per item: the compressed item vector (Eq.4), the BEA item-side
//! attention weights (Alg.1 step 3) and the packed LSH signature (Eq.5).
//! Supports **full** rebuilds (model update -> new generation, atomic swap)
//! and **incremental** updates (item feature changes / new items -> in-place
//! row upserts), mirroring the paper's "index table for N2O that supports
//! both full and incremental updates ... updated synchronously whenever the
//! original item feature index table undergoes full or incremental updates".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::lsh;
use crate::runtime::Tensor;

/// One item's nearline-computed row.
#[derive(Debug, Clone, PartialEq)]
pub struct N2oEntry {
    pub item_vec: Vec<f32>,
    pub bea_w: Vec<f32>,
    pub sign_packed: Vec<u8>,
}

impl N2oEntry {
    pub fn size_bytes(&self) -> usize {
        self.item_vec.len() * 4 + self.bea_w.len() * 4 + self.sign_packed.len()
    }
}

/// One immutable generation of the table.
#[derive(Debug)]
struct Generation {
    /// Dense by item id; None = not yet computed for this generation.
    entries: Vec<Option<N2oEntry>>,
    version: u64,
}

/// Versioned, concurrently readable N2O table.
pub struct N2oTable {
    inner: RwLock<Arc<Generation>>,
    pub d: usize,
    pub n_bridge: usize,
    pub n_bits: usize,
    pub reads: AtomicU64,
    pub stale_reads: AtomicU64,
}

impl N2oTable {
    pub fn new(n_items: usize, d: usize, n_bridge: usize, n_bits: usize) -> Self {
        N2oTable {
            inner: RwLock::new(Arc::new(Generation {
                entries: vec![None; n_items],
                version: 0,
            })),
            d,
            n_bridge,
            n_bits,
            reads: AtomicU64::new(0),
            stale_reads: AtomicU64::new(0),
        }
    }

    pub fn version(&self) -> u64 {
        self.inner.read().unwrap().version
    }

    pub fn n_items(&self) -> usize {
        self.inner.read().unwrap().entries.len()
    }

    /// Atomic full swap to a new generation (model update trigger).
    pub fn swap_full(&self, entries: Vec<Option<N2oEntry>>, version: u64) {
        let mut guard = self.inner.write().unwrap();
        assert!(
            version > guard.version,
            "full swap must advance the version ({} -> {version})",
            guard.version
        );
        *guard = Arc::new(Generation { entries, version });
    }

    /// Incremental upsert into the current generation (item feature update
    /// / new item from the message queue).  Copy-on-write of the generation
    /// vector: readers holding the old Arc are unaffected.
    pub fn upsert(&self, rows: Vec<(u32, N2oEntry)>) {
        if rows.is_empty() {
            return;
        }
        let mut guard = self.inner.write().unwrap();
        let mut entries = guard.entries.clone();
        let max_id = rows.iter().map(|(i, _)| *i as usize).max().unwrap();
        if max_id >= entries.len() {
            entries.resize(max_id + 1, None); // new items extend the table
        }
        for (id, e) in rows {
            entries[id as usize] = Some(e);
        }
        *guard = Arc::new(Generation {
            entries,
            version: guard.version,
        });
    }

    /// Snapshot handle for consistent multi-row reads within one request.
    pub fn snapshot(&self) -> N2oSnapshot {
        self.reads.fetch_add(1, Ordering::Relaxed);
        N2oSnapshot {
            generation: Arc::clone(&self.inner.read().unwrap()),
            d: self.d,
            n_bridge: self.n_bridge,
            n_bits: self.n_bits,
        }
    }

    /// Total resident bytes (the §5.3 storage comparison numerator).
    pub fn size_bytes(&self) -> usize {
        self.inner
            .read()
            .unwrap()
            .entries
            .iter()
            .flatten()
            .map(|e| e.size_bytes())
            .sum()
    }

    pub fn coverage(&self) -> f64 {
        let g = self.inner.read().unwrap();
        let have = g.entries.iter().filter(|e| e.is_some()).count();
        have as f64 / g.entries.len().max(1) as f64
    }
}

/// Immutable view of one generation.
pub struct N2oSnapshot {
    generation: Arc<Generation>,
    d: usize,
    n_bridge: usize,
    n_bits: usize,
}

impl N2oSnapshot {
    pub fn version(&self) -> u64 {
        self.generation.version
    }

    pub fn get(&self, item: u32) -> Option<&N2oEntry> {
        self.generation
            .entries
            .get(item as usize)
            .and_then(|e| e.as_ref())
    }

    /// Assemble the pre-rank head inputs for a mini-batch of items, padded
    /// to `batch` rows: (item_vec [B,D], bea_w [B,n], item_sign [B,bits]).
    /// Returns None if any item is missing from this generation (caller
    /// falls back to inline computation or errors).
    pub fn assemble(
        &self,
        items: &[u32],
        batch: usize,
    ) -> Option<(Tensor, Tensor, Tensor)> {
        assert!(!items.is_empty() && items.len() <= batch);
        let mut vecs = Vec::with_capacity(batch * self.d);
        let mut ws = Vec::with_capacity(batch * self.n_bridge);
        let mut packed = Vec::with_capacity(batch * self.n_bits / 8);
        for &it in items {
            let e = self.get(it)?;
            vecs.extend_from_slice(&e.item_vec);
            ws.extend_from_slice(&e.bea_w);
            packed.extend_from_slice(&e.sign_packed);
        }
        let last = self.get(items[items.len() - 1])?;
        for _ in items.len()..batch {
            vecs.extend_from_slice(&last.item_vec);
            ws.extend_from_slice(&last.bea_w);
            packed.extend_from_slice(&last.sign_packed);
        }
        let sign = lsh::unpack_plane(&packed, batch, self.n_bits);
        Some((
            Tensor::new(vec![batch, self.d], vecs),
            Tensor::new(vec![batch, self.n_bridge], ws),
            sign,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(v: f32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![v; 4],
            bea_w: vec![v; 2],
            sign_packed: vec![0b1010_0101],
        }
    }

    #[test]
    fn full_swap_advances_version() {
        let t = N2oTable::new(4, 4, 2, 8);
        assert_eq!(t.version(), 0);
        t.swap_full(vec![Some(entry(1.0)); 4], 1);
        assert_eq!(t.version(), 1);
        assert_eq!(t.coverage(), 1.0);
    }

    #[test]
    #[should_panic(expected = "advance")]
    fn full_swap_rejects_stale_version() {
        let t = N2oTable::new(2, 4, 2, 8);
        t.swap_full(vec![None, None], 3);
        t.swap_full(vec![None, None], 2);
    }

    #[test]
    fn snapshot_is_isolated_from_upserts() {
        let t = N2oTable::new(3, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 3], 1);
        let snap = t.snapshot();
        t.upsert(vec![(0, entry(9.0))]);
        // Old snapshot still sees the old row.
        assert_eq!(snap.get(0).unwrap().item_vec[0], 1.0);
        // New snapshot sees the update.
        assert_eq!(t.snapshot().get(0).unwrap().item_vec[0], 9.0);
    }

    #[test]
    fn upsert_extends_for_new_items() {
        let t = N2oTable::new(2, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)); 2], 1);
        t.upsert(vec![(5, entry(2.0))]); // new item id beyond table
        assert_eq!(t.n_items(), 6);
        assert_eq!(t.snapshot().get(5).unwrap().item_vec[0], 2.0);
    }

    /// Entry whose item_vec encodes (writer tag, item id) so readers can
    /// tell exactly which write produced a row.
    fn tagged(tag: f32, id: u32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![tag, id as f32, 0.0, 0.0],
            bea_w: vec![tag; 2],
            sign_packed: vec![id as u8],
        }
    }

    #[test]
    fn upserts_after_swap_are_never_lost() {
        // Deterministic phase ordering via barriers: pre-swap upserts,
        // the atomic generation swap, post-swap upserts.  The final table
        // must carry every post-swap row — "no lost rows across the
        // swap" — and the swap must wipe pre-swap rows wholesale (a full
        // rebuild recomputes everything).
        use std::sync::Barrier;
        let n = 64usize;
        let t = Arc::new(N2oTable::new(n, 4, 2, 8));
        t.swap_full((0..n).map(|i| Some(tagged(0.0, i as u32))).collect(), 1);

        let barrier = Arc::new(Barrier::new(2));
        let writer = {
            let t = Arc::clone(&t);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                for i in 0..n as u32 {
                    t.upsert(vec![(i, tagged(1.0, i))]); // pre-swap
                }
                barrier.wait(); // swapper goes
                barrier.wait(); // swap done
                for i in 0..n as u32 {
                    t.upsert(vec![(i, tagged(3.0, i))]); // post-swap
                }
            })
        };
        barrier.wait();
        t.swap_full(
            (0..n).map(|i| Some(tagged(2.0, i as u32))).collect(),
            2,
        );
        barrier.wait();
        writer.join().unwrap();

        assert_eq!(t.version(), 2);
        let snap = t.snapshot();
        for i in 0..n as u32 {
            let e = snap.get(i).expect("no holes after the swap");
            assert_eq!(
                e.item_vec[0], 3.0,
                "item {i}: post-swap upsert was lost"
            );
            assert_eq!(e.item_vec[1], i as f32);
        }
    }

    #[test]
    fn concurrent_upserts_racing_full_rebuild_stay_consistent() {
        // Chaos phase: writers upsert while another thread swaps to a new
        // generation; readers snapshot continuously.  Invariants that
        // must hold under ANY interleaving: versions never decrease, rows
        // are never torn (tag and id always agree), and no row is ever
        // missing.
        let n = 32usize;
        let t = Arc::new(N2oTable::new(n, 4, 2, 8));
        t.swap_full((0..n).map(|i| Some(tagged(0.0, i as u32))).collect(), 1);

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for w in 0..2u32 {
            let t = Arc::clone(&t);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let mut round = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    for i in (w % 2..n as u32).step_by(2) {
                        t.upsert(vec![(i, tagged(1.0 + round as f32, i))]);
                    }
                    round += 1;
                }
            }));
        }
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut last_version = 0u64;
                    let mut checked = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = t.snapshot();
                        assert!(
                            snap.version() >= last_version,
                            "version moved backwards: {} -> {}",
                            last_version,
                            snap.version()
                        );
                        last_version = snap.version();
                        for i in 0..n as u32 {
                            let e = snap
                                .get(i)
                                .expect("row vanished mid-flight");
                            // Rows swap atomically: the id channel always
                            // matches, whatever generation or writer won.
                            assert_eq!(e.item_vec[1], i as f32);
                            assert_eq!(e.sign_packed[0], i as u8);
                            checked += 1;
                        }
                    }
                    checked
                })
            })
            .collect();

        // Two racing generation swaps while the writers hammer away.
        for v in 2..4u64 {
            std::thread::sleep(std::time::Duration::from_millis(20));
            t.swap_full(
                (0..n).map(|i| Some(tagged(100.0, i as u32))).collect(),
                v,
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        for r in readers {
            assert!(r.join().unwrap() > 0, "readers actually ran");
        }
        assert_eq!(t.version(), 3);
        // Coverage never regressed: every row still present.
        assert_eq!(t.coverage(), 1.0);
    }

    #[test]
    fn assemble_pads_and_unpacks() {
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0)), Some(entry(2.0)), None, None], 1);
        let snap = t.snapshot();
        let (v, w, s) = snap.assemble(&[0, 1], 3).unwrap();
        assert_eq!(v.shape, vec![3, 4]);
        assert_eq!(w.shape, vec![3, 2]);
        assert_eq!(s.shape, vec![3, 8]);
        assert_eq!(v.row(2), v.row(1), "padding repeats last row");
        // 0b1010_0101 little-endian bit order -> +1,-1,+1,-1,-1,+1,-1,+1
        assert_eq!(s.row(0), &[1., -1., 1., -1., -1., 1., -1., 1.]);
        // Missing item -> None.
        assert!(snap.assemble(&[0, 2], 2).is_none());
    }
}
