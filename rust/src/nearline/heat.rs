//! Serving-traffic heat for items — the signal behind the update queue's
//! priority refresh lane (COLD's compute/effectiveness framing: spend
//! refresh compute where the traffic is).
//!
//! The serving path calls [`ItemHeat::touch`] with the items it actually
//! returned (the top-K), so heat tracks *served* popularity, which under
//! zipfian traffic concentrates on a small head.  Counters live in a
//! fixed power-of-two table of relaxed atomics indexed by `id & mask`:
//! touches are wait-free and cost one `fetch_add` per served item, which
//! keeps the hot path's zero-lock budget intact.  Collisions can only
//! over-report heat (two ids sharing a slot), which errs toward refreshing
//! more items sooner — acceptable for a priority hint.  [`ItemHeat::decay`]
//! halves every slot; the queue calls it on its compaction cadence so heat
//! follows traffic shifts instead of accumulating forever.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

pub struct ItemHeat {
    slots: Vec<AtomicU32>,
    mask: usize,
    /// Total touches since start (observability).
    pub touches: AtomicU64,
}

impl ItemHeat {
    /// `capacity` is rounded up to a power of two (min 1024 slots).
    pub fn new(capacity: usize) -> ItemHeat {
        let n = capacity.next_power_of_two().max(1024);
        ItemHeat {
            slots: (0..n).map(|_| AtomicU32::new(0)).collect(),
            mask: n - 1,
            touches: AtomicU64::new(0),
        }
    }

    /// Record one serving of each item (called with a request's top-K).
    pub fn touch<I: IntoIterator<Item = u32>>(&self, items: I) {
        let mut n = 0u64;
        for id in items {
            self.slots[id as usize & self.mask].fetch_add(1, Ordering::Relaxed);
            n += 1;
        }
        if n > 0 {
            self.touches.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn heat(&self, id: u32) -> u32 {
        self.slots[id as usize & self.mask].load(Ordering::Relaxed)
    }

    pub fn is_hot(&self, id: u32, min_touches: u32) -> bool {
        min_touches > 0 && self.heat(id) >= min_touches
    }

    /// Halve every slot (periodic, from the queue's maintenance cadence).
    pub fn decay(&self) {
        for s in &self.slots {
            // Racy read-modify-write is fine: a lost concurrent touch
            // only under-counts by one during the decay sweep.
            let v = s.load(Ordering::Relaxed);
            if v > 0 {
                s.store(v / 2, Ordering::Relaxed);
            }
        }
    }

    /// (hot slots above threshold, max slot heat) — for `/metrics`.
    pub fn stats(&self, min_touches: u32) -> (usize, u32) {
        let mut hot = 0usize;
        let mut max = 0u32;
        for s in &self.slots {
            let v = s.load(Ordering::Relaxed);
            if min_touches > 0 && v >= min_touches {
                hot += 1;
            }
            max = max.max(v);
        }
        (hot, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_heat_and_threshold() {
        let h = ItemHeat::new(16); // rounds up to 1024
        h.touch([3u32, 3, 3, 7]);
        assert_eq!(h.heat(3), 3);
        assert_eq!(h.heat(7), 1);
        assert_eq!(h.heat(9), 0);
        assert!(h.is_hot(3, 2));
        assert!(!h.is_hot(7, 2));
        assert!(!h.is_hot(3, 0), "threshold 0 disables the hot lane");
        assert_eq!(h.touches.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn decay_halves() {
        let h = ItemHeat::new(1024);
        h.touch(std::iter::repeat(5u32).take(9));
        h.decay();
        assert_eq!(h.heat(5), 4);
        h.decay();
        h.decay();
        assert_eq!(h.heat(5), 1);
        let (hot, max) = h.stats(1);
        assert_eq!((hot, max), (1, 1));
    }
}
