//! Nearline asynchronous inference for item-side computations (paper §3.2):
//! the N2O index table, the update-triggered nearline worker and the
//! incremental message queue.

pub mod n2o;
pub mod queue;
pub mod worker;

pub use n2o::{
    N2oChunkView, N2oEntry, N2oExport, N2oRow, N2oSnapshot, N2oTable,
    RestoredChunk, N2O_CHUNK,
};
pub use queue::{UpdateEvent, UpdateQueue};
pub use worker::NearlineWorker;
