//! Nearline asynchronous inference for item-side computations (paper §3.2):
//! the N2O index table, the update-triggered nearline worker and the
//! incremental message queue.

pub mod heat;
pub mod n2o;
pub mod queue;
pub mod worker;

pub use heat::ItemHeat;
pub use n2o::{
    CompactReport, N2oChunkView, N2oEntry, N2oExport, N2oRow, N2oSnapshot,
    N2oTable, RestoredChunk, TableStats, N2O_CHUNK,
};
pub use queue::{
    IncrementalReport, PublishOutcome, QueueStats, UpdateApplier,
    UpdateEvent, UpdateQueue, Watermarks,
};
pub use worker::NearlineWorker;
