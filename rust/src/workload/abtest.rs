//! Online A/B test simulator (paper §5.1-5.2).
//!
//! Users are assigned to arms by a hash of the user id (consistent
//! assignment, no cross-contamination); each arm serves its traffic with
//! its own Merger; the oracle click model simulates user behavior on the
//! displayed slate; CTR / RPM deltas come with bootstrap confidence
//! intervals (1000 resamples, 95%), exactly the paper's protocol.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{PreRanker, ScoreRequest};
use crate::features::World;
use crate::util::rng::Pcg64;

/// Per-request online sample.
#[derive(Debug, Clone, Copy)]
struct Sample {
    impressions: u32,
    clicks: u32,
    revenue: f32,
}

/// Per-arm aggregate.
#[derive(Debug, Clone)]
pub struct ArmReport {
    pub name: String,
    pub requests: usize,
    pub ctr: f64,
    pub rpm: f64,
    pub avg_rt_ms: f64,
    /// 95% bootstrap CI of the CTR delta vs control (None for control).
    pub ctr_delta_ci: Option<(f64, f64)>,
    pub rpm_delta_ci: Option<(f64, f64)>,
    /// Per-request samples (kept for downstream re-analysis).
    #[allow(dead_code)]
    samples: Vec<Sample>,
}

impl ArmReport {
    pub fn ctr_delta_pct(&self, control: &ArmReport) -> f64 {
        (self.ctr - control.ctr) / control.ctr * 100.0
    }
    pub fn rpm_delta_pct(&self, control: &ArmReport) -> f64 {
        (self.rpm - control.rpm) / control.rpm * 100.0
    }
}

/// Run a multi-arm A/B test.  `arms[0]` is the control.  `slate` is how
/// many of the pre-ranked top-K are displayed (the downstream stages are
/// identity here — pre-rank quality differences flow straight to CTR).
/// `world` is the click/revenue oracle the arms are judged against — a
/// simulator concern, which is why it isn't part of the serving trait.
pub fn run<P: PreRanker + ?Sized>(
    world: &World,
    arms: &[(&str, Arc<P>)],
    n_requests: u64,
    slate: usize,
    seed: u64,
) -> Result<Vec<ArmReport>> {
    assert!(!arms.is_empty());
    let mut per_arm: Vec<Vec<Sample>> =
        (0..arms.len()).map(|_| Vec::new()).collect();
    let mut rt_sum: Vec<f64> = vec![0.0; arms.len()];
    let mut rng = Pcg64::with_stream(seed, 77);

    for id in 0..n_requests {
        let user = rng.below(world.n_users as u64) as usize;
        // Consistent hash assignment: a user always lands in the same arm.
        let arm = (crate::cache::RequestKey::new(0, &format!("u{user}")).0
            as usize)
            % arms.len();
        let ranker = &arms[arm].1;
        let result =
            ranker.score(ScoreRequest::user(user).with_request_id(id))?;
        rt_sum[arm] += result.timings.total.as_secs_f64();

        // Display the slate; oracle user clicks.
        let shown = &result.items[..slate.min(result.items.len())];
        let mut clicks = 0u32;
        let mut revenue = 0.0f32;
        for s in shown {
            let p = world.click_prob(user, s.item);
            if rng.chance(p as f64) {
                clicks += 1;
                revenue += world.bid(s.item);
            }
        }
        per_arm[arm].push(Sample {
            impressions: shown.len() as u32,
            clicks,
            revenue,
        });
    }

    // Aggregate + bootstrap vs control.
    let agg = |samples: &[Sample]| -> (f64, f64) {
        let imp: f64 = samples.iter().map(|s| s.impressions as f64).sum();
        let clk: f64 = samples.iter().map(|s| s.clicks as f64).sum();
        let rev: f64 = samples.iter().map(|s| s.revenue as f64).sum();
        (clk / imp.max(1.0), rev / imp.max(1.0) * 1000.0)
    };

    let (control_ctr, control_rpm) = agg(&per_arm[0]);
    let mut reports = Vec::new();
    for (i, (name, _)) in arms.iter().enumerate() {
        let (ctr, rpm) = agg(&per_arm[i]);
        let (ctr_ci, rpm_ci) = if i == 0 {
            (None, None)
        } else {
            let boot = bootstrap_delta(
                &per_arm[0],
                &per_arm[i],
                1000,
                seed ^ i as u64,
            );
            (Some(boot.0), Some(boot.1))
        };
        reports.push(ArmReport {
            name: name.to_string(),
            requests: per_arm[i].len(),
            ctr,
            rpm,
            avg_rt_ms: rt_sum[i] / per_arm[i].len().max(1) as f64 * 1e3,
            ctr_delta_ci: ctr_ci,
            rpm_delta_ci: rpm_ci,
            samples: per_arm[i].clone(),
        });
    }
    let _ = (control_ctr, control_rpm);
    Ok(reports)
}

/// Bootstrap 95% CI of (treatment − control) for CTR and RPM.
fn bootstrap_delta(
    control: &[Sample],
    treatment: &[Sample],
    n_resamples: usize,
    seed: u64,
) -> ((f64, f64), (f64, f64)) {
    let mut rng = Pcg64::with_stream(seed, 99);
    let mut ctr_deltas = Vec::with_capacity(n_resamples);
    let mut rpm_deltas = Vec::with_capacity(n_resamples);
    let stat = |s: &[Sample], rng: &mut Pcg64| -> (f64, f64) {
        let n = s.len();
        let mut imp = 0f64;
        let mut clk = 0f64;
        let mut rev = 0f64;
        for _ in 0..n {
            let x = &s[rng.below(n as u64) as usize];
            imp += x.impressions as f64;
            clk += x.clicks as f64;
            rev += x.revenue as f64;
        }
        (clk / imp.max(1.0), rev / imp.max(1.0) * 1000.0)
    };
    for _ in 0..n_resamples {
        let (c_ctr, c_rpm) = stat(control, &mut rng);
        let (t_ctr, t_rpm) = stat(treatment, &mut rng);
        ctr_deltas.push(t_ctr - c_ctr);
        rpm_deltas.push(t_rpm - c_rpm);
    }
    (ci95(&mut ctr_deltas), ci95(&mut rpm_deltas))
}

fn ci95(deltas: &mut [f64]) -> (f64, f64) {
    deltas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = deltas.len();
    (deltas[n * 25 / 1000], deltas[n * 975 / 1000 - 1])
}

/// Render an A/B report table (paper Table 2 online columns).
pub fn render(reports: &[ArmReport]) -> String {
    let control = &reports[0];
    let mut out = String::new();
    out.push_str(&format!(
        "{:28} {:>8} {:>9} {:>9} {:>10} {:>24}\n",
        "arm", "requests", "CTR", "RPM", "avgRT", "ΔCTR 95% CI"
    ));
    for r in reports {
        let delta = if r.name == control.name {
            "-".to_string()
        } else {
            let ci = r.ctr_delta_ci.unwrap();
            let sig = if ci.0 > 0.0 || ci.1 < 0.0 { "*" } else { " " };
            format!(
                "{:+.2}% [{:+.4},{:+.4}]{sig}",
                r.ctr_delta_pct(control),
                ci.0,
                ci.1
            )
        };
        out.push_str(&format!(
            "{:28} {:>8} {:>9.4} {:>9.3} {:>9.2}ms {:>24}\n",
            r.name, r.requests, r.ctr, r.rpm, r.avg_rt_ms, delta
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci95_brackets_the_distribution() {
        let mut d: Vec<f64> = (0..1000).map(|i| i as f64 / 1000.0).collect();
        let (lo, hi) = ci95(&mut d);
        assert!(lo < 0.05 && lo >= 0.0, "{lo}");
        assert!(hi > 0.95 && hi <= 1.0, "{hi}");
    }
}
