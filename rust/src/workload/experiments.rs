//! Paper-experiment harnesses (DESIGN.md §6) — shared by the `aif`
//! subcommands and the `cargo bench` targets so every table/figure can be
//! regenerated from either entry point.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ScenarioConfig, ServingConfig, SimMode};
use crate::coordinator::{Merger, PreRanker, ScoreRequest};
use crate::features::World;
use crate::lsh::Hasher;
use crate::nearline::{N2oTable, NearlineWorker};
use crate::runtime::{Manifest, RtpPool};
use crate::util::bench::DeltaTable;
use crate::workload::runner::{self, LoadReport};

/// Scale knob: `quick` shrinks request counts for CI-speed runs.
#[derive(Debug, Clone, Copy)]
pub struct ExpScale {
    pub requests: u64,
    pub clients: usize,
    pub qps_step_requests: u64,
}

impl ExpScale {
    pub fn quick() -> Self {
        ExpScale {
            requests: 24,
            clients: 4,
            qps_step_requests: 16,
        }
    }
    pub fn full() -> Self {
        ExpScale {
            requests: 96,
            clients: 4,
            qps_step_requests: 48,
        }
    }
    pub fn from_env() -> Self {
        if std::env::var("AIF_QUICK").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::full()
        }
    }
}

fn cfg_with_dir(mut cfg: ServingConfig, artifacts_dir: &str) -> ServingConfig {
    cfg.artifacts_dir = artifacts_dir.to_string();
    cfg
}

// ==========================================================================
// Table 4 — system performance of each pipeline increment.
// ==========================================================================
pub struct Table4Row {
    pub name: String,
    pub load: LoadReport,
    pub max_qps: f64,
    pub extra_storage: bool,
}

/// One shared-core Merger whose registry holds every Table-4 row as a
/// scenario (the sweep used to build 8 full Mergers — 8 fleets, 8 N2O
/// tables, 8 cache clusters; now it's 8 thin engines over one substrate).
fn build_table4_merger(artifacts_dir: &str) -> Result<Arc<Merger>> {
    let rows = ServingConfig::table4_rows();
    let mut core_cfg = cfg_with_dir(rows[0].1.clone(), artifacts_dir);
    core_cfg.scenarios = rows
        .iter()
        .map(|(name, cfg)| ScenarioConfig::from_serving(name, cfg))
        .collect();
    core_cfg.default_scenario = Some(rows[0].0.to_string());
    Ok(Arc::new(Merger::build(core_cfg)?))
}

pub fn run_table4(artifacts_dir: &str, scale: ExpScale) -> Result<String> {
    log::info!("table4: bringing up the shared core + 8 scenarios");
    let merger = build_table4_merger(artifacts_dir)?;
    let mut rows = Vec::new();
    for engine in merger.registry().engines() {
        // Benchmark isolation: the rows share one core, but each row must
        // be measured from a cold SIM cache (the pre-refactor sweep built
        // a fresh Merger per row, so "+ Pre-Caching" must not pre-warm
        // "AIF"'s fetches).
        merger.core().sim_cache.clear();
        let name = engine.name().to_string();
        let extra = engine.uses_shared_storage();
        let ranker: Arc<dyn PreRanker> = engine;
        let load = runner::closed_loop(
            &name,
            &ranker,
            scale.requests,
            scale.clients,
            42,
        );
        let (mq, _) = runner::max_qps(&ranker, scale.qps_step_requests, 43);
        println!(
            "{}  maxQPS {:8.2}  extra_storage {}",
            load.render(),
            mq,
            if extra { "yes" } else { "no" }
        );
        rows.push(Table4Row {
            name,
            load,
            max_qps: mq,
            extra_storage: extra,
        });
    }

    let mut t = DeltaTable::new(
        "Table 4: system performance (deltas vs Base)",
        &["avgRT(ms)", "p99RT(ms)", "maxQPS"],
    );
    for r in &rows {
        t.row(
            &format!(
                "{}{}",
                r.name,
                if r.extra_storage { "  [S]" } else { "" }
            ),
            vec![r.load.avg_prerank_ms, r.load.p99_prerank_ms, r.max_qps],
        );
    }
    let mut out = t.render_deltas();
    out.push_str("\n[S] = uses shared extra storage (N2O / pre-cache pool)\n");
    out.push_str(&format!(
        "shared-core extra storage (counted ONCE across all {} scenarios): \
         {:.2} MiB\n",
        merger.registry().len(),
        merger.core().shared_storage_bytes() as f64 / (1 << 20) as f64
    ));
    Ok(out)
}

/// Shared-core vs per-Merger comparison (bench satellite): bring up the
/// same K variants both ways, report resident extra-storage bytes saved
/// and assert the shared-core scenarios rank identically to dedicated
/// single-variant Mergers on a fixed candidate set.
pub fn run_shared_core_comparison(artifacts_dir: &str) -> Result<String> {
    let variants: &[(&str, &str, SimMode)] = &[
        ("Base", "base", SimMode::Off),
        ("+ SIM", "t4_sim", SimMode::Precached),
        ("AIF", "aif", SimMode::Precached),
    ];

    // Dedicated: one full Merger per variant (the pre-registry shape).
    let mut dedicated: Vec<(&str, Arc<Merger>)> = Vec::new();
    let mut dedicated_bytes = 0usize;
    for &(name, variant, sim) in variants {
        let cfg = ServingConfig {
            variant: variant.into(),
            sim_mode: sim,
            artifacts_dir: artifacts_dir.into(),
            ..Default::default()
        };
        let m = Arc::new(Merger::build(cfg)?);
        dedicated_bytes += m.extra_storage_bytes();
        dedicated.push((name, m));
    }

    // Shared: one core, K scenarios.
    let template = ServingConfig {
        artifacts_dir: artifacts_dir.into(),
        ..Default::default()
    };
    let mut cfg = template.clone();
    cfg.scenarios = variants
        .iter()
        .map(|&(name, variant, sim)| ScenarioConfig {
            name: name.to_string(),
            variant: variant.to_string(),
            sim_mode: sim,
            ..ScenarioConfig::from_serving(name, &template)
        })
        .collect();
    cfg.default_scenario = Some("Base".to_string());
    let shared = Arc::new(Merger::build(cfg)?);

    // Identical top-K per variant on a fixed candidate override (the
    // retrieval stage is stochastic; the scoring path must not be).
    let candidates: Vec<u32> =
        (0..512.min(shared.world().n_items) as u32).collect();
    let mut checked = 0usize;
    for (name, ded) in &dedicated {
        for user in [1usize, 17, 42] {
            let req = |id: u64| {
                ScoreRequest::user(user)
                    .with_request_id(id)
                    .with_candidates(candidates.clone())
                    .with_top_k(64)
            };
            let a = ded.score(req(1))?;
            let b = shared.score(req(2).with_scenario(*name))?;
            anyhow::ensure!(
                a.items == b.items,
                "{name}: shared-core scores diverge from the dedicated \
                 Merger for user {user}"
            );
            checked += 1;
        }
    }

    let shared_bytes = shared.extra_storage_bytes();
    let mut out = String::new();
    out.push_str("\n== shared core vs per-variant Mergers ==\n");
    out.push_str(&format!(
        "{} dedicated Mergers: {:.2} MiB extra resident\n",
        dedicated.len(),
        dedicated_bytes as f64 / (1 << 20) as f64
    ));
    out.push_str(&format!(
        "1 shared core x {} scenarios: {:.2} MiB extra resident\n",
        dedicated.len(),
        shared_bytes as f64 / (1 << 20) as f64
    ));
    out.push_str(&format!(
        "saved: {:.2} MiB ({:.1}%)  |  top-K identical on {} \
         (variant, user) pairs\n",
        (dedicated_bytes.saturating_sub(shared_bytes)) as f64
            / (1 << 20) as f64,
        (1.0 - shared_bytes as f64 / dedicated_bytes.max(1) as f64) * 100.0,
        checked
    ));
    Ok(out)
}

// ==========================================================================
// Table 1 — asynchronous inference strategies, measured.
// ==========================================================================
pub fn run_table1(artifacts_dir: &str, scale: ExpScale) -> Result<String> {
    let manifest = Arc::new(Manifest::load(artifacts_dir)?);
    let world = Arc::new(World::load(&manifest)?);
    let hasher = Arc::new(Hasher::from_table(&world.w_hash));
    let rtp = Arc::new(RtpPool::new(
        Arc::clone(&manifest),
        vec!["user_tower".into(), "item_tower".into()],
        2,
    ));
    let batch = manifest.batch;

    // Workload: T requests, item reuse from zipf candidates.
    let n_requests = scale.requests;
    let n_cands = 2048usize;
    let n_batches = n_cands.div_ceil(batch) as u64;

    // Measure steady-state tower execution (one warm-up call first — the
    // cold call pays one-time buffer allocation).
    let time_of = |artifact: &str, inputs: Vec<crate::runtime::Tensor>| {
        rtp.call(artifact, inputs.clone()).unwrap();
        let t0 = Instant::now();
        for _ in 0..5 {
            rtp.call(artifact, inputs.clone()).unwrap();
        }
        t0.elapsed() / 5
    };
    let uf = crate::features::FeatureStore::new(
        Arc::clone(&world),
        crate::features::LatencyModel::zero(),
        crate::features::LatencyModel::zero(),
    )
    .fetch_user(1);
    let mut user_inputs =
        crate::features::assembly::user_tower_inputs(&world, &uf);
    // The serving tower also ingests the long-term signature plane
    // (linearized-DIN factors; DESIGN.md §9.5).
    let packed = crate::coordinator::merger::packed_signs(&world, &uf.long_seq);
    user_inputs.push(crate::lsh::unpack_plane(
        &packed,
        uf.long_seq.len(),
        world.w_hash.shape()[0],
    ));
    let user_t = time_of("user_tower", user_inputs.clone());
    let ids: Vec<u32> = (0..batch as u32).collect();
    let feats = crate::features::FeatureStore::new(
        Arc::clone(&world),
        crate::features::LatencyModel::zero(),
        crate::features::LatencyModel::zero(),
    )
    .fetch_items(&ids);
    let item_inputs =
        vec![crate::features::assembly::item_raw_batch(&feats, batch)];
    let item_t = time_of("item_tower", item_inputs.clone());

    // N2O nearline build for storage numbers.
    let n2o = Arc::new(N2oTable::new(
        world.n_items,
        manifest.dim("D"),
        manifest.dim("N_BRIDGE"),
        manifest.dim("D_LSH_BITS"),
    ));
    let worker = NearlineWorker::new(
        Arc::clone(&rtp),
        Arc::clone(&world),
        hasher,
        Arc::clone(&n2o),
        batch,
    );
    let build = worker.full_build(1)?;
    let update_period = Duration::from_secs(600); // nearline refresh cadence
    let offline_period = Duration::from_secs(86_400);

    // Per-strategy accounting over the request window.
    // computation = tower-executions per request window; latency = added
    // critical-path ms per request; storage = resident bytes; timeliness =
    // mean staleness of the tensors at use.
    let real_time_exec = n_requests * n_batches;
    let online_async_exec = n_requests;
    let nearline_exec = build.executions as u64; // once per update period
    let offline_exec = build.executions as u64; // once per day

    let user_cache_bytes = {
        // one in-flight async result per request
        let d = manifest.dim("D");
        let n = manifest.dim("N_BRIDGE");
        let l = manifest.l_long;
        let bits = manifest.dim("D_LSH_BITS");
        (d + n * d + l * d + l * bits) * 4
    };

    let mut out = String::new();
    out.push_str("\n== Table 1: asynchronous inference strategies (measured) ==\n");
    out.push_str(&format!(
        "{:28}{:>22}{:>16}{:>18}{:>14}\n",
        "strategy", "compute (exec/req-win)", "storage", "added latency",
        "staleness"
    ));
    let fmt_bytes = |b: usize| {
        if b > 1 << 20 {
            format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
        } else {
            format!("{:.1} KiB", b as f64 / 1024.0)
        }
    };
    out.push_str(&format!(
        "{:28}{:>22}{:>16}{:>18}{:>14}\n",
        "Offline async (item)",
        offline_exec.to_string(),
        fmt_bytes(build.table_bytes),
        "0 ms",
        format!("≤{:.0} s", offline_period.as_secs_f64()),
    ));
    out.push_str(&format!(
        "{:28}{:>22}{:>16}{:>18}{:>14}\n",
        "Nearline async (item)",
        nearline_exec.to_string(),
        fmt_bytes(build.table_bytes),
        "0 ms",
        format!("≤{:.0} s", update_period.as_secs_f64()),
    ));
    out.push_str(&format!(
        "{:28}{:>22}{:>16}{:>18}{:>14}\n",
        "Online async (user)",
        online_async_exec.to_string(),
        fmt_bytes(user_cache_bytes),
        format!(
            "{:.2} ms (hidden)",
            user_t.as_secs_f64() * 1e3
        ),
        "0 s (fresh)",
    ));
    out.push_str(&format!(
        "{:28}{:>22}{:>16}{:>18}{:>14}\n",
        "Real-time inference",
        real_time_exec.to_string(),
        "0 B".to_string(),
        format!(
            "{:.2} ms/req",
            item_t.as_secs_f64() * 1e3 * n_batches as f64
        ),
        "0 s (fresh)",
    ));
    out.push_str(&format!(
        "\n(user_tower {:.2} ms, item_tower {:.2} ms per exec; \
         {n_requests} requests x {n_batches} mini-batches)\n",
        user_t.as_secs_f64() * 1e3,
        item_t.as_secs_f64() * 1e3
    ));
    Ok(out)
}

// ==========================================================================
// Table 3 — long-term interaction complexity (measured, rust reference).
// ==========================================================================
pub fn run_table3(artifacts_dir: &str) -> Result<String> {
    let manifest = Manifest::load(artifacts_dir)?;
    let world = World::load(&manifest)?;
    let hasher = Hasher::from_table(&world.w_hash);
    let b = manifest.batch.min(256);
    let l = manifest.l_long;
    let d_id = manifest.dim("D");
    let d_mm = manifest.dim("D_MM");
    let d_lsh_bits = manifest.dim("D_LSH_BITS");
    let d_lsh_bytes = d_lsh_bits / 8;

    // Operands from the world.
    let items: Vec<u32> = (0..b as u32).collect();
    let seq: Vec<u32> = world.users_long_seq.u32_row(0).to_vec();
    let item_mm: Vec<&[f32]> =
        items.iter().map(|&i| world.items_mm.f32_row(i as usize)).collect();
    let seq_mm: Vec<&[f32]> =
        seq.iter().map(|&i| world.items_mm.f32_row(i as usize)).collect();
    let item_id: Vec<&[f32]> = items
        .iter()
        .map(|&i| world.items_seq_emb.f32_row(i as usize))
        .collect();
    let seq_id: Vec<&[f32]> = seq
        .iter()
        .map(|&i| world.items_seq_emb.f32_row(i as usize))
        .collect();
    let item_sig: Vec<Vec<u8>> =
        item_mm.iter().map(|m| hasher.sign(m)).collect();
    let seq_sig: Vec<Vec<u8>> = seq_mm.iter().map(|m| hasher.sign(m)).collect();

    let bench = crate::util::bench::Bench::quick();
    let dots = |a: &[&[f32]], bm: &[&[f32]]| {
        let mut acc = 0.0f32;
        for ra in a {
            for rb in bm {
                let mut s = 0.0;
                for (x, y) in ra.iter().zip(rb.iter()) {
                    s += x * y;
                }
                acc += s;
            }
        }
        acc
    };
    let lsh_sims = || {
        let mut acc = 0u32;
        for sa in &item_sig {
            for sb in &seq_sig {
                acc = acc.wrapping_add(crate::util::bits::xnor_matches_lut(
                    sa, sb, d_lsh_bits,
                ));
            }
        }
        acc
    };

    // Five Table-3 rows: which similarity matrices must be computed.
    struct Row {
        name: &'static str,
        complexity: String,
        macs: u64,
        time: f64,
    }
    let bl = (b * l) as u64;
    let mut rows = Vec::new();

    let t = bench.run("DIN(id) + SimTier(mm)", || {
        crate::util::bench::black_box(dots(&item_id, &seq_id));
        crate::util::bench::black_box(dots(&item_mm, &seq_mm));
    });
    rows.push(Row {
        name: "DIN + SimTier",
        complexity: "bl(d_id + d_mm)".into(),
        macs: bl * (d_id + d_mm) as u64,
        time: t.mean(),
    });
    let t = bench.run("LSH-DIN + SimTier(mm)", || {
        crate::util::bench::black_box(lsh_sims());
        crate::util::bench::black_box(dots(&item_mm, &seq_mm));
    });
    rows.push(Row {
        name: "LSH-DIN + SimTier",
        complexity: "bl(d_lsh + d_mm)".into(),
        macs: bl * (d_lsh_bytes + d_mm) as u64,
        time: t.mean(),
    });
    let t = bench.run("DIN(id) + LSH-SimTier", || {
        crate::util::bench::black_box(dots(&item_id, &seq_id));
        crate::util::bench::black_box(lsh_sims());
    });
    rows.push(Row {
        name: "DIN + LSH-SimTier",
        complexity: "bl(d_id + d_lsh)".into(),
        macs: bl * (d_id + d_lsh_bytes) as u64,
        time: t.mean(),
    });
    let t = bench.run("MM-DIN + SimTier (shared mm)", || {
        crate::util::bench::black_box(dots(&item_mm, &seq_mm));
    });
    rows.push(Row {
        name: "MM-DIN + SimTier",
        complexity: "bl·d_mm".into(),
        macs: bl * d_mm as u64,
        time: t.mean(),
    });
    let t = bench.run("LSH-DIN + LSH-SimTier (AIF)", || {
        crate::util::bench::black_box(lsh_sims());
    });
    rows.push(Row {
        name: "LSH-DIN + LSH-SimTier (AIF)",
        complexity: "bl·d_lsh".into(),
        macs: bl * d_lsh_bytes as u64,
        time: t.mean(),
    });

    let base_macs = rows[0].macs as f64;
    let base_time = rows[0].time;
    let mut out = String::new();
    out.push_str(&format!(
        "\n== Table 3: long-term interaction complexity \
         (b={b}, l={l}, d_id={d_id}, d_mm={d_mm}, d_lsh={d_lsh_bytes}B) ==\n"
    ));
    out.push_str(&format!(
        "{:30}{:>20}{:>14}{:>14}{:>12}{:>14}\n",
        "method", "complexity", "MACs", "reduction", "time(ms)", "speedup"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:30}{:>20}{:>14}{:>13.2}%{:>12.3}{:>13.2}x\n",
            r.name,
            r.complexity,
            r.macs,
            (1.0 - r.macs as f64 / base_macs) * 100.0,
            r.time * 1e3,
            base_time / r.time
        ));
    }
    Ok(out)
}

// ==========================================================================
// Fig 6 — interaction compute vs number of bridge embeddings.
// ==========================================================================
pub fn run_fig6(artifacts_dir: &str) -> Result<String> {
    let manifest = Manifest::load(artifacts_dir)?;
    let b = manifest.batch;
    let d = manifest.dim("D_BEA");
    let m = manifest.dim("M_GROUPS");
    let bench = crate::util::bench::Bench::quick();

    let mut out = String::new();
    out.push_str("\n== Fig 6 (compute side): BEA interaction cost vs n ==\n");
    out.push_str(&format!(
        "{:>6}{:>16}{:>14}{:>18}\n",
        "n", "MACs/batch", "time(µs)", "vs Full-Cross"
    ));
    // Full-Cross reference: every candidate attends over the m user groups
    // AND the per-item V inference runs online (what BEA amortizes).
    let full_cross_macs = (b * m * d * 3) as f64;
    for n in [1usize, 2, 4, 8, 10, 16, 32] {
        // BEA real-time cost: weighted sum [b,n]@[n,d].
        let w: Vec<f32> = (0..b * n).map(|i| (i % 7) as f32 * 0.1).collect();
        let v: Vec<f32> = (0..n * d).map(|i| (i % 5) as f32 * 0.2).collect();
        let mut out_buf = vec![0.0f32; b * d];
        let t = bench.run(&format!("bea_combine n={n}"), || {
            for i in 0..b {
                for k in 0..d {
                    let mut acc = 0.0;
                    for j in 0..n {
                        acc += w[i * n + j] * v[j * d + k];
                    }
                    out_buf[i * d + k] = acc;
                }
            }
            crate::util::bench::black_box(&out_buf);
        });
        let macs = (b * n * d) as f64;
        out.push_str(&format!(
            "{:>6}{:>16.0}{:>14.2}{:>17.1}x\n",
            n,
            macs,
            t.mean() * 1e6,
            full_cross_macs / macs
        ));
    }
    out.push_str(
        "\n(model-quality side of Fig 6 — GAUC vs n — comes from \
         `make exp-fig6`'s python half)\n",
    );
    Ok(out)
}

// ==========================================================================
// Table 2 online columns — A/B over serving variants.
// ==========================================================================
pub fn run_abtest(
    artifacts_dir: &str,
    variants: &[(&str, &str, SimMode, f64, usize)],
    n_requests: u64,
    slate: usize,
) -> Result<String> {
    // (display, variant, sim_mode, sim_budget, n_candidates): every arm is
    // a registry scenario over ONE shared core — the A/B harness stops
    // paying a full substrate copy per arm.
    let core_cfg = ServingConfig {
        artifacts_dir: artifacts_dir.into(),
        // Small latencies: the A/B measures ranking quality, not RT.
        retrieval_latency: crate::features::LatencyModel::fixed(200.0),
        user_store_latency: crate::features::LatencyModel::fixed(30.0),
        item_store_latency: crate::features::LatencyModel::fixed(10.0),
        ..Default::default()
    };
    let mut cfg = core_cfg.clone();
    cfg.scenarios = variants
        .iter()
        .map(|&(display, variant, sim, budget, n_cands)| ScenarioConfig {
            name: display.to_string(),
            variant: variant.to_string(),
            sim_mode: sim,
            sim_budget: budget,
            n_candidates: n_cands,
            ..ScenarioConfig::from_serving(display, &core_cfg)
        })
        .collect();
    cfg.default_scenario = Some(variants[0].0.to_string());
    log::info!("abtest: bringing up {} arms over one core", variants.len());
    let merger = Arc::new(Merger::build(cfg)?);
    let world = Arc::clone(merger.world());
    let engines = merger.registry().engines();
    let arms: Vec<(&str, Arc<dyn PreRanker>)> = variants
        .iter()
        .zip(&engines)
        .map(|(&(display, ..), e)| {
            (display, Arc::clone(e) as Arc<dyn PreRanker>)
        })
        .collect();
    let reports =
        super::abtest::run(&world, &arms, n_requests, slate, 4242)?;
    Ok(super::abtest::render(&reports))
}
