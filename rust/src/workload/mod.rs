//! Workload substrate: load generation (closed/open loop, saturation
//! sweeps) and the online A/B test simulator with bootstrap significance.

pub mod abtest;
pub mod experiments;
pub mod runner;

pub use runner::{closed_loop, max_qps, open_loop, LoadReport, UserSampler};
