//! Load generation: closed-loop and open-loop drivers over any
//! [`PreRanker`], plus the saturation sweep that measures maxQPS (Table 4).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{PreRanker, ScoreRequest};
use crate::util::rng::{Pcg64, Zipf};

/// Aggregate results of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub name: String,
    pub n_requests: u64,
    pub n_errors: u64,
    pub wall: Duration,
    pub qps: f64,
    pub avg_rt_ms: f64,
    pub p99_rt_ms: f64,
    pub avg_prerank_ms: f64,
    pub p99_prerank_ms: f64,
    pub avg_retrieval_ms: f64,
    pub extra_storage_bytes: usize,
}

impl LoadReport {
    pub fn render(&self) -> String {
        format!(
            "{:28} qps {:8.2}  avgRT {:8.3}ms  p99RT {:8.3}ms  \
             prerank avg {:7.3}ms p99 {:7.3}ms  err {}",
            self.name,
            self.qps,
            self.avg_rt_ms,
            self.p99_rt_ms,
            self.avg_prerank_ms,
            self.p99_prerank_ms,
            self.n_errors
        )
    }
}

/// Zipf-skewed user sampler (hot users exist in production traffic).
pub struct UserSampler {
    zipf: Zipf,
    n_users: usize,
}

impl UserSampler {
    pub fn new(n_users: usize) -> Self {
        UserSampler {
            zipf: Zipf::new(n_users, 1.02),
            n_users,
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        self.zipf.sample(rng) % self.n_users
    }
}

/// Closed-loop run: `n_clients` threads each issue requests back-to-back
/// until `n_requests` total are served.  Throughput at high `n_clients`
/// approaches maxQPS.
pub fn closed_loop<P: PreRanker + ?Sized + 'static>(
    name: &str,
    ranker: &Arc<P>,
    n_requests: u64,
    n_clients: usize,
    seed: u64,
) -> LoadReport {
    ranker.metrics().reset();
    let issued = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let sampler = Arc::new(UserSampler::new(ranker.n_users()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let ranker = Arc::clone(ranker);
        let issued = Arc::clone(&issued);
        let errors = Arc::clone(&errors);
        let sampler = Arc::clone(&sampler);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::with_stream(seed, c as u64 + 1);
            loop {
                let id = issued.fetch_add(1, Ordering::Relaxed);
                if id >= n_requests {
                    break;
                }
                let user = sampler.sample(&mut rng);
                let req = ScoreRequest::user(user).with_request_id(id);
                if ranker.score(req).is_err() {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed();
    report(name, ranker, n_requests, errors.load(Ordering::Relaxed), wall)
}

/// Open-loop run at a fixed arrival rate (Poisson): measures latency at a
/// target load without coordinated omission.
pub fn open_loop<P: PreRanker + ?Sized + 'static>(
    name: &str,
    ranker: &Arc<P>,
    n_requests: u64,
    rate_qps: f64,
    seed: u64,
) -> LoadReport {
    ranker.metrics().reset();
    let errors = Arc::new(AtomicU64::new(0));
    let sampler = UserSampler::new(ranker.n_users());
    let mut rng = Pcg64::with_stream(seed, 0);
    let t0 = Instant::now();
    let mut next_at = t0;
    let mut handles = Vec::new();
    for id in 0..n_requests {
        // Poisson arrivals.
        let gap = rng.exponential(rate_qps);
        next_at += Duration::from_secs_f64(gap);
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        let user = sampler.sample(&mut rng);
        let ranker = Arc::clone(ranker);
        let errors = Arc::clone(&errors);
        handles.push(std::thread::spawn(move || {
            let req = ScoreRequest::user(user).with_request_id(id);
            if ranker.score(req).is_err() {
                errors.fetch_add(1, Ordering::Relaxed);
            }
        }));
        // Bound the number of dangling threads.
        if handles.len() > 256 {
            for h in handles.drain(..128) {
                let _ = h.join();
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed();
    report(name, ranker, n_requests, errors.load(Ordering::Relaxed), wall)
}

/// Closed-loop ladder at explicit client counts — the grid that
/// `benches/e2e_throughput.rs` uses to compare coalescing on vs off
/// under concurrent load (the dispatch layer only pays off once several
/// requests are in flight, so the interesting rows are >= 8 clients).
pub fn concurrency_sweep<P: PreRanker + ?Sized + 'static>(
    name_prefix: &str,
    ranker: &Arc<P>,
    clients: &[usize],
    requests_per_step: u64,
    seed: u64,
) -> Vec<LoadReport> {
    clients
        .iter()
        .map(|&c| {
            closed_loop(
                &format!("{name_prefix} clients={c}"),
                ranker,
                requests_per_step,
                c,
                seed,
            )
        })
        .collect()
}

/// maxQPS: closed-loop saturation with a client ladder; returns the peak
/// observed throughput (the paper's maxQPS column).
pub fn max_qps<P: PreRanker + ?Sized + 'static>(
    ranker: &Arc<P>,
    requests_per_step: u64,
    seed: u64,
) -> (f64, Vec<LoadReport>) {
    let mut best = 0.0f64;
    let mut reports = Vec::new();
    for clients in [2usize, 4, 8, 16] {
        let r = closed_loop(
            &format!("clients={clients}"),
            ranker,
            requests_per_step,
            clients,
            seed,
        );
        best = best.max(r.qps);
        let saturated =
            reports.last().map(|p: &LoadReport| r.qps < p.qps * 1.05);
        reports.push(r);
        if saturated.unwrap_or(false) {
            break; // adding clients no longer helps
        }
    }
    (best, reports)
}

fn report<P: PreRanker + ?Sized>(
    name: &str,
    ranker: &Arc<P>,
    n_requests: u64,
    n_errors: u64,
    wall: Duration,
) -> LoadReport {
    let m = ranker.metrics();
    LoadReport {
        name: name.to_string(),
        n_requests,
        n_errors,
        wall,
        qps: n_requests as f64 / wall.as_secs_f64(),
        avg_rt_ms: m.total_rt.mean() * 1e3,
        p99_rt_ms: m.total_rt.percentile(99.0) * 1e3,
        avg_prerank_ms: m.prerank_rt.mean() * 1e3,
        p99_prerank_ms: m.prerank_rt.percentile(99.0) * 1e3,
        avg_retrieval_ms: m.retrieval_rt.mean() * 1e3,
        extra_storage_bytes: ranker.extra_storage_bytes(),
    }
}
