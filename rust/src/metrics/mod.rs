//! Serving metrics: log-bucketed latency histograms (avgRT / p99RT),
//! windowed QPS counters and snapshot reporting — the measurement substrate
//! behind Tables 1 and 4.

pub mod histogram;
pub mod report;

pub use histogram::{Histogram, ValueHistogram};
pub use report::{ClusterNodeStats, CoalesceStats, ServingMetrics};
