//! Lock-free log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Buckets are log-spaced from 1µs to ~17min with ~4.5% relative error per
//! bucket — plenty for avgRT/p99RT deltas at the percent level.  Recording
//! is a single atomic increment, safe from any thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const N_BUCKETS: usize = 512;
/// Bucket boundaries grow by 2^(1/16) per step: 16 buckets per octave.
const BUCKETS_PER_OCTAVE: f64 = 16.0;
const MIN_NANOS: f64 = 1_000.0; // 1µs

pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            max_nanos: AtomicU64::new(0),
        }
    }

    fn bucket_of(nanos: u64) -> usize {
        if (nanos as f64) <= MIN_NANOS {
            return 0;
        }
        let idx = ((nanos as f64 / MIN_NANOS).log2() * BUCKETS_PER_OCTAVE)
            .floor() as usize;
        idx.min(N_BUCKETS - 1)
    }

    fn bucket_upper(idx: usize) -> f64 {
        MIN_NANOS * 2f64.powf((idx + 1) as f64 / BUCKETS_PER_OCTAVE)
    }

    pub fn record(&self, d: Duration) {
        let nanos = d.as_nanos() as u64;
        self.buckets[Self::bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in seconds.
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    /// Percentile in seconds (upper bucket bound -> ≤4.5% overestimate).
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_upper(i) / 1e9;
            }
        }
        self.max_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn max(&self) -> f64 {
        self.max_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_nanos.store(0, Ordering::Relaxed);
        self.max_nanos.store(0, Ordering::Relaxed);
    }

    /// Snapshot of the raw bucket counters.  Pair two snapshots with
    /// [`Histogram::percentile_between`] to read percentiles over a time
    /// *window* of a histogram that itself accumulates forever — the
    /// overload controller's view of "p99 over the last sample tick".
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// Percentile in seconds over the recordings BETWEEN two
    /// [`Histogram::bucket_counts`] snapshots (`prev` taken earlier).
    /// Returns `None` when the window holds no recordings.  Counters are
    /// monotonic, so the per-bucket delta is exact even while writers
    /// race the snapshots.
    pub fn percentile_between(
        prev: &[u64],
        cur: &[u64],
        p: f64,
    ) -> Option<f64> {
        debug_assert_eq!(prev.len(), cur.len());
        let total: u64 = cur
            .iter()
            .zip(prev)
            .map(|(c, pr)| c.saturating_sub(*pr))
            .sum();
        if total == 0 {
            return None;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, (c, pr)) in cur.iter().zip(prev).enumerate() {
            seen += c.saturating_sub(*pr);
            if seen >= target {
                return Some(Self::bucket_upper(i) / 1e9);
            }
        }
        None
    }
}

/// Lock-free log2-bucketed histogram over plain counts (batch sizes, rows
/// per execution, jobs per flush) — the non-latency sibling of
/// [`Histogram`].
pub struct ValueHistogram {
    /// Bucket `k` holds values in `[2^(k-1), 2^k)`; bucket 0 holds 0.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

const N_VALUE_BUCKETS: usize = 65;

impl Default for ValueHistogram {
    fn default() -> Self {
        ValueHistogram {
            buckets: (0..N_VALUE_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl ValueHistogram {
    pub fn new() -> ValueHistogram {
        Self::default()
    }

    pub fn record(&self, v: u64) {
        let bucket = (64 - v.leading_zeros()) as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Percentile as an upper bucket bound (2x relative error).
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        self.max()
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_count() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 0.002).abs() < 1e-4, "{}", h.mean());
    }

    #[test]
    fn percentile_bounds() {
        let h = Histogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 > 400e-6 && p50 < 600e-6, "p50 {p50}");
        assert!(p99 > 900e-6 && p99 < 1150e-6, "p99 {p99}");
        assert!(h.percentile(100.0) >= p99);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        h.record(Duration::from_micros(100));
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn windowed_percentile_sees_only_the_delta() {
        let h = Histogram::new();
        // Epoch 1: a slow regime.
        for _ in 0..100 {
            h.record(Duration::from_millis(50));
        }
        let snap1 = h.bucket_counts();
        // Epoch 2: fast again.  The cumulative p99 stays ~50ms, the
        // windowed p99 sees only the fresh fast recordings.
        for _ in 0..100 {
            h.record(Duration::from_micros(100));
        }
        let snap2 = h.bucket_counts();
        let cumulative = h.percentile(99.0);
        assert!(cumulative > 10e-3, "cumulative p99 {cumulative}");
        let windowed =
            Histogram::percentile_between(&snap1, &snap2, 99.0).unwrap();
        assert!(windowed < 1e-3, "windowed p99 {windowed}");
        // An empty window has no percentile.
        assert_eq!(
            Histogram::percentile_between(&snap2, &snap2, 99.0),
            None
        );
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(Duration::from_millis(5));
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0.0);
    }

    #[test]
    fn value_histogram_moments() {
        let v = ValueHistogram::new();
        for x in [0u64, 1, 2, 256, 256, 512] {
            v.record(x);
        }
        assert_eq!(v.count(), 6);
        assert_eq!(v.max(), 512);
        assert!((v.mean() - (1027.0 / 6.0)).abs() < 1e-9, "{}", v.mean());
        // p50 is the 3rd of 6 values (2) -> its bucket's upper bound, 4.
        assert_eq!(v.percentile(50.0), 4);
        assert_eq!(v.percentile(99.0), 1024);
        v.reset();
        assert_eq!(v.count(), 0);
        assert_eq!(v.max(), 0);
    }
}
