//! Aggregated serving metrics + JSON snapshot (the numbers Tables 1/4 and
//! the `/metrics` endpoint report).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::histogram::Histogram;
use crate::util::json::{Object, Value};

#[derive(Default)]
pub struct ServingMetrics {
    /// End-to-end request latency (what the user sees).
    pub total_rt: Histogram,
    /// Real-time pre-rank phase only (the paper's RT metric: retrieval is
    /// upstream of pre-ranking, so avgRT/p99RT measure the pre-rank stage).
    pub prerank_rt: Histogram,
    /// Online-async user-side phase (overlapped with retrieval).
    pub user_async_rt: Histogram,
    /// Retrieval stage (upstream, for overlap accounting).
    pub retrieval_rt: Histogram,

    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub rtp_calls: AtomicU64,
    pub items_scored: AtomicU64,
    /// Async-phase time hidden under retrieval (the latency the paper's
    /// design removes from the critical path).
    pub overlap_saved_nanos: AtomicU64,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(
        &self,
        total: Duration,
        prerank: Duration,
        user_async: Option<Duration>,
        retrieval: Duration,
    ) {
        self.total_rt.record(total);
        self.prerank_rt.record(prerank);
        self.retrieval_rt.record(retrieval);
        if let Some(ua) = user_async {
            self.user_async_rt.record(ua);
            let hidden = ua.min(retrieval);
            self.overlap_saved_nanos
                .fetch_add(hidden.as_nanos() as u64, Ordering::Relaxed);
        }
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn qps(&self, wall: Duration) -> f64 {
        self.requests.load(Ordering::Relaxed) as f64 / wall.as_secs_f64()
    }

    pub fn snapshot(&self, wall: Duration) -> Value {
        let mut o = Object::new();
        let hist = |h: &Histogram| {
            let mut v = Object::new();
            v.insert("count", h.count());
            v.insert("avg_ms", h.mean() * 1e3);
            v.insert("p50_ms", h.percentile(50.0) * 1e3);
            v.insert("p99_ms", h.percentile(99.0) * 1e3);
            v.insert("max_ms", h.max() * 1e3);
            Value::Obj(v)
        };
        o.insert("total_rt", hist(&self.total_rt));
        o.insert("prerank_rt", hist(&self.prerank_rt));
        o.insert("user_async_rt", hist(&self.user_async_rt));
        o.insert("retrieval_rt", hist(&self.retrieval_rt));
        o.insert("requests", self.requests.load(Ordering::Relaxed));
        o.insert("errors", self.errors.load(Ordering::Relaxed));
        o.insert("rtp_calls", self.rtp_calls.load(Ordering::Relaxed));
        o.insert("items_scored", self.items_scored.load(Ordering::Relaxed));
        o.insert(
            "overlap_saved_ms_total",
            self.overlap_saved_nanos.load(Ordering::Relaxed) as f64 / 1e6,
        );
        o.insert("qps", self.qps(wall));
        Value::Obj(o)
    }

    pub fn reset(&self) {
        self.total_rt.reset();
        self.prerank_rt.reset();
        self.user_async_rt.reset();
        self.retrieval_rt.reset();
        self.requests.store(0, Ordering::Relaxed);
        self.errors.store(0, Ordering::Relaxed);
        self.rtp_calls.store(0, Ordering::Relaxed);
        self.items_scored.store(0, Ordering::Relaxed);
        self.overlap_saved_nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_expected_fields() {
        let m = ServingMetrics::new();
        m.record_request(
            Duration::from_millis(20),
            Duration::from_millis(8),
            Some(Duration::from_millis(5)),
            Duration::from_millis(10),
        );
        let snap = m.snapshot(Duration::from_secs(1));
        assert_eq!(snap.req("requests").as_usize(), Some(1));
        assert!(snap.req("prerank_rt").req("avg_ms").as_f64().unwrap() > 7.0);
        // 5ms async fully hidden under 10ms retrieval.
        let saved = snap.req("overlap_saved_ms_total").as_f64().unwrap();
        assert!((saved - 5.0).abs() < 0.01, "{saved}");
    }
}
