//! Aggregated serving metrics + JSON snapshot (the numbers Tables 1/4 and
//! the `/metrics` endpoint report).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::histogram::{Histogram, ValueHistogram};
use crate::util::json::{Object, Value};

/// Cross-request batch-coalescing counters (runtime::coalescer records
/// into these; `/metrics` and the load reports read them).  All zeros when
/// coalescing is off — the sequential baseline is unchanged.
#[derive(Default)]
pub struct CoalesceStats {
    /// Merged head executions dispatched to the RTP fleet.
    pub executions: AtomicU64,
    /// Per-request jobs that went through the coalescer.
    pub jobs: AtomicU64,
    /// Jobs that skipped the coalescing window (deadline bypass).
    pub bypass_jobs: AtomicU64,
    /// Padding rows executed (the waste coalescing exists to shrink).
    pub padded_rows: AtomicU64,
    /// Real rows per merged execution (the coalesced-batch-size histogram).
    pub exec_rows: ValueHistogram,
    /// Jobs merged per execution.
    pub exec_jobs: ValueHistogram,
    /// Per-job queue dwell before dispatch.
    pub queue_wait: Histogram,
}

impl CoalesceStats {
    /// Record one merged execution of `jobs` jobs totaling `rows` real
    /// rows, padded up to `exec_rows` artifact rows.
    pub fn record_execution(&self, jobs: u64, rows: u64, exec_rows: u64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(jobs, Ordering::Relaxed);
        self.padded_rows
            .fetch_add(exec_rows.saturating_sub(rows), Ordering::Relaxed);
        self.exec_rows.record(rows);
        self.exec_jobs.record(jobs);
    }

    pub fn snapshot(&self) -> Value {
        let mut o = Object::new();
        o.insert("executions", self.executions.load(Ordering::Relaxed));
        o.insert("jobs", self.jobs.load(Ordering::Relaxed));
        o.insert("bypass_jobs", self.bypass_jobs.load(Ordering::Relaxed));
        o.insert("padded_rows", self.padded_rows.load(Ordering::Relaxed));
        o.insert("rows_per_exec_avg", self.exec_rows.mean());
        o.insert("rows_per_exec_max", self.exec_rows.max());
        o.insert("jobs_per_exec_avg", self.exec_jobs.mean());
        o.insert("jobs_per_exec_max", self.exec_jobs.max());
        o.insert("queue_wait_avg_ms", self.queue_wait.mean() * 1e3);
        o.insert(
            "queue_wait_p99_ms",
            self.queue_wait.percentile(99.0) * 1e3,
        );
        Value::Obj(o)
    }

    pub fn reset(&self) {
        self.executions.store(0, Ordering::Relaxed);
        self.jobs.store(0, Ordering::Relaxed);
        self.bypass_jobs.store(0, Ordering::Relaxed);
        self.padded_rows.store(0, Ordering::Relaxed);
        self.exec_rows.reset();
        self.exec_jobs.reset();
        self.queue_wait.reset();
    }
}

/// Per-worker counters of the cluster router tier (DESIGN.md §19): one
/// instance per member node, reported under the `/metrics` `cluster`
/// block and `GET /v1/cluster`.  The remote client records into these;
/// the health prober drives ejections/readmissions.
#[derive(Default)]
pub struct ClusterNodeStats {
    /// Requests attempted against this worker (each retry attempt counts).
    pub requests: AtomicU64,
    /// Attempts that failed (connect error, io error, 5xx).
    pub errors: AtomicU64,
    /// Attempts that were retries of an earlier failed attempt.
    pub retries: AtomicU64,
    /// Times this worker was ejected from the ring.
    pub ejections: AtomicU64,
    /// Times this worker was readmitted after ejection.
    pub readmissions: AtomicU64,
    /// Requests currently in flight towards this worker (gauge).
    pub inflight: AtomicU64,
    /// Attempts skipped because the in-flight cap was reached.
    pub at_capacity: AtomicU64,
    /// Fresh connections dialed.
    pub pool_created: AtomicU64,
    /// Attempts served over a pooled keep-alive connection.
    pub pool_reused: AtomicU64,
    /// Pooled connections found dead on first use (retried fresh without
    /// consuming a replica retry).
    pub pool_stale: AtomicU64,
    /// Per-attempt round-trip latency to this worker.
    pub rtt: Histogram,
}

impl ClusterNodeStats {
    pub fn snapshot(&self, wall: Duration) -> Value {
        let mut o = Object::new();
        let requests = self.requests.load(Ordering::Relaxed);
        o.insert("requests", requests);
        o.insert("errors", self.errors.load(Ordering::Relaxed));
        o.insert("retries", self.retries.load(Ordering::Relaxed));
        o.insert("ejections", self.ejections.load(Ordering::Relaxed));
        o.insert(
            "readmissions",
            self.readmissions.load(Ordering::Relaxed),
        );
        o.insert("inflight", self.inflight.load(Ordering::Relaxed));
        o.insert("at_capacity", self.at_capacity.load(Ordering::Relaxed));
        o.insert(
            "pool_created",
            self.pool_created.load(Ordering::Relaxed),
        );
        o.insert("pool_reused", self.pool_reused.load(Ordering::Relaxed));
        o.insert("pool_stale", self.pool_stale.load(Ordering::Relaxed));
        o.insert("qps", requests as f64 / wall.as_secs_f64().max(1e-9));
        o.insert("rtt_avg_ms", self.rtt.mean() * 1e3);
        o.insert("rtt_p99_ms", self.rtt.percentile(99.0) * 1e3);
        o.insert("rtt_max_ms", self.rtt.max() * 1e3);
        Value::Obj(o)
    }
}

#[derive(Default)]
pub struct ServingMetrics {
    /// End-to-end request latency (what the user sees).
    pub total_rt: Histogram,
    /// Real-time pre-rank phase only (the paper's RT metric: retrieval is
    /// upstream of pre-ranking, so avgRT/p99RT measure the pre-rank stage).
    pub prerank_rt: Histogram,
    /// Online-async user-side phase (overlapped with retrieval).
    pub user_async_rt: Histogram,
    /// Retrieval stage (upstream, for overlap accounting).
    pub retrieval_rt: Histogram,

    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub rtp_calls: AtomicU64,
    pub items_scored: AtomicU64,
    /// Async-phase time hidden under retrieval (the latency the paper's
    /// design removes from the critical path).
    pub overlap_saved_nanos: AtomicU64,
    /// Cross-request coalescing counters (`Arc` so the coalescer's
    /// dispatch thread records without holding the whole metrics struct).
    pub coalesce: Arc<CoalesceStats>,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(
        &self,
        total: Duration,
        prerank: Duration,
        user_async: Option<Duration>,
        retrieval: Duration,
    ) {
        self.total_rt.record(total);
        self.prerank_rt.record(prerank);
        self.retrieval_rt.record(retrieval);
        if let Some(ua) = user_async {
            self.user_async_rt.record(ua);
            let hidden = ua.min(retrieval);
            self.overlap_saved_nanos
                .fetch_add(hidden.as_nanos() as u64, Ordering::Relaxed);
        }
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn qps(&self, wall: Duration) -> f64 {
        self.requests.load(Ordering::Relaxed) as f64 / wall.as_secs_f64()
    }

    pub fn snapshot(&self, wall: Duration) -> Value {
        let mut o = Object::new();
        let hist = |h: &Histogram| {
            let mut v = Object::new();
            v.insert("count", h.count());
            v.insert("avg_ms", h.mean() * 1e3);
            v.insert("p50_ms", h.percentile(50.0) * 1e3);
            v.insert("p99_ms", h.percentile(99.0) * 1e3);
            v.insert("max_ms", h.max() * 1e3);
            Value::Obj(v)
        };
        o.insert("total_rt", hist(&self.total_rt));
        o.insert("prerank_rt", hist(&self.prerank_rt));
        o.insert("user_async_rt", hist(&self.user_async_rt));
        o.insert("retrieval_rt", hist(&self.retrieval_rt));
        o.insert("requests", self.requests.load(Ordering::Relaxed));
        o.insert("errors", self.errors.load(Ordering::Relaxed));
        // Total fleet executions: direct per-request calls plus merged
        // coalesced executions (which are one fleet call each) — so the
        // counter stays meaningful whichever way dispatch is configured.
        o.insert(
            "rtp_calls",
            self.rtp_calls.load(Ordering::Relaxed)
                + self.coalesce.executions.load(Ordering::Relaxed),
        );
        o.insert("items_scored", self.items_scored.load(Ordering::Relaxed));
        o.insert(
            "overlap_saved_ms_total",
            self.overlap_saved_nanos.load(Ordering::Relaxed) as f64 / 1e6,
        );
        o.insert("coalesce", self.coalesce.snapshot());
        o.insert("qps", self.qps(wall));
        Value::Obj(o)
    }

    pub fn reset(&self) {
        self.total_rt.reset();
        self.prerank_rt.reset();
        self.user_async_rt.reset();
        self.retrieval_rt.reset();
        self.requests.store(0, Ordering::Relaxed);
        self.errors.store(0, Ordering::Relaxed);
        self.rtp_calls.store(0, Ordering::Relaxed);
        self.items_scored.store(0, Ordering::Relaxed);
        self.overlap_saved_nanos.store(0, Ordering::Relaxed);
        self.coalesce.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_expected_fields() {
        let m = ServingMetrics::new();
        m.record_request(
            Duration::from_millis(20),
            Duration::from_millis(8),
            Some(Duration::from_millis(5)),
            Duration::from_millis(10),
        );
        let snap = m.snapshot(Duration::from_secs(1));
        assert_eq!(snap.req("requests").as_usize(), Some(1));
        assert!(snap.req("prerank_rt").req("avg_ms").as_f64().unwrap() > 7.0);
        // 5ms async fully hidden under 10ms retrieval.
        let saved = snap.req("overlap_saved_ms_total").as_f64().unwrap();
        assert!((saved - 5.0).abs() < 0.01, "{saved}");
        // Coalescing block is present (zeroed when coalescing is off).
        assert_eq!(
            snap.req("coalesce").req("executions").as_usize(),
            Some(0)
        );
    }

    #[test]
    fn cluster_node_stats_snapshot() {
        let s = ClusterNodeStats::default();
        s.requests.fetch_add(10, Ordering::Relaxed);
        s.errors.fetch_add(2, Ordering::Relaxed);
        s.retries.fetch_add(1, Ordering::Relaxed);
        s.ejections.fetch_add(1, Ordering::Relaxed);
        s.inflight.fetch_add(3, Ordering::Relaxed);
        s.pool_created.fetch_add(2, Ordering::Relaxed);
        s.pool_reused.fetch_add(8, Ordering::Relaxed);
        s.rtt.record(Duration::from_millis(4));
        let snap = s.snapshot(Duration::from_secs(2));
        assert_eq!(snap.req("requests").as_usize(), Some(10));
        assert_eq!(snap.req("errors").as_usize(), Some(2));
        assert_eq!(snap.req("retries").as_usize(), Some(1));
        assert_eq!(snap.req("ejections").as_usize(), Some(1));
        assert_eq!(snap.req("inflight").as_usize(), Some(3));
        assert_eq!(snap.req("pool_reused").as_usize(), Some(8));
        assert!((snap.req("qps").as_f64().unwrap() - 5.0).abs() < 1e-9);
        assert!(snap.req("rtt_p99_ms").as_f64().unwrap() > 3.0);
    }

    #[test]
    fn coalesce_stats_record_and_reset() {
        let m = ServingMetrics::new();
        m.coalesce.record_execution(3, 300, 512);
        m.coalesce.record_execution(1, 100, 512);
        m.coalesce.queue_wait.record(Duration::from_micros(150));
        let snap = m.coalesce.snapshot();
        assert_eq!(snap.req("executions").as_usize(), Some(2));
        assert_eq!(snap.req("jobs").as_usize(), Some(4));
        assert_eq!(snap.req("padded_rows").as_usize(), Some(212 + 412));
        assert!(
            (snap.req("rows_per_exec_avg").as_f64().unwrap() - 200.0).abs()
                < 1e-9
        );
        m.reset();
        assert_eq!(m.coalesce.executions.load(Ordering::Relaxed), 0);
        assert_eq!(m.coalesce.queue_wait.count(), 0);
    }
}
