//! Snapshot serialization: columnar N2O generations to/from versioned,
//! checksummed blobs (DESIGN.md §16).
//!
//! Two file kinds, both little-endian with a CRC32 trailer over every
//! preceding byte:
//!
//! * **Full** (`AIFSNAP1`): dims header + every chunk of one generation
//!   in stable ascending-id order.  All-absent chunks are a single flag
//!   byte (they share one zeroed allocation in memory, and on disk they
//!   cost nothing).
//! * **Delta** (`AIFDELT1`): the chunks whose `Arc` pointer changed
//!   since the previously published export — copy-on-write upserts make
//!   "changed since last checkpoint" a pointer comparison, not a diff.
//!
//! The snapshot header carries the table's lock-free `version_hint`
//! mirror so a restored table RESUMES the epoch sequence: resetting it
//! would silently un-invalidate `UserStateCache` entries keyed on the
//! composed epoch.

use crate::nearline::{N2oExport, RestoredChunk, N2O_CHUNK};

use super::backend::{crc32, Result, StorageError};

pub const FULL_MAGIC: &[u8; 8] = b"AIFSNAP1";
pub const DELTA_MAGIC: &[u8; 8] = b"AIFDELT1";

/// Decoded full snapshot, ready for `N2oTable::restore`.
pub struct FullSnapshot {
    pub d: usize,
    pub n_bridge: usize,
    pub n_bits: usize,
    pub version: u64,
    pub version_hint: u64,
    pub n_items: usize,
    pub chunks: Vec<Option<RestoredChunk>>,
}

/// Decoded delta, ready for `N2oTable::patch_chunks`.
pub struct DeltaFile {
    pub base_version: u64,
    pub seq: u64,
    pub n_items: usize,
    pub patches: Vec<(usize, RestoredChunk)>,
}

// -- little-endian writers ---------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_bools(out: &mut Vec<u8>, vs: &[bool]) {
    out.extend(vs.iter().map(|&b| b as u8));
}

fn seal(mut out: Vec<u8>) -> Vec<u8> {
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

// -- checked little-endian reader -------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    key: &'a str,
}

impl<'a> Reader<'a> {
    fn corrupt(&self, reason: &str) -> StorageError {
        StorageError::Corrupt {
            key: self.key.to_string(),
            reason: reason.to_string(),
        }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(self.corrupt("truncated"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn bools(&mut self, n: usize) -> Result<Vec<bool>> {
        Ok(self.bytes(n)?.iter().map(|&b| b != 0).collect())
    }
}

/// Verify the CRC32 trailer and return the body (everything before it).
fn verify<'a>(bytes: &'a [u8], key: &str) -> Result<&'a [u8]> {
    if bytes.len() < 12 {
        return Err(StorageError::Corrupt {
            key: key.to_string(),
            reason: "too short for header + checksum".into(),
        });
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(trailer.try_into().unwrap());
    let got = crc32(body);
    if want != got {
        return Err(StorageError::Corrupt {
            key: key.to_string(),
            reason: format!("checksum mismatch: {got:#010x} != {want:#010x}"),
        });
    }
    Ok(body)
}

fn put_chunk_payload(
    out: &mut Vec<u8>,
    c: &crate::nearline::N2oChunkView<'_>,
) {
    put_f32s(out, c.item_vec);
    put_f32s(out, c.bea_w);
    out.extend_from_slice(c.sign_packed);
    put_bools(out, c.present);
}

fn read_chunk_payload(
    r: &mut Reader<'_>,
    d: usize,
    n_bridge: usize,
    pl: usize,
) -> Result<RestoredChunk> {
    Ok(RestoredChunk {
        item_vec: r.f32s(N2O_CHUNK * d)?,
        bea_w: r.f32s(N2O_CHUNK * n_bridge)?,
        sign_packed: r.bytes(N2O_CHUNK * pl)?.to_vec(),
        present: r.bools(N2O_CHUNK)?,
    })
}

/// Serialize a pinned generation as a full snapshot.  `version_hint` is
/// the table's lock-free mirror, captured under the checkpoint barrier
/// alongside the export so the pair is consistent.
pub fn encode_full(ex: &N2oExport, version_hint: u64) -> Vec<u8> {
    let (d, n_bridge, n_bits) = ex.dims();
    let mut out = Vec::new();
    out.extend_from_slice(FULL_MAGIC);
    put_u32(&mut out, d as u32);
    put_u32(&mut out, n_bridge as u32);
    put_u32(&mut out, n_bits as u32);
    put_u64(&mut out, ex.version());
    put_u64(&mut out, version_hint);
    put_u64(&mut out, ex.n_items() as u64);
    put_u64(&mut out, ex.n_chunks() as u64);
    for i in 0..ex.n_chunks() {
        let c = ex.chunk(i);
        if c.any_present() {
            out.push(1);
            put_chunk_payload(&mut out, &c);
        } else {
            out.push(0);
        }
    }
    seal(out)
}

pub fn decode_full(bytes: &[u8], key: &str) -> Result<FullSnapshot> {
    let body = verify(bytes, key)?;
    let mut r = Reader { buf: body, pos: 0, key };
    if r.bytes(8)? != FULL_MAGIC {
        return Err(r.corrupt("bad magic (not a full snapshot)"));
    }
    let d = r.u32()? as usize;
    let n_bridge = r.u32()? as usize;
    let n_bits = r.u32()? as usize;
    let version = r.u64()?;
    let version_hint = r.u64()?;
    let n_items = r.u64()? as usize;
    let n_chunks = r.u64()? as usize;
    if n_chunks == 0 || n_chunks * N2O_CHUNK < n_items {
        return Err(r.corrupt("chunk count cannot hold n_items"));
    }
    let pl = n_bits.div_ceil(8);
    let mut chunks = Vec::with_capacity(n_chunks);
    for _ in 0..n_chunks {
        let flag = r.bytes(1)?[0];
        chunks.push(match flag {
            0 => None,
            1 => Some(read_chunk_payload(&mut r, d, n_bridge, pl)?),
            _ => return Err(r.corrupt("bad chunk flag")),
        });
    }
    if r.pos != body.len() {
        return Err(r.corrupt("trailing bytes after last chunk"));
    }
    Ok(FullSnapshot {
        d,
        n_bridge,
        n_bits,
        version,
        version_hint,
        n_items,
        chunks,
    })
}

/// Serialize the chunks that changed between two exports of the SAME
/// generation version (incremental upserts keep the version; a version
/// change means a full rebuild happened and callers must write a full
/// snapshot instead).  Returns `None` when nothing changed.
pub fn encode_delta(
    prev: &N2oExport,
    cur: &N2oExport,
    seq: u64,
) -> Option<Vec<u8>> {
    assert_eq!(
        prev.version(),
        cur.version(),
        "delta requires same base version"
    );
    let changed: Vec<usize> = (0..cur.n_chunks())
        .filter(|&i| {
            !cur.chunk_shared_with(prev, i) && cur.chunk(i).any_present()
        })
        .collect();
    if changed.is_empty() && cur.n_items() == prev.n_items() {
        return None;
    }
    let (d, n_bridge, n_bits) = cur.dims();
    let mut out = Vec::new();
    out.extend_from_slice(DELTA_MAGIC);
    put_u32(&mut out, d as u32);
    put_u32(&mut out, n_bridge as u32);
    put_u32(&mut out, n_bits as u32);
    put_u64(&mut out, cur.version());
    put_u64(&mut out, seq);
    put_u64(&mut out, cur.n_items() as u64);
    put_u32(&mut out, changed.len() as u32);
    for i in changed {
        put_u32(&mut out, i as u32);
        put_chunk_payload(&mut out, &cur.chunk(i));
    }
    Some(seal(out))
}

pub fn decode_delta(bytes: &[u8], key: &str) -> Result<DeltaFile> {
    let body = verify(bytes, key)?;
    let mut r = Reader { buf: body, pos: 0, key };
    if r.bytes(8)? != DELTA_MAGIC {
        return Err(r.corrupt("bad magic (not a delta)"));
    }
    let d = r.u32()? as usize;
    let n_bridge = r.u32()? as usize;
    let n_bits = r.u32()? as usize;
    let base_version = r.u64()?;
    let seq = r.u64()?;
    let n_items = r.u64()? as usize;
    let n_patches = r.u32()? as usize;
    let pl = n_bits.div_ceil(8);
    let mut patches = Vec::with_capacity(n_patches);
    for _ in 0..n_patches {
        let ci = r.u32()? as usize;
        patches.push((ci, read_chunk_payload(&mut r, d, n_bridge, pl)?));
    }
    if r.pos != body.len() {
        return Err(r.corrupt("trailing bytes after last patch"));
    }
    Ok(DeltaFile {
        base_version,
        seq,
        n_items,
        patches,
    })
}

/// FNV-1a digest over the full columnar state of an export, in stable
/// chunk order.  Scoring is deterministic given the N2O state, the
/// compiled artifacts and the user state, so digest equality between the
/// capture-side export and the restored table IS the bitwise-identity
/// check for restored scores — verified before readiness flips, and
/// re-verified end-to-end (actual top-K bytes) by the warm-restart
/// tests.
pub fn state_digest(ex: &N2oExport) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    let (d, n_bridge, n_bits) = ex.dims();
    mix(&(d as u64).to_le_bytes());
    mix(&(n_bridge as u64).to_le_bytes());
    mix(&(n_bits as u64).to_le_bytes());
    mix(&ex.version().to_le_bytes());
    mix(&(ex.n_items() as u64).to_le_bytes());
    mix(&(ex.n_chunks() as u64).to_le_bytes());
    for i in 0..ex.n_chunks() {
        let c = ex.chunk(i);
        for v in c.item_vec {
            mix(&v.to_le_bytes());
        }
        for v in c.bea_w {
            mix(&v.to_le_bytes());
        }
        mix(c.sign_packed);
        for &p in c.present {
            mix(&[p as u8]);
        }
    }
    h
}

/// Render a u64 digest as a fixed-width hex string for JSON manifests
/// (u64 does not survive a round-trip through JSON's f64 numbers).
pub fn digest_hex(d: u64) -> String {
    format!("{d:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nearline::{N2oEntry, N2oTable};

    fn entry(v: f32, id: u32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![v, id as f32, -v, 0.25],
            bea_w: vec![v; 2],
            sign_packed: vec![id as u8],
        }
    }

    fn build_table(n: usize) -> N2oTable {
        let t = N2oTable::new(n, 4, 2, 8);
        t.swap_full(
            (0..n)
                .map(|i| {
                    (i % 3 != 2).then(|| entry(0.5 + i as f32, i as u32))
                })
                .collect(),
            5,
        );
        t
    }

    fn restore_into(full: FullSnapshot) -> N2oTable {
        let t = N2oTable::new(full.n_items, full.d, full.n_bridge, full.n_bits);
        t.restore(full.chunks, full.n_items, full.version, full.version_hint);
        t
    }

    #[test]
    fn full_round_trip_is_bitwise_identical() {
        let src = build_table(N2O_CHUNK + 37);
        let bytes = encode_full(&src.export(), src.version_hint());
        let full = decode_full(&bytes, "k").unwrap();
        let dst = restore_into(full);
        assert_eq!(dst.version(), 5);
        assert_eq!(dst.version_hint(), 5);
        assert_eq!(state_digest(&dst.export()), state_digest(&src.export()));
        let (a, b) = (src.snapshot(), dst.snapshot());
        for i in 0..src.n_items() as u32 {
            match (a.get(i), b.get(i)) {
                (Some(x), Some(y)) => assert_eq!(x.to_entry(), y.to_entry()),
                (None, None) => {}
                _ => panic!("presence mismatch at {i}"),
            }
        }
    }

    #[test]
    fn corrupted_and_truncated_snapshots_are_rejected() {
        let src = build_table(16);
        let bytes = encode_full(&src.export(), src.version_hint());
        for i in [0, 12, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                matches!(
                    decode_full(&bad, "k"),
                    Err(StorageError::Corrupt { .. })
                ),
                "flip at byte {i} must be caught"
            );
        }
        for cut in [0, 4, 11, bytes.len() - 1] {
            assert!(matches!(
                decode_full(&bytes[..cut], "k"),
                Err(StorageError::Corrupt { .. })
            ));
        }
        // A delta blob is not a full snapshot.
        let delta_as_full = {
            let t2 = build_table(16);
            t2.upsert(vec![(1, entry(9.0, 1))]);
            encode_delta(&src.export(), &t2.export(), 1).unwrap()
        };
        assert!(decode_full(&delta_as_full, "k").is_err());
    }

    #[test]
    fn delta_round_trip_patches_to_equality() {
        let src = build_table(2 * N2O_CHUNK);
        let base = src.export();
        let full_bytes = encode_full(&base, src.version_hint());

        // Mutate chunk 1 only, plus grow the table into chunk 2.
        src.upsert(vec![
            (N2O_CHUNK as u32 + 3, entry(77.0, N2O_CHUNK as u32 + 3)),
            (2 * N2O_CHUNK as u32 + 1, entry(88.0, 1)),
        ]);
        let cur = src.export();
        let delta_bytes = encode_delta(&base, &cur, 1).unwrap();

        let dst = restore_into(decode_full(&full_bytes, "k").unwrap());
        let delta = decode_delta(&delta_bytes, "k").unwrap();
        assert_eq!(delta.base_version, 5);
        assert_eq!(delta.seq, 1);
        dst.patch_chunks(delta.n_items, delta.patches);
        assert_eq!(state_digest(&dst.export()), state_digest(&cur));
        assert_eq!(
            dst.snapshot()
                .get(2 * N2O_CHUNK as u32 + 1)
                .unwrap()
                .item_vec[0],
            88.0
        );
    }

    #[test]
    fn unchanged_export_produces_no_delta() {
        let src = build_table(64);
        let a = src.export();
        let b = src.export();
        assert!(encode_delta(&a, &b, 1).is_none());
    }

    #[test]
    fn encoding_is_deterministic() {
        let src = build_table(100);
        let a = encode_full(&src.export(), src.version_hint());
        let b = encode_full(&src.export(), src.version_hint());
        assert_eq!(a, b, "stable chunk order -> byte-identical snapshots");
    }

    #[test]
    fn digest_distinguishes_single_bit_changes() {
        let a = build_table(32);
        let b = build_table(32);
        assert_eq!(state_digest(&a.export()), state_digest(&b.export()));
        // Perturb one value by exactly one ULP — an additive epsilon
        // could round away and leave the table bit-identical.
        let mut e = entry(0.5 + 7.0, 7);
        e.item_vec[0] = f32::from_bits(e.item_vec[0].to_bits() ^ 1);
        b.upsert(vec![(7, e)]);
        assert_ne!(state_digest(&a.export()), state_digest(&b.export()));
    }
}
