//! Checkpointer: periodic durable publication of serving state, and the
//! warm-boot restore path (DESIGN.md §16).
//!
//! Publication is incremental: the first checkpoint of a generation
//! writes a full snapshot; later checkpoints of the SAME generation
//! write per-chunk deltas discovered by `Arc` pointer comparison against
//! the previously published export (copy-on-write upserts make the diff
//! free).  Every checkpoint ends with a manifest — allocated with
//! `put_if_not_exists` so concurrent publishers get exactly one winner
//! per id — and a `meta/LATEST` pointer naming the newest consistent
//! set.
//!
//! Capture happens under the checkpoint barrier shared with
//! `NearlineWorker::full_build` and `ScenarioRegistry::reload`, so a
//! manifest never records state that straddles a generation swap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::nearline::{N2oExport, N2oTable};
use crate::util::json::{Object, Value};

use super::backend::{Result, Storage, StorageError};
use super::snapshot::{self, digest_hex};
use super::{ReadyState, Readiness};

const LATEST_KEY: &str = "meta/LATEST";

fn full_key(version: u64) -> String {
    format!("n2o/v{version:012}/full.n2o")
}

fn delta_key(version: u64, seq: u64) -> String {
    format!("n2o/v{version:012}/delta-{seq:06}.n2o")
}

fn manifest_key(id: u64) -> String {
    format!("meta/manifest-{id:012}.json")
}

fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// What one `checkpoint()` call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointOutcome {
    /// Nothing changed since the last published checkpoint; no writes.
    Skipped,
    /// New generation (or first checkpoint): full snapshot written.
    Full,
    /// Same generation, changed chunks: delta written.
    Delta,
    /// Chunks unchanged but metadata (epoch / hint) moved: manifest only.
    MetaOnly,
}

impl CheckpointOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            CheckpointOutcome::Skipped => "skipped",
            CheckpointOutcome::Full => "full",
            CheckpointOutcome::Delta => "delta",
            CheckpointOutcome::MetaOnly => "meta_only",
        }
    }
}

/// Result of a successful warm-boot restore.
#[derive(Debug)]
pub struct RestoreReport {
    pub manifest_key: String,
    pub version: u64,
    pub version_hint: u64,
    pub n_items: usize,
    pub deltas_replayed: usize,
    pub user_epoch: u64,
    pub elapsed_ms: u64,
}

/// The last published state, kept for pointer-diffing the next delta.
struct Published {
    export: N2oExport,
    base_version: u64,
    digest: u64,
    version_hint: u64,
    user_epoch: u64,
    full_key: String,
    delta_keys: Vec<String>,
    next_seq: u64,
}

#[derive(Default)]
struct CkptState {
    published: Option<Published>,
    next_manifest_id: Option<u64>,
}

pub struct Checkpointer {
    store: Arc<dyn Storage>,
    barrier: Arc<Mutex<u64>>,
    state: Mutex<CkptState>,
    // Stats (the `/metrics` storage block).
    fulls_written: AtomicU64,
    deltas_written: AtomicU64,
    manifests_written: AtomicU64,
    bytes_written: AtomicU64,
    skipped_unchanged: AtomicU64,
    last_checkpoint_unix_ms: AtomicU64,
    restored: AtomicU64,
    restore_ms: AtomicU64,
    delta_replays: AtomicU64,
}

impl Checkpointer {
    pub fn new(store: Arc<dyn Storage>, barrier: Arc<Mutex<u64>>) -> Self {
        Checkpointer {
            store,
            barrier,
            state: Mutex::new(CkptState::default()),
            fulls_written: AtomicU64::new(0),
            deltas_written: AtomicU64::new(0),
            manifests_written: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            skipped_unchanged: AtomicU64::new(0),
            last_checkpoint_unix_ms: AtomicU64::new(0),
            restored: AtomicU64::new(0),
            restore_ms: AtomicU64::new(0),
            delta_replays: AtomicU64::new(0),
        }
    }

    pub fn store(&self) -> &Arc<dyn Storage> {
        &self.store
    }

    fn put_counted(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.store.put(key, bytes)?;
        self.bytes_written
            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Publish the current state.  `user_epoch` is the composed user
    /// cache epoch; `artifacts_dir` records which compiled-artifact
    /// manifest this snapshot was built against.
    pub fn checkpoint(
        &self,
        table: &N2oTable,
        user_epoch: u64,
        artifacts_dir: &str,
    ) -> Result<CheckpointOutcome> {
        let mut state = self.state.lock().unwrap();
        // Capture under the barrier: export + version_hint are taken as
        // one consistent pair, with no generation swap in between.  The
        // barrier is released before serialization — the pinned export
        // is immutable, so the expensive part runs without blocking
        // rebuilds or reloads.
        let (ex, hint) = {
            let mut crossings = self.barrier.lock().unwrap();
            *crossings += 1;
            (table.export(), table.version_hint())
        };
        let digest = snapshot::state_digest(&ex);

        let outcome = match &mut state.published {
            Some(p) if p.base_version == ex.version() => {
                if p.digest == digest
                    && p.version_hint == hint
                    && p.user_epoch == user_epoch
                {
                    self.skipped_unchanged.fetch_add(1, Ordering::Relaxed);
                    return Ok(CheckpointOutcome::Skipped);
                }
                let outcome =
                    match snapshot::encode_delta(&p.export, &ex, p.next_seq) {
                        Some(bytes) => {
                            let key = delta_key(ex.version(), p.next_seq);
                            self.put_counted(&key, &bytes)?;
                            self.deltas_written
                                .fetch_add(1, Ordering::Relaxed);
                            p.delta_keys.push(key);
                            p.next_seq += 1;
                            CheckpointOutcome::Delta
                        }
                        None => CheckpointOutcome::MetaOnly,
                    };
                p.export = ex;
                p.digest = digest;
                p.version_hint = hint;
                p.user_epoch = user_epoch;
                outcome
            }
            _ => {
                let bytes = snapshot::encode_full(&ex, hint);
                let key = full_key(ex.version());
                self.put_counted(&key, &bytes)?;
                self.fulls_written.fetch_add(1, Ordering::Relaxed);
                state.published = Some(Published {
                    base_version: ex.version(),
                    digest,
                    version_hint: hint,
                    user_epoch,
                    full_key: key,
                    delta_keys: Vec::new(),
                    next_seq: 1,
                    export: ex,
                });
                CheckpointOutcome::Full
            }
        };
        self.write_manifest(&mut state, user_epoch, artifacts_dir)?;
        Ok(outcome)
    }

    fn write_manifest(
        &self,
        state: &mut CkptState,
        user_epoch: u64,
        artifacts_dir: &str,
    ) -> Result<()> {
        if state.next_manifest_id.is_none() {
            // First manifest from this process: resume the id sequence
            // past whatever an earlier incarnation published.
            let max = self
                .store
                .list("meta/manifest-")?
                .iter()
                .filter_map(|k| parse_manifest_id(k))
                .max();
            state.next_manifest_id = Some(max.map_or(0, |m| m + 1));
        }
        let p = state.published.as_ref().expect("published before manifest");

        let mut n2o = Object::new();
        n2o.insert("version", p.base_version);
        n2o.insert("version_hint", p.version_hint);
        n2o.insert("n_items", p.export.n_items());
        n2o.insert("digest", digest_hex(p.digest));
        n2o.insert("full", p.full_key.as_str());
        n2o.insert(
            "deltas",
            p.delta_keys
                .iter()
                .map(|k| Value::from(k.as_str()))
                .collect::<Vec<Value>>(),
        );
        let mut user_cache = Object::new();
        user_cache.insert("epoch", user_epoch);
        let mut artifacts = Object::new();
        artifacts.insert("dir", artifacts_dir);

        let mut id = state.next_manifest_id.unwrap();
        let key = loop {
            let mut m = Object::new();
            m.insert("checkpoint_id", id);
            m.insert("created_unix_ms", unix_ms());
            m.insert("n2o", n2o.clone());
            m.insert("user_cache", user_cache.clone());
            m.insert("artifacts", artifacts.clone());
            let body = Value::from(m).to_string_pretty();
            let key = manifest_key(id);
            // Leader-safe id allocation: losing the race means another
            // publisher took this id — step past it and retry.
            if self.store.put_if_not_exists(key.as_str(), body.as_bytes())? {
                self.bytes_written
                    .fetch_add(body.len() as u64, Ordering::Relaxed);
                break key;
            }
            id += 1;
        };
        state.next_manifest_id = Some(id + 1);
        self.store.put(LATEST_KEY, key.as_bytes())?;
        self.manifests_written.fetch_add(1, Ordering::Relaxed);
        self.last_checkpoint_unix_ms.store(unix_ms(), Ordering::Relaxed);
        Ok(())
    }

    /// Warm-boot restore: newest manifest -> full snapshot -> delta
    /// replay -> digest verification.  Returns `Ok(None)` when the store
    /// holds no checkpoint yet (cold boot).  Advances `readiness`
    /// through Restoring/Replaying/Verifying; the caller flips Ready.
    pub fn restore(
        &self,
        table: &N2oTable,
        readiness: &Readiness,
    ) -> Result<Option<RestoreReport>> {
        let t0 = Instant::now();
        let manifest_key = match self.store.get(LATEST_KEY) {
            Ok(b) => String::from_utf8_lossy(&b).trim().to_string(),
            Err(StorageError::NotFound(_)) => return Ok(None),
            Err(e) => return Err(e),
        };
        let corrupt = |reason: &str| StorageError::Corrupt {
            key: manifest_key.clone(),
            reason: reason.to_string(),
        };
        let manifest_bytes = self.store.get(&manifest_key)?;
        let manifest = Value::parse(
            std::str::from_utf8(&manifest_bytes)
                .map_err(|_| corrupt("manifest is not utf-8"))?,
        )
        .map_err(|e| corrupt(&format!("manifest parse: {e:?}")))?;
        let root = manifest.as_obj().ok_or_else(|| corrupt("not an object"))?;
        let n2o = root
            .get("n2o")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| corrupt("missing n2o block"))?;
        let version = n2o
            .get("version")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| corrupt("missing n2o.version"))?
            as u64;
        let version_hint = n2o
            .get("version_hint")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| corrupt("missing n2o.version_hint"))?
            as u64;
        let want_digest = n2o
            .get("digest")
            .and_then(|v| v.as_str())
            .ok_or_else(|| corrupt("missing n2o.digest"))?
            .to_string();
        let full_key = n2o
            .get("full")
            .and_then(|v| v.as_str())
            .ok_or_else(|| corrupt("missing n2o.full"))?
            .to_string();
        let delta_keys: Vec<String> = n2o
            .get("deltas")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| corrupt("missing n2o.deltas"))?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let user_epoch = root
            .get("user_cache")
            .and_then(|v| v.as_obj())
            .and_then(|o| o.get("epoch"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;

        // Phase 1: restore the full snapshot.
        readiness.set(ReadyState::Restoring);
        let full =
            snapshot::decode_full(&self.store.get(&full_key)?, &full_key)?;
        if (full.d, full.n_bridge, full.n_bits)
            != (table.d, table.n_bridge, table.n_bits)
        {
            return Err(StorageError::Corrupt {
                key: full_key,
                reason: format!(
                    "dims mismatch: snapshot ({},{},{}) vs table ({},{},{})",
                    full.d,
                    full.n_bridge,
                    full.n_bits,
                    table.d,
                    table.n_bridge,
                    table.n_bits
                ),
            });
        }
        if full.version != version {
            return Err(StorageError::Corrupt {
                key: full_key,
                reason: format!(
                    "full snapshot version {} != manifest version {version}",
                    full.version
                ),
            });
        }
        table.restore(full.chunks, full.n_items, version, version_hint);

        // Phase 2: replay the delta queue in published order.
        readiness.set(ReadyState::Replaying);
        let mut replayed = 0usize;
        for key in &delta_keys {
            let delta = snapshot::decode_delta(&self.store.get(key)?, key)?;
            if delta.base_version != version {
                return Err(StorageError::Corrupt {
                    key: key.clone(),
                    reason: format!(
                        "delta base {} != snapshot version {version}",
                        delta.base_version
                    ),
                });
            }
            table.patch_chunks(delta.n_items, delta.patches);
            replayed += 1;
        }

        // Phase 3: verify the restored state is bitwise-identical to
        // what the manifest recorded, BEFORE the caller flips readiness.
        readiness.set(ReadyState::Verifying);
        let ex = table.export();
        let digest = snapshot::state_digest(&ex);
        if digest_hex(digest) != want_digest {
            return Err(StorageError::Corrupt {
                key: manifest_key,
                reason: format!(
                    "restored digest {} != manifest digest {want_digest}",
                    digest_hex(digest)
                ),
            });
        }

        // Seed the publication state so the NEXT checkpoint diffs
        // against the restored export instead of rewriting a full.
        let n_items = ex.n_items();
        {
            let mut state = self.state.lock().unwrap();
            state.published = Some(Published {
                base_version: version,
                digest,
                version_hint,
                user_epoch,
                full_key: full_key.clone(),
                next_seq: replayed as u64 + 1,
                delta_keys,
                export: ex,
            });
        }

        let elapsed_ms = t0.elapsed().as_millis() as u64;
        self.restored.store(1, Ordering::Relaxed);
        self.restore_ms.store(elapsed_ms, Ordering::Relaxed);
        self.delta_replays
            .fetch_add(replayed as u64, Ordering::Relaxed);
        Ok(Some(RestoreReport {
            manifest_key,
            version,
            version_hint,
            n_items,
            deltas_replayed: replayed,
            user_epoch,
            elapsed_ms,
        }))
    }

    /// The `/metrics` storage block (same shape discipline as the
    /// arena / user_cache blocks).
    pub fn stats_snapshot(&self) -> Object {
        let mut o = Object::new();
        o.insert(
            "snapshots_full",
            self.fulls_written.load(Ordering::Relaxed),
        );
        o.insert(
            "snapshots_delta",
            self.deltas_written.load(Ordering::Relaxed),
        );
        o.insert(
            "manifests",
            self.manifests_written.load(Ordering::Relaxed),
        );
        o.insert("bytes_written", self.bytes_written.load(Ordering::Relaxed));
        o.insert(
            "skipped_unchanged",
            self.skipped_unchanged.load(Ordering::Relaxed),
        );
        let last_ms = self.last_checkpoint_unix_ms.load(Ordering::Relaxed);
        o.insert("last_checkpoint_unix_ms", last_ms);
        o.insert(
            "last_checkpoint_age_ms",
            if last_ms == 0 {
                -1i64
            } else {
                unix_ms().saturating_sub(last_ms) as i64
            },
        );
        o.insert("restored", self.restored.load(Ordering::Relaxed) == 1);
        o.insert("restore_ms", self.restore_ms.load(Ordering::Relaxed));
        o.insert(
            "delta_replays",
            self.delta_replays.load(Ordering::Relaxed),
        );
        o.insert("barrier_crossings", *self.barrier.lock().unwrap());
        o
    }
}

fn parse_manifest_id(key: &str) -> Option<u64> {
    key.strip_prefix("meta/manifest-")?
        .strip_suffix(".json")?
        .parse()
        .ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nearline::N2oEntry;
    use crate::storage::MemStorage;

    fn entry(v: f32, id: u32) -> N2oEntry {
        N2oEntry {
            item_vec: vec![v, id as f32, 0.0, 1.0],
            bea_w: vec![v; 2],
            sign_packed: vec![id as u8],
        }
    }

    fn checkpointer() -> Checkpointer {
        Checkpointer::new(
            Arc::new(MemStorage::new()),
            Arc::new(Mutex::new(0)),
        )
    }

    #[test]
    fn full_then_delta_then_skip() {
        let cp = checkpointer();
        let t = N2oTable::new(8, 4, 2, 8);
        t.swap_full((0..8).map(|i| Some(entry(1.0, i as u32))).collect(), 1);
        assert_eq!(
            cp.checkpoint(&t, 0, "art").unwrap(),
            CheckpointOutcome::Full
        );
        assert_eq!(
            cp.checkpoint(&t, 0, "art").unwrap(),
            CheckpointOutcome::Skipped
        );
        t.upsert(vec![(3, entry(9.0, 3))]);
        assert_eq!(
            cp.checkpoint(&t, 0, "art").unwrap(),
            CheckpointOutcome::Delta
        );
        // Epoch-only movement publishes a manifest without new blobs.
        assert_eq!(
            cp.checkpoint(&t, 1, "art").unwrap(),
            CheckpointOutcome::MetaOnly
        );
        // A rebuild (new generation) forces a full snapshot again.
        t.swap_full((0..8).map(|i| Some(entry(2.0, i as u32))).collect(), 2);
        assert_eq!(
            cp.checkpoint(&t, 1, "art").unwrap(),
            CheckpointOutcome::Full
        );
    }

    #[test]
    fn restore_round_trip_with_deltas() {
        let store: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let cp =
            Checkpointer::new(Arc::clone(&store), Arc::new(Mutex::new(0)));
        let src = N2oTable::new(8, 4, 2, 8);
        src.swap_full(
            (0..8).map(|i| Some(entry(1.0, i as u32))).collect(),
            4,
        );
        cp.checkpoint(&src, 10, "art").unwrap();
        src.upsert(vec![(2, entry(7.0, 2)), (9, entry(8.0, 9))]);
        cp.checkpoint(&src, 11, "art").unwrap();

        let cp2 = Checkpointer::new(store, Arc::new(Mutex::new(0)));
        let dst = N2oTable::new(8, 4, 2, 8);
        let readiness = Readiness::new();
        let report = cp2.restore(&dst, &readiness).unwrap().unwrap();
        assert_eq!(report.version, 4);
        assert_eq!(report.deltas_replayed, 1);
        assert_eq!(report.user_epoch, 11);
        assert_eq!(dst.version_hint(), 4);
        assert_eq!(dst.n_items(), 10);
        assert_eq!(dst.snapshot().get(9).unwrap().item_vec[0], 8.0);
        assert_eq!(
            snapshot::state_digest(&dst.export()),
            snapshot::state_digest(&src.export())
        );
        // Restore seeds publication state: an unchanged re-checkpoint
        // from the restored process skips instead of rewriting a full.
        assert_eq!(
            cp2.checkpoint(&dst, 11, "art").unwrap(),
            CheckpointOutcome::Skipped
        );
    }

    #[test]
    fn restore_on_empty_store_is_none() {
        let cp = checkpointer();
        let t = N2oTable::new(4, 4, 2, 8);
        assert!(cp.restore(&t, &Readiness::new()).unwrap().is_none());
    }

    #[test]
    fn restore_rejects_dims_mismatch() {
        let store: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let cp =
            Checkpointer::new(Arc::clone(&store), Arc::new(Mutex::new(0)));
        let src = N2oTable::new(4, 4, 2, 8);
        src.swap_full(vec![Some(entry(1.0, 0)); 4], 1);
        cp.checkpoint(&src, 0, "art").unwrap();
        let cp2 = Checkpointer::new(store, Arc::new(Mutex::new(0)));
        let dst = N2oTable::new(4, 6, 2, 8); // d=6, snapshot has d=4
        assert!(matches!(
            cp2.restore(&dst, &Readiness::new()),
            Err(StorageError::Corrupt { .. })
        ));
    }

    #[test]
    fn manifest_ids_resume_across_incarnations() {
        let store: Arc<dyn Storage> = Arc::new(MemStorage::new());
        let cp =
            Checkpointer::new(Arc::clone(&store), Arc::new(Mutex::new(0)));
        let t = N2oTable::new(4, 4, 2, 8);
        t.swap_full(vec![Some(entry(1.0, 0)); 4], 1);
        cp.checkpoint(&t, 0, "art").unwrap();
        t.upsert(vec![(0, entry(2.0, 0))]);
        cp.checkpoint(&t, 0, "art").unwrap();

        let cp2 =
            Checkpointer::new(Arc::clone(&store), Arc::new(Mutex::new(0)));
        t.upsert(vec![(1, entry(3.0, 1))]);
        cp2.checkpoint(&t, 0, "art").unwrap();
        let manifests = store.list("meta/manifest-").unwrap();
        assert_eq!(manifests.len(), 3, "no id collision: {manifests:?}");
    }
}
