//! Durable state store (DESIGN.md §16): pluggable versioned-key blob
//! backends, checksummed N2O snapshot serialization, and the
//! checkpointer that publishes incremental checkpoints and warm-boots a
//! restarted node from the newest consistent set — so a restart replays
//! a delta queue instead of recomputing the item corpus.

pub mod backend;
pub mod checkpoint;
pub mod snapshot;

pub use backend::{crc32, FsStorage, MemStorage, Storage, StorageError};
pub use checkpoint::{CheckpointOutcome, Checkpointer, RestoreReport};
pub use snapshot::{
    decode_delta, decode_full, digest_hex, encode_delta, encode_full,
    state_digest, DeltaFile, FullSnapshot,
};

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::Object;

/// Warm-boot state machine.  A node serves traffic only in `Ready`;
/// `/readyz` returns 503 in every other state so a router never sends
/// traffic to a node that would serve stale or partial N2O state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ReadyState {
    /// Process up, nearline state not yet established.
    Starting = 0,
    /// Reading the full snapshot from the store.
    Restoring = 1,
    /// Replaying the per-chunk delta queue.
    Replaying = 2,
    /// Digest-verifying the restored state against the manifest.
    Verifying = 3,
    /// Cold path: full N2O rebuild in progress (no usable snapshot).
    Building = 4,
    /// Serving.
    Ready = 5,
}

impl ReadyState {
    pub fn name(&self) -> &'static str {
        match self {
            ReadyState::Starting => "starting",
            ReadyState::Restoring => "restoring",
            ReadyState::Replaying => "replaying",
            ReadyState::Verifying => "verifying",
            ReadyState::Building => "building",
            ReadyState::Ready => "ready",
        }
    }

    fn from_u8(v: u8) -> ReadyState {
        match v {
            0 => ReadyState::Starting,
            1 => ReadyState::Restoring,
            2 => ReadyState::Replaying,
            3 => ReadyState::Verifying,
            4 => ReadyState::Building,
            _ => ReadyState::Ready,
        }
    }
}

/// Lock-free readiness gate, shared between the warm-boot path (writer)
/// and the `/readyz` endpoint (reader).
pub struct Readiness {
    state: AtomicU8,
}

impl Default for Readiness {
    fn default() -> Self {
        Self::new()
    }
}

impl Readiness {
    pub fn new() -> Self {
        Readiness {
            state: AtomicU8::new(ReadyState::Starting as u8),
        }
    }

    pub fn set(&self, s: ReadyState) {
        self.state.store(s as u8, Ordering::Release);
    }

    pub fn get(&self) -> ReadyState {
        ReadyState::from_u8(self.state.load(Ordering::Acquire))
    }

    pub fn is_ready(&self) -> bool {
        self.get() == ReadyState::Ready
    }

    pub fn as_json(&self) -> Object {
        let s = self.get();
        let mut o = Object::new();
        o.insert("ready", s == ReadyState::Ready);
        o.insert("state", s.name());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readiness_walks_the_state_machine() {
        let r = Readiness::new();
        assert!(!r.is_ready());
        assert_eq!(r.get().name(), "starting");
        for s in [
            ReadyState::Restoring,
            ReadyState::Replaying,
            ReadyState::Verifying,
            ReadyState::Ready,
        ] {
            r.set(s);
            assert_eq!(r.get(), s);
        }
        assert!(r.is_ready());
        let j = r.as_json();
        assert_eq!(j.get("ready").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("state").unwrap().as_str(), Some("ready"));
    }
}
