//! Pluggable storage backends with an S3-shaped API.
//!
//! The `Storage` trait is intentionally small: opaque byte blobs under
//! string keys, prefix listing, and a conditional `put_if_not_exists`
//! used for leader-safe manifest allocation (exactly one writer wins a
//! given key). `MemStorage` backs tests; `FsStorage` maps keys onto a
//! directory tree with atomic rename-based writes so a real object
//! store can slot in behind the same trait later.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use thiserror::Error;

#[derive(Debug, Error)]
pub enum StorageError {
    #[error("key not found: {0}")]
    NotFound(String),
    #[error("corrupt blob at {key}: {reason}")]
    Corrupt { key: String, reason: String },
    #[error("io error at {key}: {source}")]
    Io {
        key: String,
        #[source]
        source: std::io::Error,
    },
}

pub type Result<T> = std::result::Result<T, StorageError>;

/// Versioned-key blob store. Keys use `/` as a hierarchy separator
/// (like S3 object keys); values are opaque byte blobs.
pub trait Storage: Send + Sync {
    fn get(&self, key: &str) -> Result<Vec<u8>>;
    fn put(&self, key: &str, value: &[u8]) -> Result<()>;
    /// Atomic create: returns `Ok(true)` if this call created the key,
    /// `Ok(false)` if the key already existed (value left untouched).
    fn put_if_not_exists(&self, key: &str, value: &[u8]) -> Result<bool>;
    /// All keys with the given prefix, in sorted (lexicographic) order.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;
    fn delete(&self, key: &str) -> Result<()>;
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected). Vendored so snapshot files are
// self-checking without pulling in a dependency.
// ---------------------------------------------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC32 (IEEE) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// MemStorage
// ---------------------------------------------------------------------------

/// In-process backend: a mutex-guarded ordered map. `Arc<Vec<u8>>`
/// values keep `get` cheap to clone out under the lock.
#[derive(Default)]
pub struct MemStorage {
    blobs: Mutex<BTreeMap<String, Arc<Vec<u8>>>>,
    puts: AtomicU64,
}

impl MemStorage {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn puts(&self) -> u64 {
        self.puts.load(Ordering::Relaxed)
    }
}

impl Storage for MemStorage {
    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let blobs = self.blobs.lock().unwrap();
        blobs
            .get(key)
            .map(|v| v.as_ref().clone())
            .ok_or_else(|| StorageError::NotFound(key.to_string()))
    }

    fn put(&self, key: &str, value: &[u8]) -> Result<()> {
        self.puts.fetch_add(1, Ordering::Relaxed);
        let mut blobs = self.blobs.lock().unwrap();
        blobs.insert(key.to_string(), Arc::new(value.to_vec()));
        Ok(())
    }

    fn put_if_not_exists(&self, key: &str, value: &[u8]) -> Result<bool> {
        let mut blobs = self.blobs.lock().unwrap();
        if blobs.contains_key(key) {
            return Ok(false);
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        blobs.insert(key.to_string(), Arc::new(value.to_vec()));
        Ok(true)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let blobs = self.blobs.lock().unwrap();
        Ok(blobs
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }

    fn delete(&self, key: &str) -> Result<()> {
        let mut blobs = self.blobs.lock().unwrap();
        blobs.remove(key);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FsStorage
// ---------------------------------------------------------------------------

/// Filesystem backend rooted at a directory. Key `a/b/c` maps to
/// `<root>/a/b/c`. Writes land in a temp file first and are installed
/// with `rename` (atomic on POSIX); `put_if_not_exists` installs with
/// `hard_link`, which fails if the destination exists — giving the same
/// exactly-one-winner semantics as a conditional S3 PUT.
pub struct FsStorage {
    root: PathBuf,
    tmp_seq: AtomicU64,
}

impl FsStorage {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root).map_err(|e| StorageError::Io {
            key: root.display().to_string(),
            source: e,
        })?;
        Ok(FsStorage { root, tmp_seq: AtomicU64::new(0) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn key_path(&self, key: &str) -> Result<PathBuf> {
        // Reject path traversal; keys are plain `/`-separated names.
        if key.is_empty()
            || key.split('/').any(|c| {
                c.is_empty() || c == "." || c == ".." || c.starts_with(".tmp-")
            })
        {
            return Err(StorageError::Corrupt {
                key: key.to_string(),
                reason: "invalid key".into(),
            });
        }
        Ok(self.root.join(key))
    }

    /// Write `value` to a unique temp file next to `path`, fsync'd.
    fn stage(&self, path: &Path, key: &str, value: &[u8]) -> Result<PathBuf> {
        let parent = path.parent().unwrap_or(&self.root);
        fs::create_dir_all(parent).map_err(|e| StorageError::Io {
            key: key.to_string(),
            source: e,
        })?;
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = parent.join(format!(
            ".tmp-{}-{}-{}",
            std::process::id(),
            seq,
            path.file_name().and_then(|n| n.to_str()).unwrap_or("blob")
        ));
        let write = (|| {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(value)?;
            f.sync_all()
        })();
        if let Err(e) = write {
            let _ = fs::remove_file(&tmp);
            return Err(StorageError::Io { key: key.to_string(), source: e });
        }
        Ok(tmp)
    }
}

impl Storage for FsStorage {
    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let path = self.key_path(key)?;
        match fs::read(&path) {
            Ok(v) => Ok(v),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(StorageError::NotFound(key.to_string()))
            }
            Err(e) => Err(StorageError::Io { key: key.to_string(), source: e }),
        }
    }

    fn put(&self, key: &str, value: &[u8]) -> Result<()> {
        let path = self.key_path(key)?;
        let tmp = self.stage(&path, key, value)?;
        fs::rename(&tmp, &path).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            StorageError::Io { key: key.to_string(), source: e }
        })
    }

    fn put_if_not_exists(&self, key: &str, value: &[u8]) -> Result<bool> {
        let path = self.key_path(key)?;
        let tmp = self.stage(&path, key, value)?;
        let linked = fs::hard_link(&tmp, &path);
        let _ = fs::remove_file(&tmp);
        match linked {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                Ok(false)
            }
            Err(e) => Err(StorageError::Io { key: key.to_string(), source: e }),
        }
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match fs::read_dir(&dir) {
                Ok(e) => e,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(StorageError::Io {
                        key: prefix.to_string(),
                        source: e,
                    })
                }
            };
            for entry in entries {
                let entry = entry.map_err(|e| StorageError::Io {
                    key: prefix.to_string(),
                    source: e,
                })?;
                let path = entry.path();
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if name.starts_with(".tmp-") {
                    continue;
                }
                if path.is_dir() {
                    stack.push(path);
                } else if let Ok(rel) = path.strip_prefix(&self.root) {
                    let key = rel
                        .components()
                        .map(|c| c.as_os_str().to_string_lossy())
                        .collect::<Vec<_>>()
                        .join("/");
                    if key.starts_with(prefix) {
                        out.push(key);
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn delete(&self, key: &str) -> Result<()> {
        let path = self.key_path(key)?;
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(StorageError::Io { key: key.to_string(), source: e }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("aif_storage_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn exercise(store: &dyn Storage) {
        assert!(matches!(store.get("a/b"), Err(StorageError::NotFound(_))));
        store.put("a/b", b"one").unwrap();
        assert_eq!(store.get("a/b").unwrap(), b"one");
        store.put("a/b", b"two").unwrap();
        assert_eq!(store.get("a/b").unwrap(), b"two");

        assert!(!store.put_if_not_exists("a/b", b"three").unwrap());
        assert_eq!(store.get("a/b").unwrap(), b"two");
        assert!(store.put_if_not_exists("a/c", b"new").unwrap());
        assert_eq!(store.get("a/c").unwrap(), b"new");

        store.put("z/deep/key", b"z").unwrap();
        assert_eq!(store.list("a/").unwrap(), vec!["a/b", "a/c"]);
        assert_eq!(store.list("").unwrap(), vec!["a/b", "a/c", "z/deep/key"]);

        store.delete("a/b").unwrap();
        store.delete("a/b").unwrap(); // idempotent
        assert!(matches!(store.get("a/b"), Err(StorageError::NotFound(_))));
        assert_eq!(store.list("a/").unwrap(), vec!["a/c"]);
    }

    #[test]
    fn mem_storage_basic_ops() {
        exercise(&MemStorage::new());
    }

    #[test]
    fn fs_storage_basic_ops() {
        let dir = tmp_dir("basic");
        exercise(&FsStorage::new(&dir).unwrap());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fs_storage_rejects_traversal_keys() {
        let dir = tmp_dir("traversal");
        let s = FsStorage::new(&dir).unwrap();
        for bad in ["../escape", "a//b", "", "a/./b", ".tmp-x"] {
            assert!(s.put(bad, b"x").is_err(), "key {bad:?} must be rejected");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fs_storage_list_skips_temp_files() {
        let dir = tmp_dir("tmpskip");
        let s = FsStorage::new(&dir).unwrap();
        s.put("k", b"v").unwrap();
        fs::write(dir.join(".tmp-999-0-k"), b"partial").unwrap();
        assert_eq!(s.list("").unwrap(), vec!["k"]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_if_not_exists_race_has_one_winner() {
        let dir = tmp_dir("race");
        let fs_store: Arc<dyn Storage> =
            Arc::new(FsStorage::new(&dir).unwrap());
        let mem_store: Arc<dyn Storage> = Arc::new(MemStorage::new());
        for store in [fs_store, mem_store] {
            let wins: Vec<bool> = std::thread::scope(|scope| {
                (0..8)
                    .map(|i| {
                        let store = &store;
                        scope.spawn(move || {
                            store
                                .put_if_not_exists(
                                    "meta/manifest-0.json",
                                    format!("writer-{i}").as_bytes(),
                                )
                                .unwrap()
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            assert_eq!(
                wins.iter().filter(|&&w| w).count(),
                1,
                "exactly one writer must win"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
