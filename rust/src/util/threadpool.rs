//! Fixed-size worker thread pool over std channels (tokio is not vendored).
//!
//! Two shapes are provided:
//!
//! * [`ThreadPool`] — fire-and-forget closures + a `scope`-style join, used
//!   by the nearline N2O builder ("highly concurrent processes for parallel
//!   computation", §3.4) and the load generator.
//! * [`WorkerSet`] — N long-lived workers each owning a `!Send` resource
//!   (a PJRT client + compiled executables), fed through per-worker request
//!   channels.  This is the substrate under `runtime::RtpPool`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared-queue thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        assert!(n_threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n_threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("aif-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool worker died");
    }

    /// Jobs spawned but not yet finished (queued + running) — the
    /// backpressure signal for bounded-concurrency callers.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with yield) until every spawned job has finished.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::Acquire) != 0 {
            std::thread::yield_now();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results = Arc::new(Mutex::new(Vec::from_iter(
            std::iter::repeat_with(|| None::<R>).take(n),
        )));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.spawn(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("map results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("worker panicked before writing result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// N long-lived workers, each owning a thread-local (possibly `!Send`)
/// resource created *on* the worker thread by the init closure.  Requests
/// are closures that receive `&mut` access to that resource.
pub struct WorkerSet<Req: Send + 'static> {
    txs: Vec<Sender<Req>>,
    workers: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl<Req: Send + 'static> WorkerSet<Req> {
    /// `init(worker_idx)` builds the per-thread resource; `handle` services
    /// one request against it.  Panics in `init` abort the process early —
    /// better than deadlocking on a missing worker.
    pub fn new<R, I, H>(n_workers: usize, init: I, handle: H) -> Self
    where
        I: Fn(usize) -> R + Send + Sync + 'static,
        H: Fn(&mut R, Req) + Send + Sync + 'static,
    {
        assert!(n_workers > 0);
        let init = Arc::new(init);
        let handle = Arc::new(handle);
        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let (tx, rx): (Sender<Req>, Receiver<Req>) = channel();
            let init = Arc::clone(&init);
            let handle = Arc::clone(&handle);
            let w = std::thread::Builder::new()
                .name(format!("aif-worker-{i}"))
                .spawn(move || {
                    let mut resource = init(i);
                    while let Ok(req) = rx.recv() {
                        handle(&mut resource, req);
                    }
                })
                .expect("spawn worker");
            txs.push(tx);
            workers.push(w);
        }
        WorkerSet {
            txs,
            workers,
            next: AtomicUsize::new(0),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.txs.len()
    }

    /// Round-robin dispatch.
    pub fn submit(&self, req: Req) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.txs[i].send(req).expect("worker died");
    }

    /// Dispatch to a specific worker (consistent-hash routing).
    pub fn submit_to(&self, worker: usize, req: Req) {
        self.txs[worker % self.txs.len()]
            .send(req)
            .expect("worker died");
    }

    /// Drop senders and join all workers.
    pub fn shutdown(mut self) {
        self.txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<Req: Send + 'static> Drop for WorkerSet<Req> {
    fn drop(&mut self) {
        self.txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64u64).collect(), |x| x * x);
        assert_eq!(out, (0..64u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn worker_set_round_robin_and_reply() {
        // Each worker owns a (non-clonable) local counter; requests carry a
        // reply channel — the same shape as RtpPool.
        struct Req {
            x: u64,
            reply: Sender<(usize, u64)>,
        }
        let ws = WorkerSet::new(
            3,
            |i| (i, 0u64),
            |state: &mut (usize, u64), req: Req| {
                state.1 += 1;
                req.reply.send((state.0, req.x * 2)).unwrap();
            },
        );
        let (tx, rx) = channel();
        for x in 0..30 {
            ws.submit(Req {
                x,
                reply: tx.clone(),
            });
        }
        let mut seen_workers = std::collections::HashSet::new();
        let mut sum = 0;
        for _ in 0..30 {
            let (w, y) = rx.recv().unwrap();
            seen_workers.insert(w);
            sum += y;
        }
        assert_eq!(sum, (0..30u64).map(|x| x * 2).sum::<u64>());
        assert_eq!(seen_workers.len(), 3, "round-robin uses every worker");
    }

    #[test]
    fn worker_set_submit_to_is_sticky() {
        struct Req {
            reply: Sender<usize>,
        }
        let ws = WorkerSet::new(
            4,
            |i| i,
            |me: &mut usize, req: Req| {
                req.reply.send(*me).unwrap();
            },
        );
        let (tx, rx) = channel();
        for _ in 0..10 {
            ws.submit_to(2, Req { reply: tx.clone() });
        }
        for _ in 0..10 {
            assert_eq!(rx.recv().unwrap(), 2);
        }
    }
}
