//! Self-built substrates for the vendored-only environment (DESIGN.md §3):
//! JSON, CLI parsing, RNG + distributions, thread pool, bench harness,
//! base64, bit utilities and a miniature property-testing framework.

pub mod base64;
pub mod bench;
pub mod bits;
pub mod cli;
pub mod fixture;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod tls;
