//! Bit-level utilities for LSH signatures (§4.2 of the paper).
//!
//! The paper stores `Relu(Sign(M W^T))` bits packed into uint8 and computes
//! similarity as XNOR + PopulationCount, replacing popcount with a 1×256
//! lookup table.  That is exactly what lives here: the packed representation
//! is what the N2O index table and the user cache store / transmit; the
//! unpacked ±1 planes are produced only at mini-batch assembly time for the
//! MXU-friendly HLO (DESIGN.md §7).

/// Precomputed population-count lookup table (the paper's 1×256 embedding
/// table replacement for the PopulationCount instruction).
pub static POPCOUNT_LUT: [u8; 256] = build_lut();

const fn build_lut() -> [u8; 256] {
    let mut lut = [0u8; 256];
    let mut i = 0;
    while i < 256 {
        lut[i] = (i as u8).count_ones() as u8;
        i += 1;
    }
    lut
}

/// Pack a bit plane (`true` = bit 1) into little-endian-bit-order bytes.
/// Bit `i` lands in byte `i / 8`, position `i % 8` — matching numpy's
/// `packbits(..., bitorder="little")` used by the AOT exporter.
pub fn pack_bits(bits: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; bits.len().div_ceil(8)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Unpack `n_bits` bits into a ±1.0 float plane (the MXU representation).
pub fn unpack_to_pm1(packed: &[u8], n_bits: usize, out: &mut [f32]) {
    debug_assert!(out.len() >= n_bits);
    for i in 0..n_bits {
        let bit = (packed[i / 8] >> (i % 8)) & 1;
        out[i] = if bit == 1 { 1.0 } else { -1.0 };
    }
}

/// XNOR-match count between two packed signatures via the LUT
/// (Eq.6: the number of equal bits).
pub fn xnor_matches_lut(a: &[u8], b: &[u8], n_bits: usize) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let full = n_bits / 8;
    let mut matches = 0u32;
    for i in 0..full {
        matches += POPCOUNT_LUT[(!(a[i] ^ b[i])) as usize] as u32;
    }
    let rem = n_bits % 8;
    if rem != 0 {
        let mask = (1u8 << rem) - 1;
        matches += POPCOUNT_LUT[((!(a[full] ^ b[full])) & mask) as usize]
            as u32;
    }
    matches
}

/// Same quantity using the hardware popcount instruction — the reference
/// the LUT path is tested against (and the faster path on modern CPUs).
pub fn xnor_matches_hw(a: &[u8], b: &[u8], n_bits: usize) -> u32 {
    let full = n_bits / 8;
    let mut matches = 0u32;
    let mut i = 0;
    // 8-bytes-at-a-time over u64 words.
    while i + 8 <= full {
        let wa = u64::from_le_bytes(a[i..i + 8].try_into().unwrap());
        let wb = u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        matches += (!(wa ^ wb)).count_ones();
        i += 8;
    }
    while i < full {
        // `!` on u8 flips exactly 8 bits, so count_ones is already correct.
        matches += (!(a[i] ^ b[i])).count_ones();
        i += 1;
    }
    let rem = n_bits % 8;
    if rem != 0 {
        let mask = (1u8 << rem) - 1;
        matches += ((!(a[full] ^ b[full])) & mask).count_ones();
    }
    matches
}

/// Normalized similarity in [0,1] (Eq.6 divided by d').
pub fn lsh_similarity_packed(a: &[u8], b: &[u8], n_bits: usize) -> f32 {
    xnor_matches_lut(a, b, n_bits) as f32 / n_bits as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_count_ones() {
        for i in 0..256usize {
            assert_eq!(POPCOUNT_LUT[i] as u32, (i as u8).count_ones());
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let bits: Vec<bool> =
            (0..64).map(|i| (i * 7 + 3) % 5 == 0).collect();
        let packed = pack_bits(&bits);
        let mut plane = vec![0.0f32; 64];
        unpack_to_pm1(&packed, 64, &mut plane);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(plane[i], if b { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn xnor_identity_is_all_matches() {
        let a = pack_bits(&(0..64).map(|i| i % 3 == 0).collect::<Vec<_>>());
        assert_eq!(xnor_matches_lut(&a, &a, 64), 64);
        assert_eq!(xnor_matches_hw(&a, &a, 64), 64);
    }

    #[test]
    fn xnor_complement_is_zero_matches() {
        let bits: Vec<bool> = (0..64).map(|i| i % 2 == 0).collect();
        let inv: Vec<bool> = bits.iter().map(|b| !b).collect();
        let a = pack_bits(&bits);
        let b = pack_bits(&inv);
        assert_eq!(xnor_matches_lut(&a, &b, 64), 0);
    }

    #[test]
    fn lut_equals_hw_on_random_pairs() {
        let mut rng = crate::util::rng::Pcg64::new(11);
        for _ in 0..200 {
            let n_bits = 8 + (rng.below(120) as usize);
            let make = |rng: &mut crate::util::rng::Pcg64| {
                pack_bits(
                    &(0..n_bits).map(|_| rng.chance(0.5)).collect::<Vec<_>>(),
                )
            };
            let a = make(&mut rng);
            let b = make(&mut rng);
            assert_eq!(
                xnor_matches_lut(&a, &b, n_bits),
                xnor_matches_hw(&a, &b, n_bits),
                "n_bits={n_bits}"
            );
        }
    }

    #[test]
    fn similarity_matches_unpacked_dot() {
        // sim_packed must equal (1 + dot(±1,±1)/d')/2 — the HLO-side formula.
        let mut rng = crate::util::rng::Pcg64::new(12);
        let n = 64;
        let bits_a: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        let bits_b: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        let (pa, pb) = (pack_bits(&bits_a), pack_bits(&bits_b));
        let mut fa = vec![0.0f32; n];
        let mut fb = vec![0.0f32; n];
        unpack_to_pm1(&pa, n, &mut fa);
        unpack_to_pm1(&pb, n, &mut fb);
        let dot: f32 = fa.iter().zip(&fb).map(|(x, y)| x * y).sum();
        let sim_float = (1.0 + dot / n as f32) / 2.0;
        let sim_packed = lsh_similarity_packed(&pa, &pb, n);
        assert!((sim_float - sim_packed).abs() < 1e-6);
    }
}
