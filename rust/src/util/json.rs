//! Minimal JSON substrate (the `serde` facade is not in the vendored set).
//!
//! Full RFC 8259 parser + serializer over an owned [`Value`] tree.  Used for
//! the AOT manifest, config files and bench/experiment reports.  Object key
//! order is preserved (insertion order) so emitted reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// Owned JSON document node.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Objects keep a parallel key vector for stable serialization order.
    Obj(Object),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Object {
    keys: Vec<String>,
    map: BTreeMap<String, Value>,
}

impl Object {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Value>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Arr(v)
    }
}
impl From<Object> for Value {
    fn from(o: Object) -> Self {
        Value::Obj(o)
    }
}

impl Value {
    // ---- typed accessors ------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&Object> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Path lookup: `v.get("a")` on objects, ignored otherwise.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` that panics with a useful message — for trusted manifests.
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?}"))
    }

    // ---- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline(out, level + 1);
                        v.write(out, Some(level + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    if !a.is_empty() {
                        newline(out, level);
                    }
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline(out, level + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(level + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    if !o.is_empty() {
                        newline(out, level);
                    }
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str(" ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset context.
#[derive(Debug, Clone, thiserror::Error)]
#[error("JSON parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut obj = Object::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#)
            .unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b"),
            &Value::Bool(false)
        );
        assert_eq!(v.req("c").as_str(), Some("x"));
    }

    #[test]
    fn round_trips() {
        let src = r#"{"x":[1,2.5,"s",null,true],"y":{"z":[]}}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // Multibyte passthrough.
        let v = Value::parse("\"héllo😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo😀"));
    }

    #[test]
    fn preserves_key_order() {
        let v = Value::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("tru").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"\x01\"").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.25).to_string(), "3.25");
    }
}
