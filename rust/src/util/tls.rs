//! Per-thread shard tags.  `std::thread::ThreadId::as_u64` is unstable,
//! so shard selection (arena free lists, feature-store RNG streams) keys
//! off a dense process-local counter assigned on first use per thread.

use std::sync::atomic::{AtomicU64, Ordering};

static NEXT_TAG: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TAG: u64 = NEXT_TAG.fetch_add(1, Ordering::Relaxed);
}

/// Small dense integer identifying the calling thread (stable for the
/// thread's lifetime; assigned in spawn-first-touch order).
pub fn thread_tag() -> u64 {
    TAG.with(|t| *t)
}

/// The calling thread's home shard out of `n`.
pub fn thread_shard(n: usize) -> usize {
    debug_assert!(n > 0);
    (thread_tag() % n as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_is_stable_within_a_thread() {
        assert_eq!(thread_tag(), thread_tag());
    }

    #[test]
    fn tags_differ_across_threads() {
        let mine = thread_tag();
        let other =
            std::thread::spawn(thread_tag).join().unwrap();
        assert_ne!(mine, other);
    }

    #[test]
    fn shard_in_range() {
        for n in 1..9 {
            assert!(thread_shard(n) < n);
        }
    }
}
