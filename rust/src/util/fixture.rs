//! Synthetic artifact-set generator for artifact-free integration tests.
//!
//! The real `artifacts/` directory comes from `make artifacts` (python
//! JAX/AOT export) and is not checked in, so integration tests that need a
//! full serving stack historically skipped in CI.  This module writes a
//! MINIATURE but structurally complete artifact set — manifest, world
//! tables, and HLO stubs — that the vendored deterministic `xla` stand-in
//! (rust/xla_stub) serves end to end: the stub reads only the ENTRY
//! return signature from each HLO file and evaluates outputs as a
//! deterministic function of the inputs, so the whole pipeline (nearline
//! N2O build, two-phase request lifecycle, registry hot reload,
//! score-equivalence assertions) exercises for real.
//!
//! The HLO files written here are signature stubs, NOT compilable HLO —
//! under the real `xla_extension` bindings these fixtures are meaningless
//! (those environments have `make artifacts`; the golden-fixture tests
//! already cover them).  Shapes are chosen so that no request-level
//! operand's leading axis collides with a row count anywhere (the stub
//! classifies row-aligned operands by leading-axis match).
//!
//! Dimensions come in named profiles ([`FixtureDims`]): the tiny default
//! used by the CI integration tests, and a larger [`FixtureDims::perf`]
//! profile for `benches/hotpath_alloc.rs`, where data buffers must
//! dominate bookkeeping allocations for the before/after comparison to
//! mean anything.

use std::path::Path;

use anyhow::{Context, Result};

use crate::lsh::Hasher;
use crate::runtime::Table;
use crate::util::json::{Object, Value};
use crate::util::rng::Pcg64;

// Default fixture dimensions (small, but with every axis distinct enough
// for the stub's row/slot classification to be unambiguous).
pub const N_USERS: usize = 24;
pub const N_ITEMS: usize = 128;
pub const BATCH: usize = 16;
pub const L_SHORT: usize = 4;
pub const L_LONG: usize = 12;
pub const D: usize = 8; // item/user vector width
pub const D_RAW: usize = 8; // profile / item_raw / mm / seq widths
pub const N_BRIDGE: usize = 4;
pub const D_LSH_BITS: usize = 16;
pub const N_TIERS: usize = 4;
pub const N_CATEGORIES: usize = 4;
pub const L_SIM_SUB: usize = 4;
pub const D_LATENT: usize = 4;
/// `head_aif_mu`: merged executions of 2x the mini-batch over 4 slots.
pub const MU_ROWS: usize = 2 * BATCH;
pub const MU_SLOTS: usize = 4;

/// One named set of fixture dimensions.  Constraint carried over from the
/// stub's operand classification: no request-level operand's leading axis
/// (1, `n_bridge`, `l_long`, `mu_slots`) may equal a row count (`batch`,
/// `mu_rows`) or a per-output leading axis.
#[derive(Debug, Clone)]
pub struct FixtureDims {
    pub n_users: usize,
    pub n_items: usize,
    pub batch: usize,
    pub l_short: usize,
    pub l_long: usize,
    pub d: usize,
    pub d_raw: usize,
    pub n_bridge: usize,
    pub d_lsh_bits: usize,
    pub n_tiers: usize,
    pub n_categories: usize,
    pub l_sim_sub: usize,
    pub d_latent: usize,
    pub mu_rows: usize,
    pub mu_slots: usize,
}

impl Default for FixtureDims {
    fn default() -> Self {
        FixtureDims {
            n_users: N_USERS,
            n_items: N_ITEMS,
            batch: BATCH,
            l_short: L_SHORT,
            l_long: L_LONG,
            d: D,
            d_raw: D_RAW,
            n_bridge: N_BRIDGE,
            d_lsh_bits: D_LSH_BITS,
            n_tiers: N_TIERS,
            n_categories: N_CATEGORIES,
            l_sim_sub: L_SIM_SUB,
            d_latent: D_LATENT,
            mu_rows: MU_ROWS,
            mu_slots: MU_SLOTS,
        }
    }
}

impl FixtureDims {
    /// Perf-bench profile: production-shaped mini-batches (64 rows, 32-
    /// wide vectors, 64-bit signatures) so assembly buffers are KiB-scale
    /// and the hotpath bench measures data movement, not struct headers.
    pub fn perf() -> FixtureDims {
        FixtureDims {
            n_users: 32,
            n_items: 1024,
            batch: 64,
            l_short: 6,
            l_long: 48,
            d: 32,
            d_raw: 32,
            n_bridge: 8,
            d_lsh_bits: 64,
            n_tiers: 8,
            n_categories: 8,
            l_sim_sub: 8,
            d_latent: 8,
            mu_rows: 128,
            mu_slots: 4,
        }
    }
}

/// Write the complete fixture artifact set into `dir` (created if
/// needed) with the default dimensions.  Deterministic: same bytes every
/// call.
pub fn write(dir: impl AsRef<Path>) -> Result<()> {
    write_dims(dir, &FixtureDims::default())
}

/// [`write`] with an explicit dimension profile.
pub fn write_dims(dir: impl AsRef<Path>, fx: &FixtureDims) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir.join("tables"))
        .with_context(|| format!("creating fixture dir {dir:?}"))?;
    assert!(
        fx.d_lsh_bits % 8 == 0 && fx.mu_rows >= fx.batch && fx.mu_slots >= 1,
        "inconsistent fixture dims: {fx:?}"
    );

    // ---- world tables -----------------------------------------------------
    let mut rng = Pcg64::new(0xF1C5_0A1F);
    let f32s = |rng: &mut Pcg64, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    };
    let ids = |rng: &mut Pcg64, n: usize, below: usize| -> Vec<u32> {
        (0..n).map(|_| rng.below(below as u64) as u32).collect()
    };

    let users_profile = f32s(&mut rng, fx.n_users * fx.d_raw);
    let users_short_seq = ids(&mut rng, fx.n_users * fx.l_short, fx.n_items);
    let users_long_seq = ids(&mut rng, fx.n_users * fx.l_long, fx.n_items);
    let users_mean_mm = f32s(&mut rng, fx.n_users * fx.d_raw);
    let users_cat_share: Vec<f32> = (0..fx.n_users * fx.n_categories)
        .map(|_| rng.f32())
        .collect();
    let users_z = f32s(&mut rng, fx.n_users * fx.d_latent);
    let items_raw = f32s(&mut rng, fx.n_items * fx.d_raw);
    let items_mm = f32s(&mut rng, fx.n_items * fx.d_raw);
    let items_seq_emb = f32s(&mut rng, fx.n_items * fx.d_raw);
    let items_category = ids(&mut rng, fx.n_items, fx.n_categories);
    let items_bid: Vec<f32> =
        (0..fx.n_items).map(|_| 0.1 + rng.f32()).collect();
    let items_z = f32s(&mut rng, fx.n_items * fx.d_latent);
    let w_hash = f32s(&mut rng, fx.d_lsh_bits * fx.d_raw);

    // Packed item signatures must agree with what the serving engine
    // derives from w_hash x items_mm (the static signature table).
    let hasher = Hasher::from_table(&Table::F32 {
        shape: vec![fx.d_lsh_bits, fx.d_raw],
        data: w_hash.clone(),
    });
    let pl = fx.d_lsh_bits / 8;
    let mut items_sign_packed = Vec::with_capacity(fx.n_items * pl);
    for i in 0..fx.n_items {
        items_sign_packed.extend_from_slice(
            &hasher.sign(&items_mm[i * fx.d_raw..(i + 1) * fx.d_raw]),
        );
    }

    let mut tables = Object::new();
    put_f32(
        dir,
        "users_profile",
        &[fx.n_users, fx.d_raw],
        &users_profile,
        &mut tables,
    )?;
    put_f32(
        dir,
        "users_mean_mm",
        &[fx.n_users, fx.d_raw],
        &users_mean_mm,
        &mut tables,
    )?;
    put_f32(
        dir,
        "users_cat_share",
        &[fx.n_users, fx.n_categories],
        &users_cat_share,
        &mut tables,
    )?;
    put_f32(dir, "users_z", &[fx.n_users, fx.d_latent], &users_z, &mut tables)?;
    put_f32(dir, "items_raw", &[fx.n_items, fx.d_raw], &items_raw, &mut tables)?;
    put_f32(dir, "items_mm", &[fx.n_items, fx.d_raw], &items_mm, &mut tables)?;
    put_f32(
        dir,
        "items_seq_emb",
        &[fx.n_items, fx.d_raw],
        &items_seq_emb,
        &mut tables,
    )?;
    put_f32(dir, "items_bid", &[fx.n_items], &items_bid, &mut tables)?;
    put_f32(dir, "items_z", &[fx.n_items, fx.d_latent], &items_z, &mut tables)?;
    put_f32(dir, "w_hash", &[fx.d_lsh_bits, fx.d_raw], &w_hash, &mut tables)?;

    write_u32(
        &dir.join("tables/users_short_seq.bin"),
        &users_short_seq,
    )?;
    tables.insert(
        "users_short_seq",
        table_entry("users_short_seq", &[fx.n_users, fx.l_short], "u32"),
    );
    write_u32(&dir.join("tables/users_long_seq.bin"), &users_long_seq)?;
    tables.insert(
        "users_long_seq",
        table_entry("users_long_seq", &[fx.n_users, fx.l_long], "u32"),
    );
    write_u32(&dir.join("tables/items_category.bin"), &items_category)?;
    tables.insert(
        "items_category",
        table_entry("items_category", &[fx.n_items], "u32"),
    );
    std::fs::write(
        dir.join("tables/items_sign_packed.bin"),
        &items_sign_packed,
    )?;
    tables.insert(
        "items_sign_packed",
        table_entry("items_sign_packed", &[fx.n_items, pl], "u8"),
    );

    // ---- artifacts (HLO signature stubs) ----------------------------------
    let mut artifacts = Object::new();

    // user_tower: mirrors assembly::user_tower_inputs + the plane operand.
    put_artifact(
        dir,
        "user_tower",
        &[
            ("profile", vec![1, fx.d_raw]),
            ("seq_short", vec![fx.l_short, fx.d_raw]),
            ("seq_long", vec![fx.l_long, fx.d_raw]),
            ("seq_plane", vec![fx.l_long, fx.d_lsh_bits]),
        ],
        &[
            ("u_vec", vec![1, fx.d]),
            ("bea_v", vec![fx.n_bridge, fx.d]),
            ("seq_emb", vec![fx.l_long, fx.d]),
            ("din_base", vec![1, fx.d]),
            ("din_g", vec![fx.l_long, fx.d]),
        ],
        &mut artifacts,
    )?;
    // item_tower: nearline N2O rows (item_vec + bea_w per item).
    put_artifact(
        dir,
        "item_tower",
        &[("item_raw", vec![fx.batch, fx.d_raw])],
        &[
            ("item_vec", vec![fx.batch, fx.d]),
            ("bea_w", vec![fx.batch, fx.n_bridge]),
        ],
        &mut artifacts,
    )?;
    // head_base: the sequential baseline head.
    put_artifact(
        dir,
        "head_base",
        &[
            ("profile", vec![1, fx.d_raw]),
            ("seq_short", vec![fx.l_short, fx.d_raw]),
            ("item_raw", vec![fx.batch, fx.d_raw]),
        ],
        &[("scores", vec![fx.batch])],
        &mut artifacts,
    )?;
    // head_aif: the full pipeline head (async user, nearline items, BEA
    // bridge, hoisted LSH long-term, SIM cross).
    put_artifact(
        dir,
        "head_aif",
        &[
            ("u_vec", vec![1, fx.d]),
            ("item_vec", vec![fx.batch, fx.d]),
            ("bea_v", vec![fx.n_bridge, fx.d]),
            ("bea_w", vec![fx.batch, fx.n_bridge]),
            ("din_base", vec![1, fx.d]),
            ("din_g", vec![fx.l_long, fx.d]),
            ("item_sign", vec![fx.batch, fx.d_lsh_bits]),
            ("tiers_in", vec![fx.batch, fx.n_tiers]),
            ("sim_cross", vec![fx.batch, fx.d_raw]),
        ],
        &[("scores", vec![fx.batch])],
        &mut artifacts,
    )?;
    // head_aif_mu: the coalesced multi-user flavor (slot-stacked
    // request-level operands, row-aligned operands at mu_rows, row_user
    // gather index last) — expected_input_names_mu order.
    put_artifact(
        dir,
        "head_aif_mu",
        &[
            ("u_vec", vec![fx.mu_slots, fx.d]),
            ("bea_v", vec![fx.mu_slots, fx.n_bridge, fx.d]),
            ("din_base", vec![fx.mu_slots, fx.d]),
            ("din_g", vec![fx.mu_slots, fx.l_long, fx.d]),
            ("item_vec", vec![fx.mu_rows, fx.d]),
            ("bea_w", vec![fx.mu_rows, fx.n_bridge]),
            ("item_sign", vec![fx.mu_rows, fx.d_lsh_bits]),
            ("tiers_in", vec![fx.mu_rows, fx.n_tiers]),
            ("sim_cross", vec![fx.mu_rows, fx.d_raw]),
            ("row_user", vec![fx.mu_rows]),
        ],
        &[("scores", vec![fx.mu_rows])],
        &mut artifacts,
    )?;

    // ---- variants ---------------------------------------------------------
    let mut variants = Object::new();
    variants.insert(
        "base",
        variant_entry("head_base", "cheap", "inline", "none", "none", "none", false),
    );
    variants.insert(
        "aif",
        variant_entry("head_aif", "async", "nearline", "bridge", "lsh", "lsh", true),
    );

    // ---- dims + oracle + manifest -----------------------------------------
    let mut dims = Object::new();
    for (k, v) in [
        ("D", fx.d),
        ("D_RAW", fx.d_raw),
        ("D_MM", fx.d_raw),
        ("D_SEQ_RAW", fx.d_raw),
        ("D_PROFILE_RAW", fx.d_raw),
        ("D_ITEM_RAW", fx.d_raw),
        ("N_BRIDGE", fx.n_bridge),
        ("D_LSH_BITS", fx.d_lsh_bits),
        ("N_TIERS", fx.n_tiers),
        ("N_CATEGORIES", fx.n_categories),
        ("L_SIM_SUB", fx.l_sim_sub),
        ("L_SHORT", fx.l_short),
        ("D_LATENT", fx.d_latent),
        ("D_BEA", fx.d),
        ("M_GROUPS", fx.n_categories),
        ("N_BRIDGE_MU", fx.mu_slots),
    ] {
        dims.insert(k, v);
    }

    let mut oracle = Object::new();
    oracle.insert(
        "click_w",
        Value::Arr(vec![
            Value::Num(0.5),
            Value::Num(0.3),
            Value::Num(0.2),
        ]),
    );
    oracle.insert("click_b", -0.1);
    oracle.insert("d_latent", fx.d_latent);

    let mut manifest = Object::new();
    manifest.insert("batch", fx.batch);
    manifest.insert("l_long", fx.l_long);
    manifest.insert("dims", Value::Obj(dims));
    manifest.insert("artifacts", Value::Obj(artifacts));
    manifest.insert("variants", Value::Obj(variants));
    manifest.insert("tables", Value::Obj(tables));
    manifest.insert("oracle", Value::Obj(oracle));
    manifest.insert("goldens", Value::Obj(Object::new()));
    std::fs::write(
        dir.join("manifest.json"),
        Value::Obj(manifest).to_string_pretty(),
    )?;
    Ok(())
}

/// Write one f32 table + its manifest entry.
fn put_f32(
    dir: &Path,
    name: &str,
    shape: &[usize],
    data: &[f32],
    tables: &mut Object,
) -> Result<()> {
    write_f32(&dir.join("tables").join(format!("{name}.bin")), data)?;
    tables.insert(name, table_entry(name, shape, "f32"));
    Ok(())
}

/// Write one HLO signature stub + its manifest artifact entry.
fn put_artifact(
    dir: &Path,
    name: &str,
    inputs: &[(&str, Vec<usize>)],
    outputs: &[(&str, Vec<usize>)],
    artifacts: &mut Object,
) -> Result<()> {
    let file = format!("{name}.hlo.txt");
    write_hlo_stub(&dir.join(&file), name, outputs)?;
    let mut o = Object::new();
    o.insert("file", file.as_str());
    o.insert("inputs", sig_list(inputs));
    o.insert("outputs", sig_list(outputs));
    artifacts.insert(name, Value::Obj(o));
    Ok(())
}

fn table_entry(name: &str, shape: &[usize], dtype: &str) -> Value {
    let mut o = Object::new();
    o.insert("file", format!("tables/{name}.bin").as_str());
    o.insert("shape", shape_value(shape));
    o.insert("dtype", dtype);
    Value::Obj(o)
}

fn variant_entry(
    artifact: &str,
    user: &str,
    item: &str,
    bea: &str,
    din_sim: &str,
    tier_sim: &str,
    sim_cross: bool,
) -> Value {
    let mut o = Object::new();
    o.insert("artifact", artifact);
    o.insert("user", user);
    o.insert("item", item);
    o.insert("bea", bea);
    o.insert("din_sim", din_sim);
    o.insert("tier_sim", tier_sim);
    o.insert("sim_cross", sim_cross);
    o.insert("sim_budget", 1.0);
    Value::Obj(o)
}

fn shape_value(shape: &[usize]) -> Value {
    Value::Arr(shape.iter().map(|&d| Value::Num(d as f64)).collect())
}

fn sig_list(sigs: &[(&str, Vec<usize>)]) -> Value {
    Value::Arr(
        sigs.iter()
            .map(|(name, shape)| {
                let mut o = Object::new();
                o.insert("name", *name);
                o.insert("shape", shape_value(shape));
                Value::Obj(o)
            })
            .collect(),
    )
}

/// One HLO signature stub: only the ENTRY return signature matters to the
/// deterministic stand-in runtime.
fn write_hlo_stub(
    path: &Path,
    name: &str,
    outputs: &[(&str, Vec<usize>)],
) -> Result<()> {
    let shapes: Vec<String> = outputs
        .iter()
        .map(|(_, shape)| {
            let dims: Vec<String> =
                shape.iter().map(|d| d.to_string()).collect();
            format!("f32[{}]", dims.join(","))
        })
        .collect();
    let text = format!(
        "HloModule fixture_{name}\n\
         ENTRY %main () -> ({}) {{\n\
         }}\n",
        shapes.join(", ")
    );
    std::fs::write(path, text)
        .with_context(|| format!("writing HLO stub {path:?}"))?;
    Ok(())
}

fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)
        .with_context(|| format!("writing table {path:?}"))?;
    Ok(())
}

fn write_u32(path: &Path, data: &[u32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)
        .with_context(|| format!("writing table {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn fixture_manifest_loads_and_is_consistent() {
        let dir = std::env::temp_dir().join(format!(
            "aif-fixture-selftest-{}",
            std::process::id()
        ));
        write(&dir).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.batch, BATCH);
        assert_eq!(manifest.l_long, L_LONG);
        assert!(manifest.variants.contains_key("aif"));
        assert!(manifest.variants.contains_key("base"));
        assert!(manifest.artifacts.contains_key("head_aif_mu"));
        let world = crate::features::World::load(&manifest).unwrap();
        assert_eq!(world.n_users, N_USERS);
        assert_eq!(world.n_items, N_ITEMS);
        // Signature table agrees with the hasher over the same w_hash.
        let hasher = Hasher::from_table(&world.w_hash);
        for i in [0usize, 7, 127] {
            assert_eq!(
                world.items_sign_packed.u8_row(i),
                hasher.sign(world.items_mm.f32_row(i)).as_slice(),
                "item {i} signature mismatch"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn perf_profile_loads_with_scaled_dims() {
        let dir = std::env::temp_dir().join(format!(
            "aif-fixture-perf-selftest-{}",
            std::process::id()
        ));
        let fx = FixtureDims::perf();
        write_dims(&dir, &fx).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.batch, fx.batch);
        assert_eq!(manifest.l_long, fx.l_long);
        assert_eq!(manifest.dim("D_LSH_BITS"), fx.d_lsh_bits);
        let world = crate::features::World::load(&manifest).unwrap();
        assert_eq!(world.n_users, fx.n_users);
        assert_eq!(world.n_items, fx.n_items);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
