//! Base64 codec (RFC 4648) — used to encode user-side async vectors for
//! transmission between the Merger's two RTP phases, exactly as §5.3 of the
//! paper does to minimize transmission overhead.

const ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

fn decode_table() -> [i8; 256] {
    let mut t = [-1i8; 256];
    let mut i = 0;
    while i < 64 {
        t[ALPHABET[i] as usize] = i as i8;
        i += 1;
    }
    t
}

pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = *chunk.get(1).unwrap_or(&0) as u32;
        let b2 = *chunk.get(2).unwrap_or(&0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

#[derive(Debug, thiserror::Error)]
#[error("invalid base64 at position {0}")]
pub struct DecodeError(pub usize);

pub fn decode(text: &str) -> Result<Vec<u8>, DecodeError> {
    let table = decode_table();
    let bytes = text.as_bytes();
    let trimmed = bytes
        .iter()
        .rposition(|&b| b != b'=')
        .map_or(0, |i| i + 1);
    let mut out = Vec::with_capacity(trimmed * 3 / 4);
    let mut acc = 0u32;
    let mut n_bits = 0u32;
    for (i, &b) in bytes[..trimmed].iter().enumerate() {
        let v = table[b as usize];
        if v < 0 {
            return Err(DecodeError(i));
        }
        acc = (acc << 6) | v as u32;
        n_bits += 6;
        if n_bits >= 8 {
            n_bits -= 8;
            out.push((acc >> n_bits) as u8);
        }
    }
    Ok(out)
}

/// Encode an f32 slice (little-endian) — the user-vector wire format.
pub fn encode_f32(values: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(values.len() * 4);
    for v in values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    encode(&bytes)
}

/// Length of `encode_f32` for `n` floats WITHOUT materializing the
/// string (padded RFC 4648: 4 output chars per 3 input bytes) — the
/// §5.3 transport accounting only needs the size.
pub fn encoded_len_f32(n: usize) -> usize {
    (n * 4).div_ceil(3) * 4
}

pub fn decode_f32(text: &str) -> Result<Vec<f32>, DecodeError> {
    let bytes = decode(text)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        // RFC 4648 §10 test vectors.
        let cases = [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ];
        for (plain, enc) in cases {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_round_trip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn f32_round_trip() {
        let v = vec![1.5f32, -0.25, 3.2e-8, f32::MAX, 0.0];
        assert_eq!(decode_f32(&encode_f32(&v)).unwrap(), v);
    }

    #[test]
    fn rejects_invalid() {
        assert!(decode("a!b=").is_err());
    }

    #[test]
    fn encoded_len_matches_encode_f32() {
        for n in [0usize, 1, 2, 3, 7, 8, 32, 100] {
            let v = vec![1.25f32; n];
            assert_eq!(encoded_len_f32(n), encode_f32(&v).len(), "n={n}");
        }
    }
}
