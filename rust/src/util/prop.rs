//! Miniature property-testing framework (proptest is not vendored).
//!
//! `forall(gen, n_cases, prop)` runs a property over generated inputs with
//! greedy shrinking on failure.  Generators are plain closures over
//! [`Pcg64`]; shrink candidates come from a user-supplied (or derived)
//! shrinker.  Used for the coordinator/cache/nearline invariants
//! (DESIGN.md §9).

use super::rng::Pcg64;

/// A generator: produces a case from RNG, plus shrink candidates.
pub struct Gen<T> {
    pub make: Box<dyn Fn(&mut Pcg64) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: 'static> Gen<T> {
    pub fn new(make: impl Fn(&mut Pcg64) -> T + 'static) -> Self {
        Gen {
            make: Box::new(make),
            shrink: Box::new(|_| Vec::new()),
        }
    }

    pub fn with_shrink(
        mut self,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn map<U: 'static>(
        self,
        f: impl Fn(T) -> U + Clone + 'static,
    ) -> Gen<U> {
        let make = self.make;
        let f2 = f.clone();
        Gen {
            make: Box::new(move |rng| f(make(rng))),
            // Mapping loses shrink structure; mapped gens shrink at the
            // source if composed via `vec_of`/tuples instead.
            shrink: Box::new(move |_| {
                let _ = &f2;
                Vec::new()
            }),
        }
    }
}

/// Integer in [lo, hi) with halving shrink toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo < hi);
    Gen::new(move |rng| lo + rng.below((hi - lo) as u64) as usize)
        .with_shrink(move |&x| {
            let mut out = Vec::new();
            if x > lo {
                out.push(lo);
                let mid = lo + (x - lo) / 2;
                if mid != lo && mid != x {
                    out.push(mid);
                }
                if x - 1 != lo {
                    out.push(x - 1);
                }
            }
            out
        })
}

/// f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng| lo + rng.f64() * (hi - lo))
}

/// Pair of independent generators with component-wise shrinking.
pub fn tuple2<A: Clone + 'static, B: Clone + 'static>(
    a: Gen<A>,
    b: Gen<B>,
) -> Gen<(A, B)> {
    let (make_a, shrink_a) = (a.make, a.shrink);
    let (make_b, shrink_b) = (b.make, b.shrink);
    Gen {
        make: Box::new(move |rng| (make_a(rng), make_b(rng))),
        shrink: Box::new(move |(x, y): &(A, B)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for sx in shrink_a(x) {
                out.push((sx, y.clone()));
            }
            for sy in shrink_b(y) {
                out.push((x.clone(), sy));
            }
            out
        }),
    }
}

/// Vector of length in [0, max_len) with element-removal + element shrink.
pub fn vec_of<T: Clone + 'static>(
    elem: Gen<T>,
    max_len: usize,
) -> Gen<Vec<T>> {
    let make_elem = elem.make;
    let shrink_elem = elem.shrink;
    Gen {
        make: Box::new(move |rng| {
            let n = rng.below(max_len as u64 + 1) as usize;
            (0..n).map(|_| make_elem(rng)).collect()
        }),
        shrink: Box::new(move |v: &Vec<T>| {
            let mut out = Vec::new();
            if !v.is_empty() {
                // Halve, drop one element, shrink one element.
                out.push(v[..v.len() / 2].to_vec());
                let mut d = v.clone();
                d.remove(v.len() - 1);
                out.push(d);
                for (i, x) in v.iter().enumerate().take(4) {
                    for sx in shrink_elem(x) {
                        let mut c = v.clone();
                        c[i] = sx;
                        out.push(c);
                    }
                }
            }
            out
        }),
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass,
    Fail {
        case: T,
        shrunk: T,
        message: String,
        seed: u64,
    },
}

/// Run `prop` over `n_cases` generated inputs; shrink on first failure.
/// Returns the (shrunk) counterexample instead of panicking so tests can
/// assert with context; use [`check`] for the panicking form.
pub fn forall<T: Clone + std::fmt::Debug>(
    gen: &Gen<T>,
    n_cases: usize,
    seed: u64,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Pcg64::new(seed);
    for case_idx in 0..n_cases {
        let case = (gen.make)(&mut rng);
        if let Err(msg) = prop(&case) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut current = case.clone();
            let mut current_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in (gen.shrink)(&current) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            let _ = case_idx;
            return PropResult::Fail {
                case,
                shrunk: current,
                message: current_msg,
                seed,
            };
        }
    }
    PropResult::Pass
}

/// Panicking wrapper for use inside `#[test]`.
pub fn check<T: Clone + std::fmt::Debug>(
    name: &str,
    gen: &Gen<T>,
    n_cases: usize,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match forall(gen, n_cases, 0xA1F, prop) {
        PropResult::Pass => {}
        PropResult::Fail {
            case,
            shrunk,
            message,
            seed,
        } => panic!(
            "property {name} failed (seed {seed:#x}):\n  original: \
             {case:?}\n  shrunk:   {shrunk:?}\n  error:    {message}"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = usize_in(0, 1000);
        check("x < 1000", &gen, 200, |&x| {
            if x < 1000 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let gen = usize_in(0, 1000);
        match forall(&gen, 500, 1, |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} >= 500"))
            }
        }) {
            PropResult::Fail { shrunk, .. } => {
                // Greedy shrink should land near the boundary.
                assert!(shrunk >= 500 && shrunk <= 520, "shrunk to {shrunk}");
            }
            PropResult::Pass => panic!("should have failed"),
        }
    }

    #[test]
    fn tuple2_shrinks_componentwise() {
        let gen = tuple2(usize_in(0, 100), usize_in(0, 100));
        match forall(&gen, 500, 3, |&(a, b)| {
            if a + b < 120 {
                Ok(())
            } else {
                Err(format!("{a}+{b} >= 120"))
            }
        }) {
            PropResult::Fail {
                case: (ca, cb),
                shrunk: (a, b),
                ..
            } => {
                assert!(a + b >= 120, "shrunk case still fails");
                assert!(a + b <= ca + cb, "shrinking never grows the case");
            }
            PropResult::Pass => panic!("should have failed"),
        }
    }

    #[test]
    fn vec_gen_shrinks_toward_small() {
        let gen = vec_of(usize_in(0, 100), 50);
        match forall(&gen, 500, 2, |v: &Vec<usize>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("len >= 3".into())
            }
        }) {
            PropResult::Fail { shrunk, .. } => {
                assert!(shrunk.len() >= 3 && shrunk.len() <= 6,
                        "shrunk len {}", shrunk.len());
            }
            PropResult::Pass => panic!("should have failed"),
        }
    }
}
