//! Deterministic RNG + distributions (the `rand` crate is not vendored).
//!
//! PCG64 (O'Neill) core with normal / lognormal / exponential / zipf /
//! gumbel samplers — everything the workload generator and the synthetic
//! latency models need.  All streams are seedable and independent.

/// PCG-XSH-RR 64/32 with 128-bit state advanced twice per `u64`.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream: same seed, different `stream` never collide.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        // XSL-RR output function.
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    // ---- distributions -----------------------------------------------------

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity — throughput is not the bottleneck here).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Standard Gumbel (for Gumbel-top-k sampling).
    pub fn gumbel(&mut self) -> f64 {
        -(-self.f64().max(1e-300).ln()).ln()
    }
}

/// Zipf-distributed sampler over [0, n) using the rejection-inversion
/// method (Hörmann & Derflinger) — O(1) per sample, any exponent > 0.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense_inv_s: f64,
}

impl Zipf {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0 && exponent > 0.0);
        let s = exponent;
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        Zipf {
            n: n as f64,
            s,
            h_x1: h(1.5) - 1.0,
            h_n: h(n as f64 + 0.5),
            dense_inv_s: 1.0 - s,
        }
    }

    fn h(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-12 {
            (1.0 + x).ln()
        } else {
            ((1.0 + x).powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-12 {
            x.exp() - 1.0
        } else {
            (1.0 + x * self.dense_inv_s).powf(1.0 / (1.0 - self.s)) - 1.0
        }
    }

    /// Sample a rank in [0, n) — rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            if k - x <= 0.5 || u >= self.h(k + 0.5) - (k).powf(-self.s) {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Pcg64::with_stream(42, 7);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg64::new(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(4);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut rng = Pcg64::new(5);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Head dominates; decade-averaged counts strictly decrease.
        let c0: usize = counts[..10].iter().sum();
        let c1: usize = counts[10..100].iter().sum::<usize>();
        let c2: usize = counts[100..].iter().sum::<usize>();
        assert!(counts[0] > counts[9]);
        assert!(c0 > 20_000, "head {c0}");
        assert!(c1 > c2 / 4, "{c1} {c2}");
    }

    #[test]
    fn shuffle_and_sample_indices() {
        let mut rng = Pcg64::new(6);
        let idx = rng.sample_indices(100, 10);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(idx.iter().all(|&i| i < 100));
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
