//! Tiny CLI argument parser (the `clap` crate is not vendored).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and subcommands:  `aif serve --config cfg.json --threads 4`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                let (key, value) = if let Some((k, v)) = rest.split_once('=')
                {
                    (k.to_string(), Some(v.to_string()))
                } else {
                    // `--key value` unless the next token is another flag.
                    let next_is_value = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if next_is_value {
                        (rest.to_string(), iter.next())
                    } else {
                        (rest.to_string(), None)
                    }
                };
                out.seen.push(key.clone());
                out.flags.insert(key, value.unwrap_or_else(|| "true".into()));
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional argument — the subcommand.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Flags the caller never consumed — typo detection for the binary.
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.seen
            .iter()
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --config cfg.json --threads 4 --verbose");
        assert_eq!(a.command(), Some("serve"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.usize_or("threads", 1), 4);
        assert!(a.bool_or("verbose", false));
        assert!(!a.bool_or("quiet", false));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --mode=closed --rate=150.5");
        assert_eq!(a.get("mode"), Some("closed"));
        assert!((a.f64_or("rate", 0.0) - 150.5).abs() < 1e-9);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.bool_or("fast", false));
    }

    #[test]
    fn positional_after_flags() {
        let a = parse("replay --n 5 trace.json");
        assert_eq!(a.positional, vec!["replay", "trace.json"]);
        assert_eq!(a.usize_or("n", 0), 5);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("serve --confg x");
        assert_eq!(a.unknown_flags(&["config"]), vec!["confg"]);
    }
}
