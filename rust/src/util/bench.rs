//! Benchmark harness (criterion is not vendored; `[[bench]]` targets use
//! `harness = false` and drive this module directly).
//!
//! Provides warmup + timed iteration with robust statistics, and a table
//! printer that renders paper-style rows (avgRT / p99RT / maxQPS deltas).

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall-clock samples, seconds.
    pub samples: Vec<f64>,
}

impl Stats {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }

    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:40} mean {:>10} p50 {:>10} p99 {:>10} min {:>10} (n={})",
            self.name,
            fmt_secs(self.mean()),
            fmt_secs(self.percentile(50.0)),
            fmt_secs(self.percentile(99.0)),
            fmt_secs(self.min()),
            self.iters
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(700),
            min_iters: 5,
            max_iters: 10_000,
        }
    }

    /// Time `f` repeatedly; each invocation is one sample.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure
            || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Stats {
            name: name.to_string(),
            iters: samples.len(),
            samples,
        };
        println!("{}", s.report());
        s
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Paper-style delta table printer: first row is the base; subsequent rows
/// render percent deltas against it, like Table 4.
pub struct DeltaTable {
    pub title: String,
    pub columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
}

impl DeltaTable {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        DeltaTable {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((name.to_string(), values));
    }

    /// Render with the first row as baseline: `+x.xx%` deltas.
    pub fn render_deltas(&self) -> String {
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&format!("{:32}", "method"));
        for c in &self.columns {
            out.push_str(&format!("{c:>16}"));
        }
        out.push('\n');
        let base = &self.rows[0].1;
        for (i, (name, vals)) in self.rows.iter().enumerate() {
            out.push_str(&format!("{name:32}"));
            for (j, v) in vals.iter().enumerate() {
                if i == 0 {
                    out.push_str(&format!("{:>16}", format!("{v:.4}")));
                } else {
                    let delta = (v - base[j]) / base[j] * 100.0;
                    out.push_str(&format!("{:>16}", format!("{delta:+.2}%")));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render raw values.
    pub fn render_raw(&self) -> String {
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&format!("{:32}", "method"));
        for c in &self.columns {
            out.push_str(&format!("{c:>16}"));
        }
        out.push('\n');
        for (name, vals) in &self.rows {
            out.push_str(&format!("{name:32}"));
            for v in vals {
                out.push_str(&format!("{:>16}", format!("{v:.4}")));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats {
            name: "t".into(),
            iters: 100,
            samples: (1..=100).map(|i| i as f64).collect(),
        };
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((s.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn bench_runs_enough_iters() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
        };
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(s.iters >= 3);
    }

    #[test]
    fn delta_table_renders() {
        let mut t = DeltaTable::new("Table", &["avgRT", "maxQPS"]);
        t.row("Base", vec![1.0, 100.0]);
        t.row("+X", vec![1.3, 93.0]);
        let s = t.render_deltas();
        assert!(s.contains("+30.00%"), "{s}");
        assert!(s.contains("-7.00%"), "{s}");
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(3e-9).ends_with("ns"));
        assert!(fmt_secs(3e-6).ends_with("µs"));
        assert!(fmt_secs(3e-3).ends_with("ms"));
        assert!(fmt_secs(3.0).ends_with('s'));
    }
}
