//! Dense-tensor assembly: gathers raw features / embeddings into the named
//! input tensors the HLO heads consume.  This is the "constructs model
//! input tensors by indexing the model embedding matrices" step of the
//! paper's feature-fetching phase, kept in rust on the request path.

use super::store::{ItemFeatures, UserFeatures};
use super::world::World;
use crate::runtime::Tensor;

/// Gather seq-embedding rows for a sequence of item ids -> [len, D_SEQ_RAW].
pub fn gather_seq_emb(world: &World, seq: &[u32]) -> Tensor {
    let d = world.items_seq_emb.shape()[1];
    let mut data = Vec::with_capacity(seq.len() * d);
    for &i in seq {
        data.extend_from_slice(world.items_seq_emb.f32_row(i as usize));
    }
    Tensor::new(vec![seq.len(), d], data)
}

/// Gather multi-modal rows -> [len, D_MM].
pub fn gather_mm(world: &World, seq: &[u32]) -> Tensor {
    let d = world.items_mm.shape()[1];
    let mut data = Vec::with_capacity(seq.len() * d);
    for &i in seq {
        data.extend_from_slice(world.items_mm.f32_row(i as usize));
    }
    Tensor::new(vec![seq.len(), d], data)
}

/// User tower inputs: (profile [1,P], seq_short [Ls,Ds], seq_long_raw [L,Ds]).
pub fn user_tower_inputs(world: &World, uf: &UserFeatures) -> Vec<Tensor> {
    let profile = Tensor::new(vec![1, uf.profile.len()], uf.profile.clone());
    let seq_short = gather_seq_emb(world, &uf.short_seq);
    let seq_long = gather_seq_emb(world, &uf.long_seq);
    vec![profile, seq_short, seq_long]
}

/// Item-raw matrix for a mini-batch (padded to `batch` rows by repeating
/// the last item — scores for padding rows are discarded downstream).
pub fn item_raw_batch(feats: &[ItemFeatures], batch: usize) -> Tensor {
    assert!(!feats.is_empty() && feats.len() <= batch);
    let d = feats[0].raw.len();
    let mut data = Vec::with_capacity(batch * d);
    for f in feats {
        data.extend_from_slice(&f.raw);
    }
    for _ in feats.len()..batch {
        data.extend_from_slice(&feats[feats.len() - 1].raw);
    }
    Tensor::new(vec![batch, d], data)
}

/// Item multi-modal matrix for a mini-batch, padded like `item_raw_batch`.
pub fn item_mm_batch(feats: &[ItemFeatures], batch: usize) -> Tensor {
    let d = feats[0].mm.len();
    let mut data = Vec::with_capacity(batch * d);
    for f in feats {
        data.extend_from_slice(&f.mm);
    }
    for _ in feats.len()..batch {
        data.extend_from_slice(&feats[feats.len() - 1].mm);
    }
    Tensor::new(vec![batch, d], data)
}

/// SIM cross feature: per candidate, mean seq-embedding of the user's
/// category-matched subsequence -> [batch, D_SEQ_RAW].  `subseq_of` maps a
/// category to the (pre-cached or freshly fetched) subsequence.
pub fn sim_cross_batch(
    world: &World,
    cats: &[u32],
    batch: usize,
    mut subseq_of: impl FnMut(u32) -> Vec<u32>,
) -> Tensor {
    let d = world.items_seq_emb.shape()[1];
    let mut out = vec![0.0f32; batch * d];
    // Group candidates by category so each subsequence pools once.
    let mut by_cat: std::collections::HashMap<u32, Vec<usize>> =
        std::collections::HashMap::new();
    for (i, &c) in cats.iter().enumerate() {
        by_cat.entry(c).or_default().push(i);
    }
    for (cat, rows) in by_cat {
        let sub = subseq_of(cat);
        if sub.is_empty() {
            continue;
        }
        let mut pooled = vec![0.0f32; d];
        for &item in &sub {
            for (p, v) in pooled
                .iter_mut()
                .zip(world.items_seq_emb.f32_row(item as usize))
            {
                *p += v;
            }
        }
        let inv = 1.0 / sub.len() as f32;
        for p in pooled.iter_mut() {
            *p *= inv;
        }
        for &r in &rows {
            out[r * d..(r + 1) * d].copy_from_slice(&pooled);
        }
    }
    // Padding rows repeat the last real row.
    if cats.len() < batch && !cats.is_empty() {
        let last = (cats.len() - 1) * d;
        let last_row = out[last..last + d].to_vec();
        for r in cats.len()..batch {
            out[r * d..(r + 1) * d].copy_from_slice(&last_row);
        }
    }
    Tensor::new(vec![batch, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::store::ItemFeatures;

    fn items(n: usize, d: usize) -> Vec<ItemFeatures> {
        (0..n)
            .map(|i| ItemFeatures {
                raw: vec![i as f32; d],
                mm: vec![i as f32 + 0.5; d],
                seq_emb: vec![0.0; 4],
                category: i as u32 % 3,
            })
            .collect()
    }

    #[test]
    fn item_batch_pads_with_last_row() {
        let t = item_raw_batch(&items(3, 4), 5);
        assert_eq!(t.shape, vec![5, 4]);
        assert_eq!(t.row(2), t.row(3));
        assert_eq!(t.row(2), t.row(4));
        assert_ne!(t.row(1), t.row(2));
    }

    #[test]
    fn mm_batch_shape() {
        let t = item_mm_batch(&items(4, 6), 4);
        assert_eq!(t.shape, vec![4, 6]);
        assert_eq!(t.row(0)[0], 0.5);
    }
}
