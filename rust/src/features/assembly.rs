//! Dense-tensor assembly: gathers raw features / embeddings into the named
//! input tensors the HLO heads consume.  This is the "constructs model
//! input tensors by indexing the model embedding matrices" step of the
//! paper's feature-fetching phase, kept in rust on the request path.
//!
//! Every gather exists in two flavors sharing ONE fill routine: the owned
//! form (`item_raw_batch`, …) allocating a fresh `Vec`, and the
//! arena-backed `_in` form writing into an [`ArenaPool`] buffer
//! (`Tensor::from_pooled`) so the hot loop allocates nothing.  Sharing the
//! fill makes the two bitwise-identical by construction (property-tested
//! in `rust/tests/prop_invariants.rs`).

use std::sync::Arc;

use super::store::{ItemFeatures, UserFeatures};
use super::world::World;
use crate::cache::ArenaPool;
use crate::runtime::Tensor;

/// Gather seq-embedding rows for a sequence of item ids -> [len, D_SEQ_RAW].
pub fn gather_seq_emb(world: &World, seq: &[u32]) -> Tensor {
    gather_seq_emb_opt(world, seq, None)
}

/// Arena-backed [`gather_seq_emb`].
pub fn gather_seq_emb_in(
    world: &World,
    seq: &[u32],
    arena: &Arc<ArenaPool>,
) -> Tensor {
    gather_seq_emb_opt(world, seq, Some(arena))
}

/// The pooled-vs-owned dispatch behind [`gather_seq_emb`] /
/// [`gather_seq_emb_in`] — call sites holding an `Option` use this.
pub fn gather_seq_emb_opt(
    world: &World,
    seq: &[u32],
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    let d = world.items_seq_emb.shape()[1];
    Tensor::build_with(arena, vec![seq.len(), d], |out| {
        for &i in seq {
            out.extend_from_slice(world.items_seq_emb.f32_row(i as usize));
        }
    })
}

/// Gather multi-modal rows -> [len, D_MM].
pub fn gather_mm(world: &World, seq: &[u32]) -> Tensor {
    gather_mm_opt(world, seq, None)
}

/// Arena-backed [`gather_mm`].
pub fn gather_mm_in(
    world: &World,
    seq: &[u32],
    arena: &Arc<ArenaPool>,
) -> Tensor {
    gather_mm_opt(world, seq, Some(arena))
}

/// The pooled-vs-owned dispatch behind [`gather_mm`] / [`gather_mm_in`].
pub fn gather_mm_opt(
    world: &World,
    seq: &[u32],
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    let d = world.items_mm.shape()[1];
    Tensor::build_with(arena, vec![seq.len(), d], |out| {
        for &i in seq {
            out.extend_from_slice(world.items_mm.f32_row(i as usize));
        }
    })
}

/// User tower inputs: (profile [1,P], seq_short [Ls,Ds], seq_long_raw [L,Ds]).
pub fn user_tower_inputs(world: &World, uf: &UserFeatures) -> Vec<Tensor> {
    user_tower_inputs_opt(world, uf, None)
}

/// The pooled-vs-owned dispatch behind [`user_tower_inputs`] (the async
/// hot path passes its arena; the profile vector stays owned — it is
/// tiny and already cloned off the fetch).
pub fn user_tower_inputs_opt(
    world: &World,
    uf: &UserFeatures,
    arena: Option<&Arc<ArenaPool>>,
) -> Vec<Tensor> {
    vec![
        Tensor::new(vec![1, uf.profile.len()], uf.profile.clone()),
        gather_seq_emb_opt(world, &uf.short_seq, arena),
        gather_seq_emb_opt(world, &uf.long_seq, arena),
    ]
}

fn raw_col(f: &ItemFeatures) -> &[f32] {
    &f.raw
}

fn mm_col(f: &ItemFeatures) -> &[f32] {
    &f.mm
}

/// Item-raw matrix for a mini-batch (padded to `batch` rows by repeating
/// the last item — scores for padding rows are discarded downstream).
pub fn item_raw_batch(feats: &[ItemFeatures], batch: usize) -> Tensor {
    item_batch_opt(feats, batch, raw_col, None)
}

/// Arena-backed [`item_raw_batch`].
pub fn item_raw_batch_in(
    feats: &[ItemFeatures],
    batch: usize,
    arena: &Arc<ArenaPool>,
) -> Tensor {
    item_batch_opt(feats, batch, raw_col, Some(arena))
}

/// The pooled-vs-owned dispatch behind [`item_raw_batch`] /
/// [`item_raw_batch_in`].
pub fn item_raw_batch_opt(
    feats: &[ItemFeatures],
    batch: usize,
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    item_batch_opt(feats, batch, raw_col, arena)
}

/// Item multi-modal matrix for a mini-batch, padded like `item_raw_batch`.
pub fn item_mm_batch(feats: &[ItemFeatures], batch: usize) -> Tensor {
    item_batch_opt(feats, batch, mm_col, None)
}

/// Arena-backed [`item_mm_batch`].
pub fn item_mm_batch_in(
    feats: &[ItemFeatures],
    batch: usize,
    arena: &Arc<ArenaPool>,
) -> Tensor {
    item_batch_opt(feats, batch, mm_col, Some(arena))
}

/// The pooled-vs-owned dispatch behind [`item_mm_batch`] /
/// [`item_mm_batch_in`].
pub fn item_mm_batch_opt(
    feats: &[ItemFeatures],
    batch: usize,
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    item_batch_opt(feats, batch, mm_col, arena)
}

fn item_batch_opt(
    feats: &[ItemFeatures],
    batch: usize,
    col: fn(&ItemFeatures) -> &[f32],
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    assert!(!feats.is_empty() && feats.len() <= batch);
    let d = col(&feats[0]).len();
    Tensor::build_with(arena, vec![batch, d], |out| {
        for f in feats {
            out.extend_from_slice(col(f));
        }
        for _ in feats.len()..batch {
            out.extend_from_slice(col(&feats[feats.len() - 1]));
        }
    })
}

/// SIM cross feature: per candidate, mean seq-embedding of the user's
/// category-matched subsequence -> [batch, D_SEQ_RAW].  `subseq_of` maps a
/// category to the (pre-cached or freshly fetched) subsequence.
pub fn sim_cross_batch(
    world: &World,
    cats: &[u32],
    batch: usize,
    subseq_of: impl FnMut(u32) -> Vec<u32>,
) -> Tensor {
    sim_cross_batch_opt(world, cats, batch, subseq_of, None)
}

/// Arena-backed [`sim_cross_batch`].
pub fn sim_cross_batch_in(
    world: &World,
    cats: &[u32],
    batch: usize,
    subseq_of: impl FnMut(u32) -> Vec<u32>,
    arena: &Arc<ArenaPool>,
) -> Tensor {
    sim_cross_batch_opt(world, cats, batch, subseq_of, Some(arena))
}

/// The pooled-vs-owned dispatch behind [`sim_cross_batch`] /
/// [`sim_cross_batch_in`].
pub fn sim_cross_batch_opt(
    world: &World,
    cats: &[u32],
    batch: usize,
    mut subseq_of: impl FnMut(u32) -> Vec<u32>,
    arena: Option<&Arc<ArenaPool>>,
) -> Tensor {
    let d = world.items_seq_emb.shape()[1];
    Tensor::build_with(arena, vec![batch, d], |out| {
        out.resize(batch * d, 0.0);
        // Group candidates by category so each subsequence pools once.
        let mut by_cat: std::collections::HashMap<u32, Vec<usize>> =
            std::collections::HashMap::new();
        for (i, &c) in cats.iter().enumerate() {
            by_cat.entry(c).or_default().push(i);
        }
        let mut pooled = vec![0.0f32; d];
        for (cat, rows) in by_cat {
            let sub = subseq_of(cat);
            if sub.is_empty() {
                continue;
            }
            pooled.iter_mut().for_each(|p| *p = 0.0);
            for &item in &sub {
                for (p, v) in pooled
                    .iter_mut()
                    .zip(world.items_seq_emb.f32_row(item as usize))
                {
                    *p += v;
                }
            }
            let inv = 1.0 / sub.len() as f32;
            for p in pooled.iter_mut() {
                *p *= inv;
            }
            for &r in &rows {
                out[r * d..(r + 1) * d].copy_from_slice(&pooled);
            }
        }
        // Padding rows repeat the last real row (in-buffer copy; the last
        // real row never overlaps a padding row).
        if cats.len() < batch && !cats.is_empty() {
            let last = (cats.len() - 1) * d;
            for r in cats.len()..batch {
                out.copy_within(last..last + d, r * d);
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::store::ItemFeatures;

    fn items(n: usize, d: usize) -> Vec<ItemFeatures> {
        (0..n)
            .map(|i| ItemFeatures {
                raw: vec![i as f32; d],
                mm: vec![i as f32 + 0.5; d],
                seq_emb: vec![0.0; 4],
                category: i as u32 % 3,
            })
            .collect()
    }

    #[test]
    fn item_batch_pads_with_last_row() {
        let t = item_raw_batch(&items(3, 4), 5);
        assert_eq!(t.shape, vec![5, 4]);
        assert_eq!(t.row(2), t.row(3));
        assert_eq!(t.row(2), t.row(4));
        assert_ne!(t.row(1), t.row(2));
    }

    #[test]
    fn mm_batch_shape() {
        let t = item_mm_batch(&items(4, 6), 4);
        assert_eq!(t.shape, vec![4, 6]);
        assert_eq!(t.row(0)[0], 0.5);
    }

    #[test]
    fn pooled_item_batches_match_owned_bitwise() {
        let arena = ArenaPool::new(8);
        let feats = items(3, 4);
        let owned = item_raw_batch(&feats, 5);
        let pooled = item_raw_batch_in(&feats, 5, &arena);
        assert!(pooled.is_pooled());
        assert_eq!(owned, pooled);
        let owned = item_mm_batch(&feats, 5);
        let pooled = item_mm_batch_in(&feats, 5, &arena);
        assert_eq!(owned, pooled);
        drop(pooled);
        assert_eq!(arena.outstanding(), 0, "pooled batches returned");
    }
}
