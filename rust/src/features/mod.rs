//! Feature substrate: the synthetic world (production-data substitute),
//! the remote feature store with latency modeling, and dense-tensor
//! assembly for the HLO heads.

pub mod assembly;
pub mod latency;
pub mod store;
pub mod world;

pub use latency::LatencyModel;
pub use store::{FeatureStore, ItemFeatures, UserFeatures};
pub use world::World;
