//! The synthetic world, loaded from the AOT-exported tables — the single
//! source of truth shared with python (`python/compile/data.py`).  Holds
//! user/item features, behavior sequences, the oracle click model and the
//! SIM-hard offline index.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{Manifest, Table};

/// All world tables resident in memory (a few tens of MB at repo scale).
pub struct World {
    pub n_users: usize,
    pub n_items: usize,
    pub l_long: usize,
    pub n_categories: usize,

    pub users_profile: Table,   // f32 [U, D_PROFILE_RAW]
    pub users_short_seq: Table, // u32 [U, L_SHORT]
    pub users_long_seq: Table,  // u32 [U, L_LONG]
    pub users_mean_mm: Table,   // f32 [U, D_MM]   (oracle)
    pub users_cat_share: Table, // f32 [U, N_CAT]  (oracle)
    pub users_z: Table,         // f32 [U, D_LATENT] (oracle)

    pub items_raw: Table,      // f32 [I, D_ITEM_RAW]
    pub items_mm: Table,       // f32 [I, D_MM]
    pub items_seq_emb: Table,  // f32 [I, D_SEQ_RAW]
    pub items_category: Table, // u32 [I]
    pub items_bid: Table,      // f32 [I]
    pub items_z: Table,        // f32 [I, D_LATENT] (oracle)

    pub w_hash: Table,             // f32 [D_LSH_BITS, D_MM]
    pub items_sign_packed: Table,  // u8  [I, D_LSH_BITS/8] (python oracle)

    pub click_w: [f32; 3],
    pub click_b: f32,

    /// SIM-hard offline index: (user, category) -> long-term subsequence.
    sim_index: Vec<HashMap<u32, Vec<u32>>>,
    pub l_sim_sub: usize,
}

impl World {
    pub fn load(manifest: &Manifest) -> Result<World> {
        let t = |n: &str| manifest.load_table(n);
        let users_long_seq = t("users_long_seq")?;
        let items_category = t("items_category")?;
        let n_users = users_long_seq.shape()[0];
        let l_long = users_long_seq.shape()[1];
        let n_items = items_category.shape()[0];
        let l_sim_sub = manifest.dim("L_SIM_SUB");
        let n_categories = manifest.dim("N_CATEGORIES");

        // Build the SIM-hard offline index (paper §3.3: preprocessed
        // <user, category, sub_sequence> triples).
        let mut sim_index = Vec::with_capacity(n_users);
        for u in 0..n_users {
            let seq = users_long_seq.u32_row(u);
            let mut per_cat: HashMap<u32, Vec<u32>> = HashMap::new();
            for &item in seq {
                let cat = items_category.as_u32()[item as usize];
                let sub = per_cat.entry(cat).or_default();
                if sub.len() < l_sim_sub {
                    sub.push(item);
                }
            }
            sim_index.push(per_cat);
        }

        Ok(World {
            n_users,
            n_items,
            l_long,
            n_categories,
            users_profile: t("users_profile")?,
            users_short_seq: t("users_short_seq")?,
            users_long_seq,
            users_mean_mm: t("users_mean_mm")?,
            users_cat_share: t("users_cat_share")?,
            users_z: t("users_z")?,
            items_raw: t("items_raw")?,
            items_mm: t("items_mm")?,
            items_seq_emb: t("items_seq_emb")?,
            items_category,
            items_bid: t("items_bid")?,
            items_z: t("items_z")?,
            w_hash: t("w_hash")?,
            items_sign_packed: t("items_sign_packed")?,
            click_w: manifest.oracle.click_w,
            click_b: manifest.oracle.click_b,
            sim_index,
            l_sim_sub,
        })
    }

    pub fn category_of(&self, item: u32) -> u32 {
        self.items_category.as_u32()[item as usize]
    }

    /// Categories present in a user's long-term history — the "all
    /// possible user-category combinations of the requesting user" that
    /// the pre-caching phase warms (§3.3, Figure 5).
    pub fn user_sim_categories(&self, user: usize) -> Vec<u32> {
        self.sim_index[user].keys().copied().collect()
    }

    /// SIM-hard subsequence for (user, category), optionally truncated to a
    /// parse budget (w/o pre-caching, §3.3).
    pub fn sim_subsequence(&self, user: usize, cat: u32, budget: f64) -> &[u32] {
        let sub = self.sim_index[user]
            .get(&cat)
            .map(|v| v.as_slice())
            .unwrap_or(&[]);
        let cap = ((self.l_sim_sub as f64 * budget).round() as usize).max(1);
        &sub[..sub.len().min(cap)]
    }

    // ---- oracle click model (matches data.World.click_logit) -------------
    pub fn click_logit(&self, user: usize, item: u32) -> f32 {
        let zu = self.users_z.f32_row(user);
        let zi = self.items_z.f32_row(item as usize);
        let d = zu.len() as f32;
        let short: f32 =
            zu.iter().zip(zi).map(|(a, b)| a * b).sum::<f32>() / d.sqrt();
        let mu = self.users_mean_mm.f32_row(user);
        let mi = self.items_mm.f32_row(item as usize);
        let long: f32 = mu.iter().zip(mi).map(|(a, b)| a * b).sum();
        let cat = self.users_cat_share.f32_row(user)
            [self.category_of(item) as usize];
        self.click_w[0] * short + self.click_w[1] * long
            + self.click_w[2] * cat + self.click_b
    }

    pub fn click_prob(&self, user: usize, item: u32) -> f32 {
        1.0 / (1.0 + (-self.click_logit(user, item)).exp())
    }

    pub fn bid(&self, item: u32) -> f32 {
        self.items_bid.as_f32()[item as usize]
    }

    /// Total bytes of raw item features (the denominator of the §5.3
    /// storage comparison: N2O table must be much smaller than this).
    pub fn item_feature_bytes(&self) -> usize {
        self.items_raw.size_bytes()
            + self.items_mm.size_bytes()
            + self.items_seq_emb.size_bytes()
    }
}
