//! Feature storage system — the remote user/item feature service of the
//! paper's Figure 2, with a synthetic latency model per fetch.
//!
//! The store is sharded; each shard charge is independent, so batched
//! fetches pay `max(shard delays)` when issued concurrently and
//! `sum(delays)` when sequential — exactly the effect that makes feature
//! fetching a latency bottleneck in the sequential pipeline and a
//! parallelizable one under AIF.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::sync::Mutex;

use super::latency::LatencyModel;
use super::world::World;
use crate::util::rng::Pcg64;
use crate::util::tls;

/// Independent latency-model RNG streams.  Concurrent batched fetches
/// used to serialize on ONE `Mutex<Pcg64>`; each thread now charges
/// against its home stream (by `util::tls::thread_tag`), so the lock is
/// effectively uncontended.  Streams stay deterministic: shard `s` is
/// always `Pcg64::with_stream(seed, 2 + s)`.
const RNG_SHARDS: usize = 8;

/// Fetched user features (owned copies — the remote returns bytes).
#[derive(Debug, Clone)]
pub struct UserFeatures {
    pub profile: Vec<f32>,
    pub short_seq: Vec<u32>,
    pub long_seq: Vec<u32>,
}

/// Fetched item features.
#[derive(Debug, Clone)]
pub struct ItemFeatures {
    pub raw: Vec<f32>,
    pub mm: Vec<f32>,
    pub seq_emb: Vec<f32>,
    pub category: u32,
}

/// Remote feature store over the world tables.
pub struct FeatureStore {
    world: Arc<World>,
    user_latency: LatencyModel,
    item_latency: LatencyModel,
    /// Per-shard RNG streams for the latency model (threads pick their
    /// home shard; see [`RNG_SHARDS`]).
    rngs: Vec<Mutex<Pcg64>>,
    pub user_fetches: AtomicU64,
    pub item_fetches: AtomicU64,
    pub bytes_served: AtomicU64,
    /// Store content version.  Bumped when the backing user-feature data
    /// is refreshed wholesale (nearline re-ingest); the user-state cache
    /// folds this into its epoch so cached tensors derived from stale
    /// features stop matching.
    version: AtomicU64,
}

impl FeatureStore {
    pub fn new(
        world: Arc<World>,
        user_latency: LatencyModel,
        item_latency: LatencyModel,
    ) -> Self {
        FeatureStore {
            world,
            user_latency,
            item_latency,
            rngs: (0..RNG_SHARDS)
                .map(|s| {
                    Mutex::new(Pcg64::with_stream(0xFEED, 2 + s as u64))
                })
                .collect(),
            user_fetches: AtomicU64::new(0),
            item_fetches: AtomicU64::new(0),
            bytes_served: AtomicU64::new(0),
            version: AtomicU64::new(0),
        }
    }

    pub fn world(&self) -> &World {
        &self.world
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Signal a wholesale refresh of the stored user features.  Cached
    /// cross-request user state keyed under the old version is
    /// invalidated on the next request (epoch mismatch).
    pub fn bump_version(&self) -> u64 {
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    fn charge(&self, model: &LatencyModel, bytes: usize) {
        let d = {
            let mut rng =
                self.rngs[tls::thread_shard(RNG_SHARDS)].lock().unwrap();
            model.sample(bytes, &mut rng)
        };
        super::latency::spin_wait(d);
        self.bytes_served.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Fetch user profile + behavior sequences (one remote round trip).
    pub fn fetch_user(&self, user: usize) -> UserFeatures {
        let w = &self.world;
        let profile = w.users_profile.f32_row(user).to_vec();
        let short_seq = w.users_short_seq.u32_row(user).to_vec();
        let long_seq = w.users_long_seq.u32_row(user).to_vec();
        let bytes =
            profile.len() * 4 + short_seq.len() * 4 + long_seq.len() * 4;
        self.charge(&self.user_latency, bytes);
        self.user_fetches.fetch_add(1, Ordering::Relaxed);
        UserFeatures {
            profile,
            short_seq,
            long_seq,
        }
    }

    /// Fetch a batch of item features (one remote round trip for the batch,
    /// as production stores support multi-get).
    pub fn fetch_items(&self, items: &[u32]) -> Vec<ItemFeatures> {
        let w = &self.world;
        let mut out = Vec::with_capacity(items.len());
        let mut bytes = 0;
        for &i in items {
            let f = ItemFeatures {
                raw: w.items_raw.f32_row(i as usize).to_vec(),
                mm: w.items_mm.f32_row(i as usize).to_vec(),
                seq_emb: w.items_seq_emb.f32_row(i as usize).to_vec(),
                category: w.category_of(i),
            };
            bytes += f.raw.len() * 4 + f.mm.len() * 4 + f.seq_emb.len() * 4 + 4;
            out.push(f);
        }
        self.charge(&self.item_latency, bytes);
        self.item_fetches.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Multi-get all SIM-hard subsequences of one user in a single remote
    /// round trip — what the pre-caching phase (§3.3, Figure 5) issues in
    /// parallel with retrieval.  One base charge + payload + parse.
    pub fn fetch_sim_all(
        &self,
        user: usize,
        budget: f64,
        parse_us_per_item: f64,
    ) -> Vec<(u32, Vec<u32>)> {
        let cats = self.world.user_sim_categories(user);
        let mut out = Vec::with_capacity(cats.len());
        let mut total_items = 0usize;
        for cat in cats {
            let sub = self.world.sim_subsequence(user, cat, budget).to_vec();
            total_items += sub.len();
            out.push((cat, sub));
        }
        self.charge(&self.user_latency, total_items * 4);
        let d = std::time::Duration::from_nanos(
            (parse_us_per_item * 1000.0 * total_items as f64) as u64,
        );
        super::latency::spin_wait(d);
        self.user_fetches.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Fetch + parse a SIM-hard subsequence from the remote store (the slow
    /// path that pre-caching eliminates, §3.3).  `parse_us_per_item` models
    /// the parsing cost the paper calls out.
    pub fn fetch_sim_subsequence(
        &self,
        user: usize,
        cat: u32,
        budget: f64,
        parse_us_per_item: f64,
    ) -> Vec<u32> {
        let sub = self.world.sim_subsequence(user, cat, budget).to_vec();
        let bytes = sub.len() * 4;
        self.charge(&self.user_latency, bytes);
        // Parsing cost scales with subsequence length.
        let d = std::time::Duration::from_nanos(
            (parse_us_per_item * 1000.0 * sub.len() as f64) as u64,
        );
        super::latency::spin_wait(d);
        sub
    }
}

#[cfg(test)]
mod tests {
    // FeatureStore needs a loaded World (integration-tested in
    // rust/tests/serving_pipeline.rs); here we cover the accounting logic
    // with the latency model alone.
    use super::super::latency::LatencyModel;
    use crate::util::rng::Pcg64;

    #[test]
    fn latency_model_deterministic_without_jitter() {
        let m = LatencyModel {
            base_us: 5.0,
            per_kib_us: 1.0,
            jitter_sigma: 0.0,
        };
        let mut rng = Pcg64::new(3);
        assert_eq!(m.sample(2048, &mut rng).as_micros(), 7);
    }
}
