//! Synthetic service-latency model for the feature-storage / retrieval
//! substrates (DESIGN.md §2).  Table 4's RT structure comes from *which*
//! fetches sit on the critical path, so remote calls are emulated with a
//! calibrated delay: base service time + per-KB payload term + lognormal
//! jitter.  Short delays spin on `Instant` (sleep() granularity on Linux is
//! ~50µs, far too coarse for µs-scale modeling).

use std::time::{Duration, Instant};

use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Fixed per-call service time, microseconds.
    pub base_us: f64,
    /// Additional microseconds per KiB of payload.
    pub per_kib_us: f64,
    /// Lognormal jitter sigma (0 = deterministic).
    pub jitter_sigma: f64,
}

impl LatencyModel {
    pub const fn zero() -> Self {
        LatencyModel {
            base_us: 0.0,
            per_kib_us: 0.0,
            jitter_sigma: 0.0,
        }
    }

    pub fn fixed(base_us: f64) -> Self {
        LatencyModel {
            base_us,
            per_kib_us: 0.0,
            jitter_sigma: 0.0,
        }
    }

    /// Sample the delay for a payload of `bytes`.
    pub fn sample(&self, bytes: usize, rng: &mut Pcg64) -> Duration {
        let mut us = self.base_us + self.per_kib_us * (bytes as f64 / 1024.0);
        if self.jitter_sigma > 0.0 {
            us *= rng.lognormal(0.0, self.jitter_sigma);
        }
        Duration::from_nanos((us * 1000.0) as u64)
    }

    /// Block the calling thread for a sampled delay.
    pub fn charge(&self, bytes: usize, rng: &mut Pcg64) -> Duration {
        let d = self.sample(bytes, rng);
        spin_wait(d);
        d
    }
}

/// Latency wait.  The testbed is a single-core VM, so burning the core on
/// a spin loop would *displace real work* and distort every overlap
/// measurement; waits above 100µs sleep (granularity ~50µs is negligible
/// at the ms scales modeled), only the short tail spins.
pub fn spin_wait(d: Duration) {
    if d.is_zero() {
        return;
    }
    let deadline = Instant::now() + d;
    if d > Duration::from_micros(100) {
        std::thread::sleep(d.saturating_sub(Duration::from_micros(60)));
    }
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_free() {
        let mut rng = Pcg64::new(1);
        assert_eq!(LatencyModel::zero().sample(1 << 20, &mut rng),
                   Duration::ZERO);
    }

    #[test]
    fn payload_term_scales() {
        let mut rng = Pcg64::new(2);
        let m = LatencyModel {
            base_us: 10.0,
            per_kib_us: 2.0,
            jitter_sigma: 0.0,
        };
        let d1 = m.sample(1024, &mut rng);
        let d2 = m.sample(10 * 1024, &mut rng);
        assert_eq!(d1, Duration::from_micros(12));
        assert_eq!(d2, Duration::from_micros(30));
    }

    #[test]
    fn spin_wait_is_accurate() {
        let t0 = Instant::now();
        spin_wait(Duration::from_micros(300));
        let e = t0.elapsed();
        assert!(e >= Duration::from_micros(300));
        assert!(e < Duration::from_millis(5), "{e:?}");
    }
}
