//! Typed serving configuration: JSON file -> [`ServingConfig`], plus the
//! preset pipeline rows of Table 4 (each paper row = one config).

use anyhow::{Context, Result};

use crate::features::LatencyModel;
use crate::util::json::Value;

/// How the SIM-hard cross feature is produced at pre-rank time (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Feature absent from the model.
    Off,
    /// Fetched + parsed synchronously inside the pre-rank phase
    /// (Table 4 "+SIM": the latency bottleneck).
    Sync,
    /// Pre-cached into the LRU cluster during retrieval ("+Pre-Caching").
    Precached,
}

/// Cross-request dynamic micro-batching knobs (runtime::coalescer).
/// Off by default: the sequential baseline path is byte-for-byte
/// unchanged unless `enabled` is set.
#[derive(Debug, Clone, PartialEq)]
pub struct CoalesceConfig {
    /// Route head executions through the `BatchCoalescer` (requires the
    /// variant's `*_mu` artifact in the manifest; silently falls back to
    /// the per-request path when absent).
    pub enabled: bool,
    /// Max queue dwell before a forced flush, microseconds.
    pub window_us: u64,
    /// Real-row cap per merged execution; 0 = the `_mu` artifact batch.
    pub max_coalesced_batch: usize,
    /// Jobs whose remaining deadline budget is below this skip the
    /// coalescing window entirely.
    pub bypass_margin_ms: f64,
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        CoalesceConfig {
            enabled: false,
            window_us: 200,
            max_coalesced_batch: 0,
            bypass_margin_ms: 5.0,
        }
    }
}

/// Durable state store + warm-restart knobs (DESIGN.md §16).  Off by
/// default: with `backend = "none"` nothing is written, nothing is
/// restored, and the serving stack behaves exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageConfig {
    /// "none" (default), "mem" (in-process, tests/demos) or "fs"
    /// (directory tree with atomic writes; S3-shaped keys).
    pub backend: String,
    /// Root directory of the "fs" backend.
    pub dir: String,
    /// Period of the background checkpoint publisher, milliseconds
    /// (0 = manual checkpoints only, via `POST /v1/checkpoint`).
    pub checkpoint_interval_ms: u64,
    /// Restore the newest snapshot + delta queue at boot instead of
    /// cold-rebuilding the N2O table.
    pub warm_boot: bool,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            backend: "none".into(),
            dir: "aif_state".into(),
            checkpoint_interval_ms: 0,
            warm_boot: true,
        }
    }
}

fn parse_storage(st: &Value, out: &mut StorageConfig) {
    if let Some(x) = st.get("backend").and_then(Value::as_str) {
        out.backend = x.to_string();
    }
    if let Some(x) = st.get("dir").and_then(Value::as_str) {
        out.dir = x.to_string();
    }
    if let Some(x) =
        st.get("checkpoint_interval_ms").and_then(Value::as_f64)
    {
        out.checkpoint_interval_ms = x as u64;
    }
    if let Some(b) = st.get("warm_boot").and_then(Value::as_bool) {
        out.warm_boot = b;
    }
}

/// What `UpdateQueue::publish` does when the bounded queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Producer blocks until the drain thread frees capacity (lossless;
    /// the producer inherits the consumer's pace).
    Block,
    /// Publish returns `Rejected` immediately and the rejection is
    /// counted (lossy but non-blocking; the producer decides what to do).
    Reject,
}

/// Parse a backpressure policy string ("block" | "reject") — shared by
/// the JSON config path and the CLI flags.
pub fn parse_backpressure(x: &str) -> Result<BackpressurePolicy> {
    Ok(match x {
        "block" => BackpressurePolicy::Block,
        "reject" => BackpressurePolicy::Reject,
        other => anyhow::bail!("unknown backpressure policy {other:?}"),
    })
}

/// Streaming nearline update-queue knobs (DESIGN.md §17).  The defaults
/// give a bounded, lossless queue with a hot-item priority lane and
/// periodic chunk compaction.
#[derive(Debug, Clone, PartialEq)]
pub struct NearlineConfig {
    /// Max pending item ids across both lanes (the queue bound).
    pub queue_capacity: usize,
    /// What `publish` does when the queue is full.
    pub policy: BackpressurePolicy,
    /// Max coalesced item ids applied per drained batch.
    pub max_batch: usize,
    /// Batching linger: how long the drain thread waits (condvar timeout,
    /// not busy-wait) for more events after the first, milliseconds.
    pub linger_ms: f64,
    /// How many times a failed batch is requeued before its ids are
    /// declared lost (`failed_updates`).
    pub retry_limit: u32,
    /// Serving touches at which an item routes to the priority lane
    /// (0 disables the hot lane).
    pub hot_min_touches: u32,
    /// Run chunk compaction + heat decay every N applied batches
    /// (0 disables the cadence).
    pub compact_every: u64,
}

impl Default for NearlineConfig {
    fn default() -> Self {
        NearlineConfig {
            queue_capacity: 65_536,
            policy: BackpressurePolicy::Block,
            max_batch: 1024,
            linger_ms: 2.0,
            retry_limit: 3,
            hot_min_touches: 32,
            compact_every: 64,
        }
    }
}

fn parse_nearline(nl: &Value, out: &mut NearlineConfig) -> Result<()> {
    if let Some(x) = nl.get("queue_capacity").and_then(Value::as_f64) {
        out.queue_capacity = x as usize;
    }
    if let Some(x) = nl.get("policy").and_then(Value::as_str) {
        out.policy = parse_backpressure(x)?;
    }
    if let Some(x) = nl.get("max_batch").and_then(Value::as_f64) {
        out.max_batch = x as usize;
    }
    if let Some(x) = nl.get("linger_ms").and_then(Value::as_f64) {
        out.linger_ms = x;
    }
    if let Some(x) = nl.get("retry_limit").and_then(Value::as_f64) {
        out.retry_limit = x as u32;
    }
    if let Some(x) = nl.get("hot_min_touches").and_then(Value::as_f64) {
        out.hot_min_touches = x as u32;
    }
    if let Some(x) = nl.get("compact_every").and_then(Value::as_f64) {
        out.compact_every = x as u64;
    }
    Ok(())
}

/// HTTP front-end knobs (DESIGN.md §18).  The default is the evented
/// reactor front end; `mode = "blocking"` keeps the thread-pool path
/// for A/B comparison (non-unix builds always fall back to blocking).
#[derive(Debug, Clone, PartialEq)]
pub struct FrontendConfig {
    /// "evented" (default) or "blocking".
    pub mode: String,
    /// Reactor threads owning the sockets (evented mode).
    pub n_event_loops: usize,
    /// Open-connection ceiling; connections past it are refused at
    /// accept (`rejected_capacity` in `/metrics`).
    pub max_connections: usize,
    /// Requests served per connection before keep-alive is withdrawn
    /// (0 = unlimited).
    pub keepalive_max_requests: usize,
    /// Timeout ladder: parked keep-alive connections close after this
    /// long with no bytes.
    pub idle_timeout_ms: u64,
    /// From a request's first byte until its head completes (408).
    pub header_timeout_ms: u64,
    /// From a request's first byte until its body completes (408).
    pub body_timeout_ms: u64,
    /// Listener accept backlog (applied via `listen(2)`).
    pub accept_backlog: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            mode: "evented".into(),
            n_event_loops: 2,
            max_connections: 16_384,
            keepalive_max_requests: 1000,
            idle_timeout_ms: 30_000,
            header_timeout_ms: 5_000,
            body_timeout_ms: 10_000,
            accept_backlog: 1024,
        }
    }
}

fn parse_frontend(fe: &Value, out: &mut FrontendConfig) -> Result<()> {
    if let Some(x) = fe.get("mode").and_then(Value::as_str) {
        match x {
            "evented" | "blocking" => out.mode = x.to_string(),
            other => {
                anyhow::bail!(
                    "unknown frontend mode {other:?} (evented|blocking)"
                )
            }
        }
    }
    if let Some(x) = fe.get("n_event_loops").and_then(Value::as_f64) {
        out.n_event_loops = (x as usize).max(1);
    }
    if let Some(x) = fe.get("max_connections").and_then(Value::as_f64) {
        out.max_connections = (x as usize).max(1);
    }
    if let Some(x) =
        fe.get("keepalive_max_requests").and_then(Value::as_f64)
    {
        out.keepalive_max_requests = x as usize;
    }
    if let Some(x) = fe.get("idle_timeout_ms").and_then(Value::as_f64) {
        out.idle_timeout_ms = x as u64;
    }
    if let Some(x) = fe.get("header_timeout_ms").and_then(Value::as_f64)
    {
        out.header_timeout_ms = x as u64;
    }
    if let Some(x) = fe.get("body_timeout_ms").and_then(Value::as_f64) {
        out.body_timeout_ms = x as u64;
    }
    if let Some(x) = fe.get("accept_backlog").and_then(Value::as_f64) {
        out.accept_backlog = (x as usize).max(1);
    }
    Ok(())
}

/// Cluster tier knobs (DESIGN.md §19): how a router process reaches its
/// worker shards — membership, connection pooling, retries, probing.
/// Only consulted in `--role router`; workers ignore it.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Static worker membership, `host:port` each.  `--join` and the
    /// `/v1/cluster/*` admin endpoints mutate the live set at runtime.
    pub workers: Vec<String>,
    /// Virtual nodes per worker on the placement ring.
    pub vnodes: usize,
    /// TCP connect timeout towards a worker, milliseconds.
    pub connect_timeout_ms: u64,
    /// Per-attempt read/write timeout towards a worker, milliseconds
    /// (the request's own `deadline_ms`, when smaller, wins).
    pub request_timeout_ms: u64,
    /// Extra attempts against successive replicas after the primary
    /// fails with a retryable error (connect failure or 5xx).
    pub retries: u32,
    /// Base backoff between attempts, milliseconds (doubled per retry;
    /// a worker's `Retry-After` on 429 overrides it upward).
    pub backoff_ms: u64,
    /// Health-prober cadence, milliseconds (0 disables probing: nodes
    /// are ejected/readmitted only by request outcomes and admin calls).
    pub probe_interval_ms: u64,
    /// Consecutive failures (probe or request) before a worker is
    /// ejected from the ring.
    pub eject_after: u32,
    /// Consecutive successful probes before an ejected worker rejoins.
    pub readmit_after: u32,
    /// Idle keep-alive connections retained per worker.
    pub pool_idle_per_node: usize,
    /// In-flight request cap per worker; at the cap the replica is
    /// skipped (all replicas capped => 429 at the router).
    pub max_inflight_per_node: usize,
    /// Explicit candidate lists at least this long scatter across all
    /// healthy shards; shorter lists take the single-hop path.
    pub scatter_min_candidates: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: Vec::new(),
            vnodes: 64,
            connect_timeout_ms: 250,
            request_timeout_ms: 2_000,
            retries: 2,
            backoff_ms: 10,
            probe_interval_ms: 200,
            eject_after: 3,
            readmit_after: 2,
            pool_idle_per_node: 8,
            max_inflight_per_node: 256,
            scatter_min_candidates: 2,
        }
    }
}

fn parse_cluster(cl: &Value, out: &mut ClusterConfig) -> Result<()> {
    if let Some(ws) = cl.get("workers") {
        let arr = ws.as_arr().ok_or_else(|| {
            anyhow::anyhow!("\"cluster.workers\" must be an array")
        })?;
        out.workers = arr
            .iter()
            .map(|w| {
                w.as_str().map(str::to_string).ok_or_else(|| {
                    anyhow::anyhow!("cluster worker entries must be strings")
                })
            })
            .collect::<Result<_>>()?;
    }
    macro_rules! num {
        ($field:ident, $key:literal, $ty:ty) => {
            if let Some(x) = cl.get($key).and_then(Value::as_f64) {
                out.$field = x as $ty;
            }
        };
    }
    num!(vnodes, "vnodes", usize);
    num!(connect_timeout_ms, "connect_timeout_ms", u64);
    num!(request_timeout_ms, "request_timeout_ms", u64);
    num!(retries, "retries", u32);
    num!(backoff_ms, "backoff_ms", u64);
    num!(probe_interval_ms, "probe_interval_ms", u64);
    num!(eject_after, "eject_after", u32);
    num!(readmit_after, "readmit_after", u32);
    num!(pool_idle_per_node, "pool_idle_per_node", usize);
    num!(max_inflight_per_node, "max_inflight_per_node", usize);
    num!(scatter_min_candidates, "scatter_min_candidates", usize);
    out.vnodes = out.vnodes.max(1);
    out.eject_after = out.eject_after.max(1);
    out.readmit_after = out.readmit_after.max(1);
    out.max_inflight_per_node = out.max_inflight_per_node.max(1);
    Ok(())
}

/// Per-request service-level class (DESIGN.md §20): how much compute
/// degradation a request tolerates under overload.  From the `sla`
/// request field / query param, defaulting to
/// [`OverloadConfig::default_sla`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlaClass {
    /// Always served at the top tier — or shed with 429 when even that
    /// is impossible.  Never observes a degraded tier.
    Guaranteed,
    /// Served at the controller's current tier (the default).
    Degradable,
    /// First to step down, last to recover: serves one tier below the
    /// controller whenever the load signal is not fully relaxed.
    BestEffort,
}

impl SlaClass {
    pub fn as_str(self) -> &'static str {
        match self {
            SlaClass::Guaranteed => "guaranteed",
            SlaClass::Degradable => "degradable",
            SlaClass::BestEffort => "best_effort",
        }
    }
}

/// Parse an SLA class string ("guaranteed" | "degradable" |
/// "best_effort") — shared by the JSON request path, the query string
/// and the config default.
pub fn parse_sla(x: &str) -> Result<SlaClass> {
    Ok(match x {
        "guaranteed" => SlaClass::Guaranteed,
        "degradable" => SlaClass::Degradable,
        "best_effort" => SlaClass::BestEffort,
        other => anyhow::bail!(
            "unknown sla {other:?} (guaranteed|degradable|best_effort)"
        ),
    })
}

/// One rung of a scenario's execution-tier ladder (DESIGN.md §20).
/// Tier 0 is the top (full) tier; later rungs trade effectiveness for
/// compute — a cheaper head variant, a truncated candidate set, or both.
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    /// Tier label, surfaced in responses/metrics (defaults to the
    /// variant name).
    pub name: String,
    /// Manifest variant serving this tier.
    pub variant: String,
    /// Cap on candidates scored at this tier (0 = no cap): explicit
    /// candidate lists are truncated, the default candidate count is
    /// clamped.  Deterministic, so scores stay bitwise-stable per tier.
    pub max_candidates: usize,
}

impl TierSpec {
    /// A full-compute tier over `variant` (what a ladder-less scenario
    /// serves).
    pub fn full(variant: &str) -> TierSpec {
        TierSpec {
            name: variant.to_string(),
            variant: variant.to_string(),
            max_candidates: 0,
        }
    }
}

/// Parse one ladder entry: either a bare variant string or
/// `{"name": .., "variant": .., "max_candidates": ..}`.
fn parse_tier(v: &Value) -> Result<TierSpec> {
    if let Some(s) = v.as_str() {
        if s.is_empty() {
            anyhow::bail!("ladder variant names must be non-empty");
        }
        return Ok(TierSpec::full(s));
    }
    let obj = v.as_obj().ok_or_else(|| {
        anyhow::anyhow!("ladder entries must be strings or objects")
    })?;
    let variant = obj
        .get("variant")
        .and_then(Value::as_str)
        .ok_or_else(|| {
            anyhow::anyhow!("ladder tier objects need a \"variant\"")
        })?
        .to_string();
    let name = obj
        .get("name")
        .and_then(Value::as_str)
        .unwrap_or(&variant)
        .to_string();
    let max_candidates = obj
        .get("max_candidates")
        .and_then(Value::as_f64)
        .unwrap_or(0.0) as usize;
    Ok(TierSpec {
        name,
        variant,
        max_candidates,
    })
}

fn parse_ladder(v: &Value) -> Result<Vec<TierSpec>> {
    let arr = v.as_arr().ok_or_else(|| {
        anyhow::anyhow!("\"ladder\" must be an array of tiers")
    })?;
    arr.iter().map(parse_tier).collect()
}

/// Load-adaptive computation tiering (DESIGN.md §20).  Off by default:
/// every scenario serves its single full tier and overload stays pure
/// 429-shedding.  When enabled, a background controller samples the
/// front-end job queue, the in-flight gauge and a windowed-p99 EWMA and
/// walks each scenario's active tier down/up its ladder with hysteresis.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadConfig {
    /// Run the feedback controller (requires a ladder with > 1 tier to
    /// have any effect).
    pub enabled: bool,
    /// Controller sampling cadence, milliseconds.
    pub sample_interval_ms: u64,
    /// Degrade one tier when the front-end job-queue depth reaches this.
    pub degrade_queue_depth: usize,
    /// Recover one tier only once the queue depth is back at or below
    /// this (must be < `degrade_queue_depth` for hysteresis).
    pub recover_queue_depth: usize,
    /// Degrade when in-flight connections reach this (0 = signal off).
    pub degrade_inflight: usize,
    /// In-flight level at or below which recovery is allowed (only
    /// consulted when `degrade_inflight` > 0).
    pub recover_inflight: usize,
    /// Degrade when the windowed-p99 EWMA reaches this, milliseconds
    /// (0 = signal off).
    pub degrade_p99_ms: f64,
    /// p99 EWMA at or below which recovery is allowed (only consulted
    /// when `degrade_p99_ms` > 0).
    pub recover_p99_ms: f64,
    /// Minimum time between tier transitions of one scenario,
    /// milliseconds (the anti-flap dwell).
    pub dwell_ms: u64,
    /// Smoothing factor of the p99 EWMA (0 < alpha <= 1; higher reacts
    /// faster).
    pub ewma_alpha: f64,
    /// The p99 bound the policy defends, milliseconds (reported in
    /// `/metrics`; the overload bench gates against it).  0 = none.
    pub sla_bound_ms: f64,
    /// SLA class of requests that don't carry one.
    pub default_sla: SlaClass,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            enabled: false,
            sample_interval_ms: 25,
            degrade_queue_depth: 8,
            recover_queue_depth: 1,
            degrade_inflight: 0,
            recover_inflight: 0,
            degrade_p99_ms: 0.0,
            recover_p99_ms: 0.0,
            dwell_ms: 250,
            ewma_alpha: 0.3,
            sla_bound_ms: 0.0,
            default_sla: SlaClass::Degradable,
        }
    }
}

fn parse_overload(ov: &Value, out: &mut OverloadConfig) -> Result<()> {
    if let Some(b) = ov.get("enabled").and_then(Value::as_bool) {
        out.enabled = b;
    }
    macro_rules! num {
        ($field:ident, $key:literal, $ty:ty) => {
            if let Some(x) = ov.get($key).and_then(Value::as_f64) {
                out.$field = x as $ty;
            }
        };
    }
    num!(sample_interval_ms, "sample_interval_ms", u64);
    num!(degrade_queue_depth, "degrade_queue_depth", usize);
    num!(recover_queue_depth, "recover_queue_depth", usize);
    num!(degrade_inflight, "degrade_inflight", usize);
    num!(recover_inflight, "recover_inflight", usize);
    num!(degrade_p99_ms, "degrade_p99_ms", f64);
    num!(recover_p99_ms, "recover_p99_ms", f64);
    num!(dwell_ms, "dwell_ms", u64);
    num!(ewma_alpha, "ewma_alpha", f64);
    num!(sla_bound_ms, "sla_bound_ms", f64);
    if let Some(x) = ov.get("default_sla").and_then(Value::as_str) {
        out.default_sla = parse_sla(x)?;
    }
    out.sample_interval_ms = out.sample_interval_ms.max(1);
    out.degrade_queue_depth = out.degrade_queue_depth.max(1);
    if out.recover_queue_depth >= out.degrade_queue_depth {
        anyhow::bail!(
            "overload.recover_queue_depth ({}) must be below \
             degrade_queue_depth ({}) for hysteresis",
            out.recover_queue_depth,
            out.degrade_queue_depth
        );
    }
    if out.degrade_p99_ms > 0.0 && out.recover_p99_ms >= out.degrade_p99_ms
    {
        anyhow::bail!(
            "overload.recover_p99_ms must be below degrade_p99_ms for \
             hysteresis"
        );
    }
    if !(out.ewma_alpha > 0.0 && out.ewma_alpha <= 1.0) {
        anyhow::bail!("overload.ewma_alpha must be in (0, 1]");
    }
    Ok(())
}

/// One named scenario served by the shared [`ServingCore`]: the
/// scenario-*specific* knobs only (variant, SIM handling, candidate count,
/// result size, dispatch-layer coalescing).  Everything else — fleet size,
/// stores, latency models, caches — is interaction-independent state owned
/// once by the core and shared by every registered scenario.
///
/// [`ServingCore`]: ../coordinator/struct.ServingCore.html
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Registry name (routing key of `ScoreRequest.scenario`).
    pub name: String,
    /// Serving variant (manifest registry name; picks the head artifact).
    pub variant: String,
    pub sim_mode: SimMode,
    /// SIM parse budget (w/o pre-caching the deadline truncates parsing).
    pub sim_budget: f64,
    pub n_candidates: usize,
    /// Default result size; per-request `top_k` overrides it.
    pub top_k: usize,
    /// Cross-request head-execution coalescing for this scenario's head.
    /// Scenarios sharing a head artifact share one coalescer queue (the
    /// first registration's knobs win).
    pub coalesce: CoalesceConfig,
    /// Execution-tier ladder, top (full) tier first.  Empty = one full
    /// tier over `variant` (see [`ScenarioConfig::effective_ladder`]).
    pub ladder: Vec<TierSpec>,
}

impl ScenarioConfig {
    /// Derive one scenario from the flat (single-variant) config fields —
    /// the backward-compatible shape every pre-registry entry point used.
    pub fn from_serving(name: &str, cfg: &ServingConfig) -> ScenarioConfig {
        ScenarioConfig {
            name: name.to_string(),
            variant: cfg.variant.clone(),
            sim_mode: cfg.sim_mode,
            sim_budget: cfg.sim_budget,
            n_candidates: cfg.n_candidates,
            top_k: cfg.top_k,
            coalesce: cfg.coalesce.clone(),
            ladder: cfg.ladder.clone(),
        }
    }

    /// The tier ladder this scenario serves: the declared rungs, or one
    /// full tier over `variant` when none are declared.  Always
    /// non-empty; tier 0 is the top tier.
    pub fn effective_ladder(&self) -> Vec<TierSpec> {
        if self.ladder.is_empty() {
            vec![TierSpec::full(&self.variant)]
        } else {
            self.ladder.clone()
        }
    }

    fn from_json(name: &str, v: &Value, base: &ServingConfig) -> Result<Self> {
        let mut s = ScenarioConfig::from_serving(name, base);
        if let Some(x) = v.get("variant").and_then(Value::as_str) {
            s.variant = x.to_string();
        }
        if let Some(x) = v.get("sim_mode").and_then(Value::as_str) {
            s.sim_mode = parse_sim_mode(x)?;
        }
        if let Some(x) = v.get("sim_budget").and_then(Value::as_f64) {
            s.sim_budget = x;
        }
        if let Some(x) = v.get("n_candidates").and_then(Value::as_f64) {
            s.n_candidates = x as usize;
        }
        if let Some(x) = v.get("top_k").and_then(Value::as_f64) {
            s.top_k = x as usize;
        }
        if let Some(co) = v.get("coalesce") {
            parse_coalesce(co, &mut s.coalesce);
        }
        if let Some(la) = v.get("ladder") {
            s.ladder = parse_ladder(la)?;
        }
        Ok(s)
    }
}

/// Parse a `sim_mode` string ("off" | "sync" | "precached") — shared by
/// the JSON config path and the CLI `--scenarios` flag.
pub fn parse_sim_mode(x: &str) -> Result<SimMode> {
    Ok(match x {
        "off" => SimMode::Off,
        "sync" => SimMode::Sync,
        "precached" => SimMode::Precached,
        other => anyhow::bail!("unknown sim_mode {other:?}"),
    })
}

fn parse_coalesce(co: &Value, out: &mut CoalesceConfig) {
    if let Some(b) = co.get("enabled").and_then(Value::as_bool) {
        out.enabled = b;
    }
    if let Some(x) = co.get("window_us").and_then(Value::as_f64) {
        out.window_us = x as u64;
    }
    if let Some(x) = co.get("max_coalesced_batch").and_then(Value::as_f64) {
        out.max_coalesced_batch = x as usize;
    }
    if let Some(x) = co.get("bypass_margin_ms").and_then(Value::as_f64) {
        out.bypass_margin_ms = x;
    }
}

/// One serving pipeline configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Serving variant (manifest registry name; picks the head artifact).
    pub variant: String,
    pub sim_mode: SimMode,
    /// SIM parse budget (w/o pre-caching the deadline truncates parsing).
    pub sim_budget: f64,
    /// RTP fleet size.
    pub n_rtp_workers: usize,
    /// Threads for the Merger's async/user-side tasks.
    pub n_async_workers: usize,
    /// Connection-handling threads of the HTTP server (`aif serve`).
    pub n_http_workers: usize,
    pub n_candidates: usize,
    /// Default result size; per-request `top_k` overrides it.
    pub top_k: usize,

    pub retrieval_latency: LatencyModel,
    pub user_store_latency: LatencyModel,
    pub item_store_latency: LatencyModel,
    /// Per-item SIM parse cost, microseconds (§3.3 "parsing processes").
    pub sim_parse_us: f64,

    pub lru_capacity: usize,
    pub lru_shards: usize,
    pub user_cache_shards: usize,
    /// Cross-request user-state reuse (DESIGN.md §15): cache async
    /// user-side tensors by (engine, user, epoch) with single-flight
    /// dedup, so back-to-back requests for one user pay one `user_tower`
    /// call.  Score-identical; `false` restores the request-scoped
    /// put/take handoff bit-for-bit.
    pub user_reuse: bool,
    /// Max cached (user, epoch) entries across shards.
    pub user_cache_entries: usize,
    /// Staleness bound for cached user state, milliseconds from insert
    /// (0 = no TTL).
    pub user_cache_ttl_ms: u64,
    /// Byte budget for cached user-side tensors (0 = unlimited); the LRU
    /// tail is evicted until the resident bytes fit.
    pub user_cache_bytes: usize,
    pub arena_retain: usize,
    /// Zero-copy hot path (DESIGN.md §14): assemble mini-batch tensors
    /// into arena-pooled buffers instead of fresh heap allocations.
    /// Score-invariant (property-tested bitwise-identical); off restores
    /// the owned-allocation path for before/after benchmarking.
    pub zero_copy: bool,

    /// Cross-request head-execution coalescing (ISSUE 2 tentpole).
    pub coalesce: CoalesceConfig,

    /// Durable state store + warm restart (ISSUE 6 tentpole).
    pub storage: StorageConfig,

    /// Streaming nearline update queue (ISSUE 7 tentpole).
    pub nearline: NearlineConfig,

    /// HTTP front end: evented reactor vs blocking pool (ISSUE 8
    /// tentpole).
    pub frontend: FrontendConfig,

    /// Sharded cluster tier: router-side knobs (ISSUE 9 tentpole).
    pub cluster: ClusterConfig,

    /// Execution-tier ladder of the flat (single-scenario) config;
    /// scenario blocks inherit it unless they declare their own
    /// (ISSUE 10 tentpole).
    pub ladder: Vec<TierSpec>,

    /// Load-adaptive tiering controller (DESIGN.md §20).
    pub overload: OverloadConfig,

    pub artifacts_dir: String,

    /// Named scenario blocks served over ONE shared core.  Empty (the
    /// default) means single-scenario mode: one scenario is derived from
    /// the flat `variant`/`sim_mode`/... fields above, named after the
    /// variant.
    pub scenarios: Vec<ScenarioConfig>,
    /// Which scenario serves requests that don't name one.  `None` =
    /// first scenario.
    pub default_scenario: Option<String>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            variant: "aif".into(),
            sim_mode: SimMode::Precached,
            sim_budget: 1.0,
            // Single-core testbed: small pools (threads only help overlap
            // modeled I/O latency, not compute).
            n_rtp_workers: 2,
            n_async_workers: 2,
            n_http_workers: 4,
            n_candidates: 4096,
            top_k: 128,
            // Calibrated so the stage ratios match the paper's setting:
            // retrieval ~12ms, user feature fetch ~2.5ms, item store
            // ~600µs/batch round trip.
            retrieval_latency: LatencyModel {
                base_us: 12_000.0,
                per_kib_us: 0.0,
                jitter_sigma: 0.25,
            },
            user_store_latency: LatencyModel {
                base_us: 2_000.0,
                per_kib_us: 4.0,
                jitter_sigma: 0.25,
            },
            item_store_latency: LatencyModel {
                base_us: 400.0,
                per_kib_us: 1.5,
                jitter_sigma: 0.25,
            },
            sim_parse_us: 3.0,
            lru_capacity: 8192,
            lru_shards: 16,
            user_cache_shards: 16,
            user_reuse: true,
            user_cache_entries: 8192,
            // Freshness bound: online-async state may be reused for at
            // most 2s before the tower re-runs (the paper's "0s fresh"
            // column becomes "<= TTL fresh" with reuse on).
            user_cache_ttl_ms: 2_000,
            user_cache_bytes: 64 << 20,
            arena_retain: 32,
            zero_copy: true,
            coalesce: CoalesceConfig::default(),
            storage: StorageConfig::default(),
            nearline: NearlineConfig::default(),
            frontend: FrontendConfig::default(),
            cluster: ClusterConfig::default(),
            ladder: Vec::new(),
            overload: OverloadConfig::default(),
            artifacts_dir: "artifacts".into(),
            scenarios: Vec::new(),
            default_scenario: None,
        }
    }
}

impl ServingConfig {
    /// Parse from a JSON object; absent keys keep defaults.
    pub fn from_json(v: &Value) -> Result<ServingConfig> {
        let mut c = ServingConfig::default();
        let get = |k: &str| v.get(k);
        if let Some(x) = get("variant").and_then(Value::as_str) {
            c.variant = x.to_string();
        }
        if let Some(x) = get("sim_mode").and_then(Value::as_str) {
            c.sim_mode = parse_sim_mode(x)?;
        }
        macro_rules! num {
            ($field:ident, $key:literal, $ty:ty) => {
                if let Some(x) = get($key).and_then(Value::as_f64) {
                    c.$field = x as $ty;
                }
            };
        }
        num!(sim_budget, "sim_budget", f64);
        num!(n_rtp_workers, "n_rtp_workers", usize);
        num!(n_async_workers, "n_async_workers", usize);
        num!(n_http_workers, "n_http_workers", usize);
        num!(n_candidates, "n_candidates", usize);
        num!(top_k, "top_k", usize);
        num!(sim_parse_us, "sim_parse_us", f64);
        num!(lru_capacity, "lru_capacity", usize);
        num!(lru_shards, "lru_shards", usize);
        num!(user_cache_entries, "user_cache_entries", usize);
        num!(user_cache_ttl_ms, "user_cache_ttl_ms", u64);
        num!(user_cache_bytes, "user_cache_bytes", usize);
        if let Some(b) = get("user_reuse").and_then(Value::as_bool) {
            c.user_reuse = b;
        }
        if let Some(x) = get("artifacts_dir").and_then(Value::as_str) {
            c.artifacts_dir = x.to_string();
        }
        if let Some(b) = get("zero_copy").and_then(Value::as_bool) {
            c.zero_copy = b;
        }
        if let Some(co) = get("coalesce") {
            parse_coalesce(co, &mut c.coalesce);
        }
        if let Some(st) = get("storage") {
            parse_storage(st, &mut c.storage);
        }
        if let Some(nl) = get("nearline") {
            parse_nearline(nl, &mut c.nearline)?;
        }
        if let Some(fe) = get("frontend") {
            parse_frontend(fe, &mut c.frontend)?;
        }
        if let Some(cl) = get("cluster") {
            parse_cluster(cl, &mut c.cluster)?;
        }
        if let Some(la) = get("ladder") {
            c.ladder = parse_ladder(la)?;
        }
        if let Some(ov) = get("overload") {
            parse_overload(ov, &mut c.overload)?;
        }
        // Named scenario blocks: `{"scenarios": {"name": {..}, ..}}`.
        // Each block starts from the flat fields and overrides.
        if let Some(sc) = get("scenarios") {
            let obj = sc.as_obj().ok_or_else(|| {
                anyhow::anyhow!("\"scenarios\" must be an object of blocks")
            })?;
            let mut blocks = Vec::with_capacity(obj.len());
            for (name, block) in obj.iter() {
                blocks.push(ScenarioConfig::from_json(name, block, &c)?);
            }
            c.scenarios = blocks;
        }
        if let Some(x) = get("default_scenario").and_then(Value::as_str) {
            c.default_scenario = Some(x.to_string());
        }
        for (key, slot) in [
            ("retrieval_latency", &mut c.retrieval_latency),
            ("user_store_latency", &mut c.user_store_latency),
            ("item_store_latency", &mut c.item_store_latency),
        ] {
            if let Some(l) = get(key) {
                *slot = LatencyModel {
                    base_us: l
                        .get("base_us")
                        .and_then(Value::as_f64)
                        .unwrap_or(slot.base_us),
                    per_kib_us: l
                        .get("per_kib_us")
                        .and_then(Value::as_f64)
                        .unwrap_or(slot.per_kib_us),
                    jitter_sigma: l
                        .get("jitter_sigma")
                        .and_then(Value::as_f64)
                        .unwrap_or(slot.jitter_sigma),
                };
            }
        }
        Ok(c)
    }

    /// The scenario list this config serves: the named blocks, or (when
    /// none are declared) one scenario derived from the flat fields and
    /// named after the variant.
    pub fn effective_scenarios(&self) -> Vec<ScenarioConfig> {
        if self.scenarios.is_empty() {
            vec![ScenarioConfig::from_serving(&self.variant, self)]
        } else {
            self.scenarios.clone()
        }
    }

    /// The scenario that serves requests not naming one.
    pub fn default_scenario_name(&self) -> String {
        match &self.default_scenario {
            Some(n) => n.clone(),
            None => self
                .scenarios
                .first()
                .map(|s| s.name.clone())
                .unwrap_or_else(|| self.variant.clone()),
        }
    }

    pub fn from_file(path: &str) -> Result<ServingConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let v = Value::parse(&text).context("parsing config JSON")?;
        Self::from_json(&v)
    }

    /// The Table-4 pipeline rows, in paper order.
    pub fn table4_rows() -> Vec<(&'static str, ServingConfig)> {
        let base = ServingConfig {
            variant: "base".into(),
            sim_mode: SimMode::Off,
            ..Default::default()
        };
        let mk = |variant: &str, sim: SimMode| ServingConfig {
            variant: variant.into(),
            sim_mode: sim,
            ..base.clone()
        };
        vec![
            ("Base", base.clone()),
            ("+ Async-Vectors", mk("t4_asyncvec", SimMode::Off)),
            ("+ SIM", mk("t4_sim", SimMode::Sync)),
            ("+ Pre-Caching", mk("t4_sim", SimMode::Precached)),
            ("+ BEA", mk("t4_bea", SimMode::Off)),
            ("+ Long-term User Behavior", mk("t4_longfull", SimMode::Off)),
            ("+ LSH", mk("t4_lsh", SimMode::Off)),
            ("AIF", mk("aif", SimMode::Precached)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = ServingConfig::default();
        assert_eq!(c.variant, "aif");
        assert!(c.n_candidates >= c.top_k);
    }

    #[test]
    fn json_overrides() {
        let v = Value::parse(
            r#"{"variant":"base","sim_mode":"sync","n_rtp_workers":2,
                "retrieval_latency":{"base_us":5000}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.variant, "base");
        assert_eq!(c.sim_mode, SimMode::Sync);
        assert_eq!(c.n_rtp_workers, 2);
        assert_eq!(c.retrieval_latency.base_us, 5000.0);
        // Untouched field keeps default.
        assert_eq!(c.top_k, 128);
    }

    #[test]
    fn table4_rows_cover_paper() {
        let rows = ServingConfig::table4_rows();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].0, "Base");
        assert_eq!(rows.last().unwrap().0, "AIF");
    }

    #[test]
    fn parses_n_http_workers() {
        let c = ServingConfig::default();
        assert_eq!(c.n_http_workers, 4);
        let v = Value::parse(r#"{"n_http_workers": 9}"#).unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.n_http_workers, 9);
    }

    #[test]
    fn single_scenario_derives_from_flat_fields() {
        let c = ServingConfig::default();
        let scenarios = c.effective_scenarios();
        assert_eq!(scenarios.len(), 1);
        assert_eq!(scenarios[0].name, "aif");
        assert_eq!(scenarios[0].variant, "aif");
        assert_eq!(scenarios[0].top_k, c.top_k);
        assert_eq!(c.default_scenario_name(), "aif");
    }

    #[test]
    fn scenario_blocks_parse_and_override() {
        let v = Value::parse(
            r#"{"variant": "aif", "top_k": 32, "default_scenario": "b",
                "scenarios": {
                  "a": {"variant": "base", "sim_mode": "off"},
                  "b": {"n_candidates": 128,
                        "coalesce": {"enabled": true}}
                }}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.scenarios.len(), 2);
        let a = c.scenarios.iter().find(|s| s.name == "a").unwrap();
        assert_eq!(a.variant, "base");
        assert_eq!(a.sim_mode, SimMode::Off);
        assert_eq!(a.top_k, 32, "blocks inherit the flat fields");
        let b = c.scenarios.iter().find(|s| s.name == "b").unwrap();
        assert_eq!(b.variant, "aif");
        assert_eq!(b.n_candidates, 128);
        assert!(b.coalesce.enabled);
        assert_eq!(c.default_scenario_name(), "b");
        assert_eq!(c.effective_scenarios().len(), 2);
    }

    #[test]
    fn rejects_bad_scenario_shapes() {
        let v = Value::parse(r#"{"scenarios": [1, 2]}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
        let v =
            Value::parse(r#"{"scenarios": {"a": {"sim_mode": "nope"}}}"#)
                .unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn zero_copy_defaults_on_and_parses() {
        let c = ServingConfig::default();
        assert!(c.zero_copy, "arena-backed hot path is the default");
        let v = Value::parse(r#"{"zero_copy": false}"#).unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert!(!c.zero_copy);
    }

    #[test]
    fn user_reuse_defaults_on_and_parses() {
        let c = ServingConfig::default();
        assert!(c.user_reuse, "cross-request reuse is the default");
        assert_eq!(c.user_cache_entries, 8192);
        assert_eq!(c.user_cache_ttl_ms, 2_000);
        assert_eq!(c.user_cache_bytes, 64 << 20);

        let v = Value::parse(
            r#"{"user_reuse": false, "user_cache_entries": 512,
                "user_cache_ttl_ms": 0, "user_cache_bytes": 1048576}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert!(!c.user_reuse);
        assert_eq!(c.user_cache_entries, 512);
        assert_eq!(c.user_cache_ttl_ms, 0);
        assert_eq!(c.user_cache_bytes, 1 << 20);
    }

    #[test]
    fn storage_defaults_off_and_parses() {
        let c = ServingConfig::default();
        assert_eq!(c.storage.backend, "none", "durability is opt-in");
        assert_eq!(c.storage.checkpoint_interval_ms, 0);
        assert!(c.storage.warm_boot);

        let v = Value::parse(
            r#"{"storage": {"backend": "fs", "dir": "/tmp/aif_state",
                 "checkpoint_interval_ms": 250, "warm_boot": false}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.storage.backend, "fs");
        assert_eq!(c.storage.dir, "/tmp/aif_state");
        assert_eq!(c.storage.checkpoint_interval_ms, 250);
        assert!(!c.storage.warm_boot);

        // Partial blocks keep remaining defaults.
        let v = Value::parse(r#"{"storage": {"backend": "mem"}}"#).unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.storage.backend, "mem");
        assert!(c.storage.warm_boot);
    }

    #[test]
    fn nearline_defaults_bounded_and_parses() {
        let c = ServingConfig::default();
        assert_eq!(c.nearline.queue_capacity, 65_536);
        assert_eq!(c.nearline.policy, BackpressurePolicy::Block);
        assert_eq!(c.nearline.max_batch, 1024);
        assert_eq!(c.nearline.retry_limit, 3);
        assert_eq!(c.nearline.hot_min_touches, 32);
        assert_eq!(c.nearline.compact_every, 64);

        let v = Value::parse(
            r#"{"nearline": {"queue_capacity": 256, "policy": "reject",
                 "max_batch": 64, "linger_ms": 0.5, "retry_limit": 1,
                 "hot_min_touches": 8, "compact_every": 0}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.nearline.queue_capacity, 256);
        assert_eq!(c.nearline.policy, BackpressurePolicy::Reject);
        assert_eq!(c.nearline.max_batch, 64);
        assert!((c.nearline.linger_ms - 0.5).abs() < 1e-9);
        assert_eq!(c.nearline.retry_limit, 1);
        assert_eq!(c.nearline.hot_min_touches, 8);
        assert_eq!(c.nearline.compact_every, 0);

        // Partial blocks keep remaining defaults.
        let v = Value::parse(r#"{"nearline": {"max_batch": 32}}"#).unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.nearline.max_batch, 32);
        assert_eq!(c.nearline.policy, BackpressurePolicy::Block);

        let v =
            Value::parse(r#"{"nearline": {"policy": "drop-newest"}}"#)
                .unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn frontend_defaults_evented_and_parses() {
        let c = ServingConfig::default();
        assert_eq!(c.frontend.mode, "evented");
        assert_eq!(c.frontend.n_event_loops, 2);
        assert_eq!(c.frontend.max_connections, 16_384);
        assert_eq!(c.frontend.keepalive_max_requests, 1000);
        assert_eq!(c.frontend.idle_timeout_ms, 30_000);
        assert_eq!(c.frontend.header_timeout_ms, 5_000);
        assert_eq!(c.frontend.body_timeout_ms, 10_000);
        assert_eq!(c.frontend.accept_backlog, 1024);

        let v = Value::parse(
            r#"{"frontend": {"mode": "blocking", "n_event_loops": 4,
                 "max_connections": 64, "keepalive_max_requests": 0,
                 "idle_timeout_ms": 100, "header_timeout_ms": 50,
                 "body_timeout_ms": 75, "accept_backlog": 8}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.frontend.mode, "blocking");
        assert_eq!(c.frontend.n_event_loops, 4);
        assert_eq!(c.frontend.max_connections, 64);
        assert_eq!(c.frontend.keepalive_max_requests, 0);
        assert_eq!(c.frontend.idle_timeout_ms, 100);
        assert_eq!(c.frontend.header_timeout_ms, 50);
        assert_eq!(c.frontend.body_timeout_ms, 75);
        assert_eq!(c.frontend.accept_backlog, 8);

        // Partial blocks keep remaining defaults; floors apply.
        let v = Value::parse(
            r#"{"frontend": {"n_event_loops": 0}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.frontend.n_event_loops, 1, "floor of 1 loop");
        assert_eq!(c.frontend.mode, "evented");

        let v = Value::parse(r#"{"frontend": {"mode": "fibers"}}"#)
            .unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn cluster_defaults_empty_and_parses() {
        let c = ServingConfig::default();
        assert!(c.cluster.workers.is_empty(), "no static members");
        assert_eq!(c.cluster.vnodes, 64);
        assert_eq!(c.cluster.retries, 2);
        assert_eq!(c.cluster.eject_after, 3);
        assert_eq!(c.cluster.readmit_after, 2);
        assert_eq!(c.cluster.max_inflight_per_node, 256);
        assert_eq!(c.cluster.scatter_min_candidates, 2);

        let v = Value::parse(
            r#"{"cluster": {"workers": ["127.0.0.1:9001", "127.0.0.1:9002"],
                 "vnodes": 16, "connect_timeout_ms": 50,
                 "request_timeout_ms": 500, "retries": 1, "backoff_ms": 5,
                 "probe_interval_ms": 40, "eject_after": 2,
                 "readmit_after": 1, "pool_idle_per_node": 4,
                 "max_inflight_per_node": 32,
                 "scatter_min_candidates": 8}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.cluster.workers.len(), 2);
        assert_eq!(c.cluster.workers[0], "127.0.0.1:9001");
        assert_eq!(c.cluster.vnodes, 16);
        assert_eq!(c.cluster.connect_timeout_ms, 50);
        assert_eq!(c.cluster.request_timeout_ms, 500);
        assert_eq!(c.cluster.retries, 1);
        assert_eq!(c.cluster.backoff_ms, 5);
        assert_eq!(c.cluster.probe_interval_ms, 40);
        assert_eq!(c.cluster.eject_after, 2);
        assert_eq!(c.cluster.readmit_after, 1);
        assert_eq!(c.cluster.pool_idle_per_node, 4);
        assert_eq!(c.cluster.max_inflight_per_node, 32);
        assert_eq!(c.cluster.scatter_min_candidates, 8);

        // Partial blocks keep remaining defaults; floors apply.
        let v = Value::parse(
            r#"{"cluster": {"vnodes": 0, "eject_after": 0}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.cluster.vnodes, 1, "floor of 1 vnode");
        assert_eq!(c.cluster.eject_after, 1, "floor of 1 failure");
        assert_eq!(c.cluster.retries, 2);

        // Bad shapes are rejected, not ignored.
        let v = Value::parse(r#"{"cluster": {"workers": "a,b"}}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
        let v = Value::parse(r#"{"cluster": {"workers": [1]}}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn overload_defaults_off_and_parses() {
        let c = ServingConfig::default();
        assert!(!c.overload.enabled, "tiering is opt-in");
        assert!(c.ladder.is_empty(), "single full tier by default");
        assert_eq!(c.overload.default_sla, SlaClass::Degradable);
        assert!(
            c.overload.recover_queue_depth < c.overload.degrade_queue_depth,
            "default thresholds carry hysteresis"
        );

        let v = Value::parse(
            r#"{"overload": {"enabled": true, "sample_interval_ms": 10,
                 "degrade_queue_depth": 6, "recover_queue_depth": 2,
                 "degrade_inflight": 32, "recover_inflight": 8,
                 "degrade_p99_ms": 40, "recover_p99_ms": 15,
                 "dwell_ms": 100, "ewma_alpha": 0.5, "sla_bound_ms": 80,
                 "default_sla": "best_effort"}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert!(c.overload.enabled);
        assert_eq!(c.overload.sample_interval_ms, 10);
        assert_eq!(c.overload.degrade_queue_depth, 6);
        assert_eq!(c.overload.recover_queue_depth, 2);
        assert_eq!(c.overload.degrade_inflight, 32);
        assert_eq!(c.overload.recover_inflight, 8);
        assert_eq!(c.overload.degrade_p99_ms, 40.0);
        assert_eq!(c.overload.recover_p99_ms, 15.0);
        assert_eq!(c.overload.dwell_ms, 100);
        assert_eq!(c.overload.ewma_alpha, 0.5);
        assert_eq!(c.overload.sla_bound_ms, 80.0);
        assert_eq!(c.overload.default_sla, SlaClass::BestEffort);

        // Inverted thresholds (no hysteresis band) are rejected.
        let v = Value::parse(
            r#"{"overload": {"degrade_queue_depth": 4,
                 "recover_queue_depth": 4}}"#,
        )
        .unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
        let v = Value::parse(
            r#"{"overload": {"degrade_p99_ms": 10, "recover_p99_ms": 20}}"#,
        )
        .unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
        let v = Value::parse(r#"{"overload": {"ewma_alpha": 0}}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
        let v =
            Value::parse(r#"{"overload": {"default_sla": "vip"}}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn ladder_parses_and_scenarios_inherit() {
        let v = Value::parse(
            r#"{"variant": "aif",
                "ladder": ["aif",
                           {"name": "lsh_only", "variant": "base",
                            "max_candidates": 32}],
                "scenarios": {
                  "a": {},
                  "b": {"ladder": ["base"]}
                }}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert_eq!(c.ladder.len(), 2);
        assert_eq!(c.ladder[0], TierSpec::full("aif"));
        assert_eq!(c.ladder[1].name, "lsh_only");
        assert_eq!(c.ladder[1].variant, "base");
        assert_eq!(c.ladder[1].max_candidates, 32);
        let a = c.scenarios.iter().find(|s| s.name == "a").unwrap();
        assert_eq!(a.ladder.len(), 2, "blocks inherit the flat ladder");
        let b = c.scenarios.iter().find(|s| s.name == "b").unwrap();
        assert_eq!(b.ladder, vec![TierSpec::full("base")]);

        // A ladder-less scenario serves one full tier over its variant.
        let c = ServingConfig::default();
        let eff = c.effective_scenarios()[0].effective_ladder();
        assert_eq!(eff, vec![TierSpec::full("aif")]);

        // Bad shapes are rejected, not ignored.
        for bad in [
            r#"{"ladder": "aif"}"#,
            r#"{"ladder": [""]}"#,
            r#"{"ladder": [{"name": "x"}]}"#,
            r#"{"ladder": [1]}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(ServingConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn sla_class_round_trips() {
        for (s, want) in [
            ("guaranteed", SlaClass::Guaranteed),
            ("degradable", SlaClass::Degradable),
            ("best_effort", SlaClass::BestEffort),
        ] {
            let got = parse_sla(s).unwrap();
            assert_eq!(got, want);
            assert_eq!(got.as_str(), s);
        }
        assert!(parse_sla("platinum").is_err());
    }

    #[test]
    fn rejects_bad_sim_mode() {
        let v = Value::parse(r#"{"sim_mode":"bogus"}"#).unwrap();
        assert!(ServingConfig::from_json(&v).is_err());
    }

    #[test]
    fn coalesce_defaults_off_and_parses() {
        let c = ServingConfig::default();
        assert!(!c.coalesce.enabled, "sequential baseline unchanged");
        assert_eq!(c.coalesce.window_us, 200);
        assert_eq!(c.coalesce.max_coalesced_batch, 0);

        let v = Value::parse(
            r#"{"coalesce": {"enabled": true, "window_us": 500,
                 "max_coalesced_batch": 384, "bypass_margin_ms": 2.5}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert!(c.coalesce.enabled);
        assert_eq!(c.coalesce.window_us, 500);
        assert_eq!(c.coalesce.max_coalesced_batch, 384);
        assert!((c.coalesce.bypass_margin_ms - 2.5).abs() < 1e-9);

        // Partial objects keep the remaining defaults.
        let v = Value::parse(r#"{"coalesce": {"enabled": true}}"#).unwrap();
        let c = ServingConfig::from_json(&v).unwrap();
        assert!(c.coalesce.enabled);
        assert_eq!(c.coalesce.window_us, 200);
    }
}
