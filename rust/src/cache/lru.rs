//! Sharded LRU cache cluster — the SIM pre-caching substrate (paper §3.3,
//! Figure 5: "an LRU cache cluster" holding parsed subsequences for all
//! user-category combinations of the requesting user).
//!
//! Classic HashMap + intrusive doubly-linked list per shard (indices into a
//! slab, no unsafe), `Mutex` per shard; keys hash to shards so concurrent
//! requests rarely contend.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const NIL: usize = usize::MAX;

struct Entry<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

struct Shard<K, V> {
    map: HashMap<K, usize>,
    slab: Vec<Entry<K, V>>,
    free: Vec<usize>,
    head: usize, // most-recent
    tail: usize, // least-recent
    capacity: usize,
}

impl<K: Eq + Hash + Clone, V> Shard<K, V> {
    fn new(capacity: usize) -> Self {
        Shard {
            map: HashMap::with_capacity(capacity),
            slab: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
        }
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn get(&mut self, key: &K) -> Option<&V> {
        let idx = *self.map.get(key)?;
        self.unlink(idx);
        self.push_front(idx);
        Some(&self.slab[idx].value)
    }

    fn insert(&mut self, key: K, value: V) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.slab[idx].value = value;
            self.unlink(idx);
            self.push_front(idx);
            return false;
        }
        let mut evicted = false;
        if self.map.len() >= self.capacity {
            // Evict LRU.
            let lru = self.tail;
            self.unlink(lru);
            let old_key = self.slab[lru].key.clone();
            self.map.remove(&old_key);
            self.free.push(lru);
            evicted = true;
        }
        let idx = if let Some(i) = self.free.pop() {
            self.slab[i] = Entry {
                key: key.clone(),
                value,
                prev: NIL,
                next: NIL,
            };
            i
        } else {
            self.slab.push(Entry {
                key: key.clone(),
                value,
                prev: NIL,
                next: NIL,
            });
            self.slab.len() - 1
        };
        self.map.insert(key, idx);
        self.push_front(idx);
        evicted
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn clear(&mut self) {
        self.map.clear();
        self.slab.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

/// Cache statistics (hit ratio drives the Table-4 pre-caching rows).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Thread-safe sharded LRU.
pub struct ShardedLru<K, V> {
    shards: Vec<Mutex<Shard<K, V>>>,
    pub stats: CacheStats,
}

impl<K: Eq + Hash + Clone, V: Clone> ShardedLru<K, V> {
    /// `capacity` is total across `n_shards` shards.
    pub fn new(capacity: usize, n_shards: usize) -> Self {
        assert!(n_shards > 0 && capacity >= n_shards);
        let per = capacity / n_shards;
        ShardedLru {
            shards: (0..n_shards)
                .map(|_| Mutex::new(Shard::new(per)))
                .collect(),
            stats: CacheStats::default(),
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    pub fn get(&self, key: &K) -> Option<V> {
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        match shard.get(key) {
            Some(v) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: K, value: V) {
        let evicted = self.shards[self.shard_of(&key)]
            .lock()
            .unwrap()
            .insert(key, value);
        if evicted {
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Get, or compute-and-insert on miss.
    pub fn get_or_insert_with(&self, key: K, f: impl FnOnce() -> V) -> V {
        if let Some(v) = self.get(&key) {
            return v;
        }
        let v = f();
        self.insert(key, v.clone());
        v
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached entry (benchmark isolation between runs sharing
    /// one cache cluster).  Hit/miss statistics are left untouched.
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(3, 1);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(3, 30);
        assert_eq!(c.get(&1), Some(10)); // touch 1 -> 2 is now LRU
        c.insert(4, 40);
        assert_eq!(c.get(&2), None, "2 evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&4), Some(40));
        assert_eq!(c.stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn update_existing_does_not_evict() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(2, 1);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(20));
    }

    #[test]
    fn clear_empties_and_cache_stays_usable() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(8, 2);
        for i in 0..8 {
            c.insert(i, i * 10);
        }
        assert!(!c.is_empty());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&3), None);
        // Insert/evict machinery still intact after the wipe.
        for i in 0..16 {
            c.insert(i, i);
        }
        assert!(c.len() <= 8);
        assert_eq!(c.get(&15), Some(15));
    }

    #[test]
    fn never_exceeds_capacity() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(64, 4);
        for i in 0..10_000 {
            c.insert(i, i);
        }
        assert!(c.len() <= 64);
    }

    #[test]
    fn get_or_insert_with_computes_once() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(8, 2);
        let mut calls = 0;
        let v = c.get_or_insert_with(5, || {
            calls += 1;
            99
        });
        assert_eq!(v, 99);
        let v = c.get_or_insert_with(5, || {
            calls += 1;
            100
        });
        assert_eq!(v, 99);
        assert_eq!(calls, 1);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(ShardedLru::<u64, u64>::new(256, 8));
        let mut handles = vec![];
        for t in 0..8u64 {
            let c = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    c.insert(t * 1000 + i % 100, i);
                    c.get(&(i % 100));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 256);
    }
}
