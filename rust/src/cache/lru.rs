//! Sharded LRU cache cluster — the SIM pre-caching substrate (paper §3.3,
//! Figure 5: "an LRU cache cluster" holding parsed subsequences for all
//! user-category combinations of the requesting user) and, since the
//! cross-request user-state cache (DESIGN.md §15), the storage layer for
//! long-lived user-side tensors.
//!
//! Classic HashMap + intrusive doubly-linked list per shard (indices into a
//! slab, no unsafe), `Mutex` per shard; keys hash to shards so concurrent
//! requests rarely contend.  Beyond the entry-count capacity, a cache can
//! carry a **TTL** (entries expire `ttl` after insert — staleness bound,
//! not touch-refreshed) and a **byte budget** with a caller-supplied
//! weigher (the LRU tail is evicted until the resident weight fits).

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

const NIL: usize = usize::MAX;

/// Computes the resident weight (bytes) of a value for budget eviction.
pub type Weigher<V> = Box<dyn Fn(&V) -> usize + Send + Sync>;

struct Entry<K, V> {
    key: K,
    /// `None` only for freed slab slots — evicted values are dropped
    /// eagerly (a byte budget that kept evictees alive would lie).
    value: Option<V>,
    prev: usize,
    next: usize,
    /// Insert/update time.  TTL expiry is measured from here, NOT from
    /// the last touch — a hot entry must still go stale on schedule.
    at: Instant,
    weight: usize,
}

enum Probe<'a, V> {
    Hit(&'a V),
    Expired,
    Absent,
}

struct Shard<K, V> {
    map: HashMap<K, usize>,
    slab: Vec<Entry<K, V>>,
    free: Vec<usize>,
    head: usize, // most-recent
    tail: usize, // least-recent
    capacity: usize,
    /// Sum of live entry weights (0 when the cache has no weigher).
    bytes: usize,
}

impl<K: Eq + Hash + Clone, V> Shard<K, V> {
    fn new(capacity: usize) -> Self {
        Shard {
            map: HashMap::with_capacity(capacity),
            slab: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
            bytes: 0,
        }
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Unlink + unmap + free one entry, dropping its value eagerly.
    fn remove_idx(&mut self, idx: usize) {
        self.unlink(idx);
        let key = self.slab[idx].key.clone();
        self.map.remove(&key);
        self.bytes -= self.slab[idx].weight;
        self.slab[idx].value = None;
        self.slab[idx].weight = 0;
        self.free.push(idx);
    }

    fn get(&mut self, key: &K, ttl: Option<Duration>) -> Probe<'_, V> {
        let Some(&idx) = self.map.get(key) else {
            return Probe::Absent;
        };
        if let Some(ttl) = ttl {
            if self.slab[idx].at.elapsed() > ttl {
                self.remove_idx(idx);
                return Probe::Expired;
            }
        }
        self.unlink(idx);
        self.push_front(idx);
        Probe::Hit(self.slab[idx].value.as_ref().expect("live entry"))
    }

    /// Insert/update, then evict from the tail until both the entry cap
    /// and the byte budget hold.  Returns evicted-entry count.
    fn insert(
        &mut self,
        key: K,
        value: V,
        weight: usize,
        max_bytes: usize,
    ) -> u64 {
        if let Some(&idx) = self.map.get(&key) {
            self.bytes = self.bytes - self.slab[idx].weight + weight;
            self.slab[idx].value = Some(value);
            self.slab[idx].weight = weight;
            self.slab[idx].at = Instant::now();
            self.unlink(idx);
            self.push_front(idx);
            return self.evict_over_budget(max_bytes);
        }
        let entry = Entry {
            key: key.clone(),
            value: Some(value),
            prev: NIL,
            next: NIL,
            at: Instant::now(),
            weight,
        };
        let idx = if let Some(i) = self.free.pop() {
            self.slab[i] = entry;
            i
        } else {
            self.slab.push(entry);
            self.slab.len() - 1
        };
        self.map.insert(key, idx);
        self.push_front(idx);
        self.bytes += weight;
        self.evict_over_budget(max_bytes)
    }

    /// Evict LRU entries while over the entry cap or the byte budget.
    /// The newest entry always survives — a single over-budget value
    /// would otherwise evict itself and defeat caching entirely.
    fn evict_over_budget(&mut self, max_bytes: usize) -> u64 {
        let mut evicted = 0;
        while self.map.len() > self.capacity
            || (max_bytes > 0
                && self.bytes > max_bytes
                && self.map.len() > 1)
        {
            let lru = self.tail;
            self.remove_idx(lru);
            evicted += 1;
        }
        evicted
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn clear(&mut self) {
        self.map.clear();
        self.slab.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.bytes = 0;
    }
}

/// Cache statistics (hit ratio drives the Table-4 pre-caching rows).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
    /// TTL expiries found on probe (also counted as misses).
    pub expired: AtomicU64,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Thread-safe sharded LRU.
pub struct ShardedLru<K, V> {
    shards: Vec<Mutex<Shard<K, V>>>,
    pub stats: CacheStats,
    ttl: Option<Duration>,
    /// Per-shard byte budget; 0 = unlimited.
    max_bytes_per_shard: usize,
    weigher: Option<Weigher<V>>,
}

impl<K: Eq + Hash + Clone, V: Clone> ShardedLru<K, V> {
    /// `capacity` is total across `n_shards` shards.  No TTL, no byte
    /// budget — the classic entry-count LRU.
    pub fn new(capacity: usize, n_shards: usize) -> Self {
        Self::with_limits(capacity, n_shards, None, 0, None)
    }

    /// Full-control constructor: optional TTL (staleness bound from
    /// insert time) and optional byte budget (`max_bytes` total across
    /// shards, weighed by `weigher`; 0 = unlimited).
    pub fn with_limits(
        capacity: usize,
        n_shards: usize,
        ttl: Option<Duration>,
        max_bytes: usize,
        weigher: Option<Weigher<V>>,
    ) -> Self {
        assert!(n_shards > 0 && capacity >= n_shards);
        let per = capacity / n_shards;
        ShardedLru {
            shards: (0..n_shards)
                .map(|_| Mutex::new(Shard::new(per)))
                .collect(),
            stats: CacheStats::default(),
            ttl,
            max_bytes_per_shard: if max_bytes == 0 {
                0
            } else {
                max_bytes.div_ceil(n_shards)
            },
            weigher,
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    pub fn get(&self, key: &K) -> Option<V> {
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        match shard.get(key, self.ttl) {
            Probe::Hit(v) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            Probe::Expired => {
                self.stats.expired.fetch_add(1, Ordering::Relaxed);
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
            Probe::Absent => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: K, value: V) {
        let weight = self.weigher.as_ref().map_or(0, |w| w(&value));
        let evicted = self.shards[self.shard_of(&key)]
            .lock()
            .unwrap()
            .insert(key, value, weight, self.max_bytes_per_shard);
        if evicted > 0 {
            self.stats.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Get, or compute-and-insert on miss.
    pub fn get_or_insert_with(&self, key: K, f: impl FnOnce() -> V) -> V {
        if let Some(v) = self.get(&key) {
            return v;
        }
        let v = f();
        self.insert(key, v.clone());
        v
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of live entry weights (0 without a weigher).
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Drop every cached entry (benchmark isolation between runs sharing
    /// one cache cluster).  Hit/miss statistics are left untouched.
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(3, 1);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(3, 30);
        assert_eq!(c.get(&1), Some(10)); // touch 1 -> 2 is now LRU
        c.insert(4, 40);
        assert_eq!(c.get(&2), None, "2 evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&4), Some(40));
        assert_eq!(c.stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn update_existing_does_not_evict() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(2, 1);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(20));
    }

    #[test]
    fn clear_empties_and_cache_stays_usable() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(8, 2);
        for i in 0..8 {
            c.insert(i, i * 10);
        }
        assert!(!c.is_empty());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&3), None);
        // Insert/evict machinery still intact after the wipe.
        for i in 0..16 {
            c.insert(i, i);
        }
        assert!(c.len() <= 8);
        assert_eq!(c.get(&15), Some(15));
    }

    #[test]
    fn never_exceeds_capacity() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(64, 4);
        for i in 0..10_000 {
            c.insert(i, i);
        }
        assert!(c.len() <= 64);
    }

    #[test]
    fn get_or_insert_with_computes_once() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(8, 2);
        let mut calls = 0;
        let v = c.get_or_insert_with(5, || {
            calls += 1;
            99
        });
        assert_eq!(v, 99);
        let v = c.get_or_insert_with(5, || {
            calls += 1;
            100
        });
        assert_eq!(v, 99);
        assert_eq!(calls, 1);
    }

    #[test]
    fn ttl_expires_entries_on_probe() {
        let c: ShardedLru<u32, u32> = ShardedLru::with_limits(
            4,
            1,
            Some(Duration::from_millis(30)),
            0,
            None,
        );
        c.insert(1, 10);
        assert_eq!(c.get(&1), Some(10), "fresh entry hits");
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(c.get(&1), None, "stale entry expires");
        assert_eq!(c.stats.expired.load(Ordering::Relaxed), 1);
        assert_eq!(c.len(), 0, "expired entry was removed, not skipped");
        // Re-insert restarts the clock.
        c.insert(1, 11);
        assert_eq!(c.get(&1), Some(11));
    }

    #[test]
    fn ttl_measured_from_insert_not_last_touch() {
        let c: ShardedLru<u32, u32> = ShardedLru::with_limits(
            4,
            1,
            Some(Duration::from_millis(50)),
            0,
            None,
        );
        c.insert(1, 10);
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(20));
            let _ = c.get(&1); // touches must NOT refresh the deadline
        }
        assert_eq!(c.get(&1), None, "hot entry still goes stale");
    }

    #[test]
    fn byte_budget_evicts_lru_until_fit() {
        // Weigher = value itself; budget of 100 "bytes" in one shard.
        let c: ShardedLru<u32, u32> = ShardedLru::with_limits(
            64,
            1,
            None,
            100,
            Some(Box::new(|v: &u32| *v as usize)),
        );
        c.insert(1, 40);
        c.insert(2, 40);
        assert_eq!(c.resident_bytes(), 80);
        c.insert(3, 40); // 120 > 100: evict LRU (key 1)
        assert_eq!(c.get(&1), None, "oldest evicted to fit the budget");
        assert_eq!(c.resident_bytes(), 80);
        assert_eq!(c.stats.evictions.load(Ordering::Relaxed), 1);
        // A single over-budget entry survives (never self-evicts).
        c.clear();
        c.insert(9, 400);
        assert_eq!(c.get(&9), Some(400));
        assert_eq!(c.resident_bytes(), 400);
    }

    #[test]
    fn update_adjusts_resident_bytes() {
        let c: ShardedLru<u32, u32> = ShardedLru::with_limits(
            8,
            1,
            None,
            1000,
            Some(Box::new(|v: &u32| *v as usize)),
        );
        c.insert(1, 30);
        c.insert(1, 70);
        assert_eq!(c.resident_bytes(), 70, "update replaces the weight");
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(ShardedLru::<u64, u64>::new(256, 8));
        let mut handles = vec![];
        for t in 0..8u64 {
            let c = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    c.insert(t * 1000 + i % 100, i);
                    c.get(&(i % 100));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 256);
    }
}
