//! Caching substrates: the SIM pre-cache LRU cluster (§3.3), the Arena
//! memory pool (§3.4) and the cross-request user-state cache with its
//! single-flight layer (§3.1/§3.4, DESIGN.md §15).

pub mod arena;
pub mod lru;
pub mod user_cache;

pub use arena::{ArenaPool, PooledBuf};
pub use lru::{CacheStats, ShardedLru};
pub use user_cache::{
    Claim, Flight, FlightGuard, RequestKey, SimPrewarm, UserAsync,
    UserKey, UserSide, UserStateCache, UserVecCache,
};
