//! Caching substrates: the SIM pre-cache LRU cluster (§3.3), the Arena
//! memory pool (§3.4) and the request-scoped user-vector cache (§3.1/§3.4).

pub mod arena;
pub mod lru;
pub mod user_cache;

pub use arena::{ArenaPool, PooledBuf};
pub use lru::{CacheStats, ShardedLru};
pub use user_cache::{RequestKey, UserAsync, UserVecCache};
