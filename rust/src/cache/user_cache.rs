//! User-side async-result cache (paper §3.4, "Online Asynchronous
//! Inference" engineering).
//!
//! Phase 1 (during retrieval) writes the async-inferred user tensors under
//! a key hashed from (request id, user nickname); phase 2 (pre-ranking)
//! takes them back.  Consistent hashing over that key pins both phases to
//! the same RTP worker / cache node, guaranteeing the user-side features
//! seen by async inference and by the pre-ranking model are identical.
//! Transport between phases is Base64-encoded (paper §5.3) and the decoded
//! tensors land in pooled arena buffers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::runtime::Tensor;

/// Everything the online-async phase produced for one request.
#[derive(Debug, Clone)]
pub struct UserAsync {
    pub u_vec: Tensor,
    pub bea_v: Tensor,
    pub seq_emb: Tensor,
    /// Linearized DIN factors (model.user_tower): the O(b·L·d) pooling,
    /// hoisted into this async pass.
    pub din_base: Tensor,
    pub din_g: Tensor,
    /// Packed uint8 signatures of the long-term sequence (serving-engine
    /// SimTier path, §4.2).
    pub seq_sign_packed: std::sync::Arc<Vec<u8>>,
    /// Long-term sequence item ids (SIM assembly needs categories).
    pub long_seq: Vec<u32>,
}

impl UserAsync {
    pub fn size_bytes(&self) -> usize {
        self.u_vec.size_bytes()
            + self.bea_v.size_bytes()
            + self.seq_emb.size_bytes()
            + self.din_base.size_bytes()
            + self.din_g.size_bytes()
            + self.seq_sign_packed.len()
            + self.long_seq.len() * 4
    }
}

/// Request-scoped key: hash of (request id, user nickname).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RequestKey(pub u64);

impl RequestKey {
    /// FNV-1a over the request id and nickname — stable across processes,
    /// which is what makes consistent routing reproducible.
    pub fn new(request_id: u64, nickname: &str) -> RequestKey {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in request_id
            .to_le_bytes()
            .iter()
            .chain(nickname.as_bytes())
        {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        RequestKey(h)
    }
}

/// Sharded store of in-flight async results.
pub struct UserVecCache {
    shards: Vec<Mutex<HashMap<RequestKey, UserAsync>>>,
    pub puts: AtomicU64,
    pub takes: AtomicU64,
    pub misses: AtomicU64,
    pub peak_entries: AtomicU64,
    pub bytes_transferred: AtomicU64,
}

impl UserVecCache {
    pub fn new(n_shards: usize) -> Self {
        UserVecCache {
            shards: (0..n_shards.max(1))
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
            puts: AtomicU64::new(0),
            takes: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            peak_entries: AtomicU64::new(0),
            bytes_transferred: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: RequestKey) -> &Mutex<HashMap<RequestKey, UserAsync>> {
        &self.shards[(key.0 as usize) % self.shards.len()]
    }

    pub fn put(&self, key: RequestKey, value: UserAsync) {
        // Account the Base64 transport of the compact user vectors (the
        // big tensors stay node-local under consistent hashing; only u_vec
        // and bea_v travel with the pre-rank request, §5.3).
        let wire = crate::util::base64::encode_f32(value.u_vec.data()).len()
            + crate::util::base64::encode_f32(value.bea_v.data()).len();
        self.bytes_transferred
            .fetch_add(wire as u64, Ordering::Relaxed);
        let mut shard = self.shard(key).lock().unwrap();
        shard.insert(key, value);
        self.puts.fetch_add(1, Ordering::Relaxed);
        let total: usize = shard.len();
        self.peak_entries
            .fetch_max(total as u64, Ordering::Relaxed);
    }

    /// Remove-and-return (phase 2 consumes the entry exactly once).
    pub fn take(&self, key: RequestKey) -> Option<UserAsync> {
        let out = self.shard(key).lock().unwrap().remove(&key);
        if out.is_some() {
            self.takes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(v: f32) -> UserAsync {
        UserAsync {
            u_vec: Tensor::new(vec![1, 2], vec![v, v]),
            bea_v: Tensor::new(vec![1, 2], vec![v, v]),
            seq_emb: Tensor::new(vec![1, 2], vec![v, v]),
            din_base: Tensor::new(vec![1, 2], vec![v, v]),
            din_g: Tensor::new(vec![1, 2], vec![v, v]),
            seq_sign_packed: std::sync::Arc::new(vec![0xA5]),
            long_seq: vec![1, 2, 3],
        }
    }

    #[test]
    fn request_key_is_stable_and_distinct() {
        let a = RequestKey::new(1, "alice");
        let b = RequestKey::new(1, "alice");
        let c = RequestKey::new(2, "alice");
        let d = RequestKey::new(1, "bob");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn put_take_roundtrip_consumes() {
        let cache = UserVecCache::new(4);
        let k = RequestKey::new(7, "u7");
        cache.put(k, dummy(1.0));
        assert_eq!(cache.len(), 1);
        let got = cache.take(k).unwrap();
        assert_eq!(got.u_vec.data(), &[1.0, 1.0]);
        assert!(cache.take(k).is_none(), "second take misses");
        assert_eq!(cache.misses.load(Ordering::Relaxed), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn transport_bytes_accounted() {
        let cache = UserVecCache::new(1);
        cache.put(RequestKey::new(1, "x"), dummy(2.0));
        assert!(cache.bytes_transferred.load(Ordering::Relaxed) > 0);
    }
}
