//! User-side async-result caching (paper §3.4, "Online Asynchronous
//! Inference" engineering) — DESIGN.md §15.
//!
//! AIF's headline claim is that interaction-independent components are
//! "calculated just once".  The original phase-1 handoff keyed results by
//! (request id, nickname), so two back-to-back requests for the same user
//! re-ran the full user tower.  [`UserStateCache`] replaces that with a
//! **cross-request** store keyed by [`UserKey`] `(engine, user, epoch)`:
//!
//! * entries live in a [`ShardedLru`] with a TTL (staleness bound) and a
//!   byte budget (weighed by [`UserAsync::size_bytes`]);
//! * a **single-flight in-flight map** coalesces concurrent misses: N
//!   requests for a hot user join ONE `user_tower` RTP call, parking on a
//!   shared [`Flight`] result slot — the loser of the insert race never
//!   issues a duplicate call;
//! * `epoch` is bumped on scenario reload and on feature-store / nearline
//!   version changes (composed by `ServingCore::user_epoch`), so stale
//!   state is invalidated by KEY — old entries simply stop matching and
//!   age out via TTL/LRU.
//!
//! Cached tensors are [detached][UserAsync::detached] to owned storage on
//! insert: a long-lived cache entry must never pin an `ArenaPool` buffer.
//!
//! The pre-reuse request-scoped behavior ([`UserVecCache`]: phase 1 puts
//! under a hash of (request id, nickname), phase 2 takes exactly once,
//! Base64 transport accounting per §5.3) is preserved bit-for-bit behind
//! `user_reuse = false` — consistent hashing over that key pins both
//! phases to the same RTP worker either way.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::lru::ShardedLru;
use crate::runtime::Tensor;

/// Everything the online-async phase produced for one request.
#[derive(Debug, Clone)]
pub struct UserAsync {
    pub u_vec: Tensor,
    pub bea_v: Tensor,
    pub seq_emb: Tensor,
    /// Linearized DIN factors (model.user_tower): the O(b·L·d) pooling,
    /// hoisted into this async pass.
    pub din_base: Tensor,
    pub din_g: Tensor,
    /// Packed uint8 signatures of the long-term sequence (serving-engine
    /// SimTier path, §4.2).
    pub seq_sign_packed: std::sync::Arc<Vec<u8>>,
    /// Long-term sequence item ids (SIM assembly needs categories).
    pub long_seq: Vec<u32>,
}

impl UserAsync {
    pub fn size_bytes(&self) -> usize {
        self.u_vec.size_bytes()
            + self.bea_v.size_bytes()
            + self.seq_emb.size_bytes()
            + self.din_base.size_bytes()
            + self.din_g.size_bytes()
            + self.seq_sign_packed.len()
            + self.long_seq.len() * 4
    }

    /// Copy of `self` whose tensors own their storage: arena-backed
    /// tensors are deep-copied, owned ones share their `Arc`.  Cache
    /// inserts go through this so a long-lived entry can never pin a
    /// pooled buffer.
    pub fn detached(&self) -> UserAsync {
        UserAsync {
            u_vec: self.u_vec.detached(),
            bea_v: self.bea_v.detached(),
            seq_emb: self.seq_emb.detached(),
            din_base: self.din_base.detached(),
            din_g: self.din_g.detached(),
            seq_sign_packed: Arc::clone(&self.seq_sign_packed),
            long_seq: self.long_seq.clone(),
        }
    }

    /// Whether any tensor still rides arena storage (leak tests).
    pub fn is_pooled(&self) -> bool {
        self.u_vec.is_pooled()
            || self.bea_v.is_pooled()
            || self.seq_emb.is_pooled()
            || self.din_base.is_pooled()
            || self.din_g.is_pooled()
    }
}

/// Request-scoped key: hash of (request id, user nickname).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RequestKey(pub u64);

impl RequestKey {
    /// FNV-1a over the request id and nickname — stable across processes,
    /// which is what makes consistent routing reproducible.
    pub fn new(request_id: u64, nickname: &str) -> RequestKey {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in request_id
            .to_le_bytes()
            .iter()
            .chain(nickname.as_bytes())
        {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        RequestKey(h)
    }
}

/// Cross-request cache key.  `engine` salts per-scenario state (a reload
/// allocates a fresh engine id), `epoch` invalidates by version: entries
/// written under an older epoch never match and age out on their own.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct UserKey {
    pub engine: u64,
    pub user: u32,
    pub epoch: u64,
}

impl UserKey {
    pub fn new(engine: u64, user: u32, epoch: u64) -> UserKey {
        UserKey {
            engine,
            user,
            epoch,
        }
    }

    /// FNV-1a over the key fields — stable across processes, so
    /// consistent-hash worker routing stays reproducible (all requests
    /// for one (user, epoch) pin to one RTP worker, §3.4).
    pub fn hash64(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self
            .engine
            .to_le_bytes()
            .iter()
            .chain(self.user.to_le_bytes().iter())
            .chain(self.epoch.to_le_bytes().iter())
        {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// How one request obtained its user-side tensors (`ScoreTrace`
/// `user_side` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UserSide {
    /// Cache probe returned a live entry; phase 1 was skipped entirely.
    Hit,
    /// Cold (user, epoch): this request led the single-flight and paid
    /// the `user_tower` call.  Also every request under
    /// `user_reuse = false`.
    Miss,
    /// Another request's flight was already computing this (user, epoch);
    /// this request parked on its result slot instead of duplicating the
    /// call.
    Joined,
}

impl UserSide {
    pub fn as_str(self) -> &'static str {
        match self {
            UserSide::Hit => "hit",
            UserSide::Miss => "miss",
            UserSide::Joined => "joined",
        }
    }
}

/// What a flight resolves to: the (shared) async result plus the leader's
/// compute time, or the leader's error (stringly — `anyhow::Error` is not
/// `Clone`, and every waiter needs a copy).
pub type FlightResult = Result<(Arc<UserAsync>, Duration), String>;

/// Shared result slot of one in-flight `user_tower` computation.  The
/// leader publishes exactly once; any number of waiters park on `wait`.
pub struct Flight {
    slot: Mutex<Option<FlightResult>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn publish(&self, result: FlightResult) {
        let mut slot = self.slot.lock().unwrap();
        debug_assert!(slot.is_none(), "flight published twice");
        *slot = Some(result);
        self.cv.notify_all();
    }

    /// Block until the leader publishes; every waiter gets a clone.
    pub fn wait(&self) -> FlightResult {
        let mut slot = self.slot.lock().unwrap();
        while slot.is_none() {
            slot = self.cv.wait(slot).unwrap();
        }
        slot.as_ref().unwrap().clone()
    }
}

/// RAII completion guard for a single-flight leader.  The leader's task
/// calls [`FlightGuard::complete`] with its result; if the task unwinds
/// first (a panic anywhere in the compute path), `Drop` publishes an
/// error and retires the flight, so waiters FAIL instead of hanging
/// forever — the legacy channel path failed cleanly on panic (the
/// dropped `Sender` errored the `recv`), and so must this one.
pub struct FlightGuard {
    cache: Arc<UserStateCache>,
    key: UserKey,
    flight: Arc<Flight>,
    done: bool,
}

impl FlightGuard {
    pub fn new(
        cache: Arc<UserStateCache>,
        key: UserKey,
        flight: Arc<Flight>,
    ) -> FlightGuard {
        FlightGuard {
            cache,
            key,
            flight,
            done: false,
        }
    }

    /// Complete the flight with the leader's result (exactly once).
    pub fn complete(
        mut self,
        result: Result<(UserAsync, Duration), String>,
    ) {
        self.done = true;
        self.cache.complete(self.key, &self.flight, result);
    }
}

impl Drop for FlightGuard {
    fn drop(&mut self) {
        if !self.done {
            self.cache.complete(
                self.key,
                &self.flight,
                Err("user async task panicked before completing".into()),
            );
        }
    }
}

/// RAII SIM pre-warm slot: released on drop, so a panicking warmer task
/// re-opens the slot instead of disabling pre-warming for that user.
pub struct SimPrewarm {
    cache: Arc<UserStateCache>,
    budget_key: u32,
    user: u32,
}

impl Drop for SimPrewarm {
    fn drop(&mut self) {
        self.cache.end_sim_prewarm(self.budget_key, self.user);
    }
}

/// Outcome of [`UserStateCache::claim`].
pub enum Claim {
    /// Live cached entry — skip the async phase.
    Hit(Arc<UserAsync>),
    /// This request leads: compute and [`UserStateCache::complete`] the
    /// flight (exactly one claimant per (user, epoch) gets this).
    Lead(Arc<Flight>),
    /// Another request is computing — park on the flight at join time.
    Join(Arc<Flight>),
}

/// Counters behind the `/metrics` `user_cache` block.
#[derive(Debug, Default)]
pub struct UserCacheStats {
    pub hits: AtomicU64,
    /// Cold claims that led a flight (== `user_tower` computations).
    pub misses: AtomicU64,
    /// Claims that joined an existing flight instead of duplicating it.
    pub single_flight_joins: AtomicU64,
    pub inserts: AtomicU64,
    /// SIM pre-warm spawns skipped because one was already in flight.
    pub sim_prewarm_dedup: AtomicU64,
    /// §5.3 Base64 transport accounting (u_vec + bea_v per computation).
    pub bytes_transferred: AtomicU64,
}

enum Mode {
    Shared {
        lru: ShardedLru<UserKey, Arc<UserAsync>>,
        /// Single-flight map: key -> in-flight computation.  Sharded by
        /// `UserKey::hash64` like the LRU, so hot-key coordination never
        /// funnels through one mutex.
        inflight: Vec<Mutex<HashMap<UserKey, Arc<Flight>>>>,
        /// (budget key, user) pairs with a SIM pre-warm task in flight —
        /// concurrent requests for a hot user spawn ONE warmer.
        sim_inflight: Mutex<HashSet<(u32, u32)>>,
    },
    RequestScoped(UserVecCache),
}

/// The user-side state cache: shared cross-request mode (the default), or
/// the legacy request-scoped handoff (`user_reuse = false`).
pub struct UserStateCache {
    mode: Mode,
    /// Reload-driven half of the epoch (`ServingCore::user_epoch` adds
    /// the nearline and feature-store versions on top).
    epoch: AtomicU64,
    pub stats: UserCacheStats,
}

impl UserStateCache {
    /// Cross-request mode: `entries` total across `n_shards`, optional
    /// TTL, `max_bytes` byte budget (0 = unlimited).
    pub fn shared(
        entries: usize,
        ttl: Option<Duration>,
        max_bytes: usize,
        n_shards: usize,
    ) -> UserStateCache {
        let n_shards = n_shards.max(1);
        UserStateCache {
            mode: Mode::Shared {
                lru: ShardedLru::with_limits(
                    entries.max(n_shards),
                    n_shards,
                    ttl,
                    max_bytes,
                    Some(Box::new(|ua: &Arc<UserAsync>| ua.size_bytes())),
                ),
                inflight: (0..n_shards)
                    .map(|_| Mutex::new(HashMap::new()))
                    .collect(),
                sim_inflight: Mutex::new(HashSet::new()),
            },
            epoch: AtomicU64::new(0),
            stats: UserCacheStats::default(),
        }
    }

    /// Legacy request-scoped mode (`--user-reuse false`): today's
    /// two-phase put/take handoff, bit-for-bit.
    pub fn request_scoped(n_shards: usize) -> UserStateCache {
        UserStateCache {
            mode: Mode::RequestScoped(UserVecCache::new(n_shards)),
            epoch: AtomicU64::new(0),
            stats: UserCacheStats::default(),
        }
    }

    pub fn is_shared(&self) -> bool {
        matches!(self.mode, Mode::Shared { .. })
    }

    /// Reload-driven epoch component.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Invalidate every live entry by moving the key space forward.
    pub fn bump_epoch(&self) -> u64 {
        self.epoch.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Warm-boot path: resume the epoch sequence at least at `e` (the
    /// reload component recorded in the restored snapshot manifest).
    /// Monotone — never moves the epoch backwards, since a rewind would
    /// resurrect keys already handed out.
    pub fn restore_epoch(&self, e: u64) {
        self.epoch.fetch_max(e, Ordering::Relaxed);
    }

    fn shared_parts(
        &self,
    ) -> (
        &ShardedLru<UserKey, Arc<UserAsync>>,
        &[Mutex<HashMap<UserKey, Arc<Flight>>>],
    ) {
        match &self.mode {
            Mode::Shared { lru, inflight, .. } => {
                (lru, inflight.as_slice())
            }
            Mode::RequestScoped(_) => {
                unreachable!("single-flight API on a request-scoped cache")
            }
        }
    }

    /// Probe the cache and, on miss, race for the flight: exactly one
    /// claimant per (user, epoch) gets [`Claim::Lead`]; everyone else
    /// hits or joins.  Shared mode only.
    pub fn claim(&self, key: UserKey) -> Claim {
        let (lru, inflight) = self.shared_parts();
        if let Some(ua) = lru.get(&key) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return Claim::Hit(ua);
        }
        let shard =
            &inflight[(key.hash64() as usize) % inflight.len()];
        let mut map = shard.lock().unwrap();
        // Double-check under the shard lock: a leader completing between
        // the probe above and this lock inserts into the LRU BEFORE
        // removing its flight, so one of these two re-checks must see it.
        if let Some(ua) = lru.get(&key) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return Claim::Hit(ua);
        }
        if let Some(flight) = map.get(&key) {
            self.stats
                .single_flight_joins
                .fetch_add(1, Ordering::Relaxed);
            return Claim::Join(Arc::clone(flight));
        }
        let flight = Arc::new(Flight::new());
        map.insert(key, Arc::clone(&flight));
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        Claim::Lead(flight)
    }

    /// Leader completion: detach + insert on success, publish to every
    /// waiter, retire the flight.  Errors are published but NOT cached —
    /// the next claimant retries.
    pub fn complete(
        &self,
        key: UserKey,
        flight: &Flight,
        result: Result<(UserAsync, Duration), String>,
    ) {
        let (lru, inflight) = self.shared_parts();
        let published: FlightResult = match result {
            Ok((ua, elapsed)) => {
                // Account the Base64 transport of the compact user
                // vectors once per computation (§5.3) — hits are served
                // node-local under consistent hashing and move nothing.
                let wire =
                    crate::util::base64::encoded_len_f32(ua.u_vec.len())
                        + crate::util::base64::encoded_len_f32(
                            ua.bea_v.len(),
                        );
                self.stats
                    .bytes_transferred
                    .fetch_add(wire as u64, Ordering::Relaxed);
                // Detach: the cache outlives the request; it must not
                // pin arena-pooled RTP buffers.
                let ua = Arc::new(ua.detached());
                lru.insert(key, Arc::clone(&ua));
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
                Ok((ua, elapsed))
            }
            Err(e) => Err(e),
        };
        // Retire AFTER the LRU insert (a claimant that misses the flight
        // is guaranteed to find the entry — see the claim double-check)
        // but BEFORE publishing: the moment any waiter unparks, the
        // in-flight map is already quiescent, so `inflight_len() == 0`
        // holds deterministically once every request has returned.
        inflight[(key.hash64() as usize) % inflight.len()]
            .lock()
            .unwrap()
            .remove(&key);
        flight.publish(published);
    }

    /// Try to become the one SIM pre-warmer for (budget, user).  `false`
    /// means another request's warmer is already in flight — skip the
    /// spawn (the cache will be warm either way).
    pub fn begin_sim_prewarm(&self, budget_key: u32, user: u32) -> bool {
        match &self.mode {
            Mode::Shared { sim_inflight, .. } => {
                let fresh = sim_inflight
                    .lock()
                    .unwrap()
                    .insert((budget_key, user));
                if !fresh {
                    self.stats
                        .sim_prewarm_dedup
                        .fetch_add(1, Ordering::Relaxed);
                }
                fresh
            }
            Mode::RequestScoped(_) => true,
        }
    }

    /// Pre-warm task finished (success or not): allow the next spawn.
    pub fn end_sim_prewarm(&self, budget_key: u32, user: u32) {
        if let Mode::Shared { sim_inflight, .. } = &self.mode {
            sim_inflight.lock().unwrap().remove(&(budget_key, user));
        }
    }

    /// [`Self::begin_sim_prewarm`] as an RAII slot: `None` when another
    /// request's warmer is already in flight; dropping the slot (normal
    /// completion OR an unwinding warmer) releases it.
    pub fn sim_prewarm(
        self: &Arc<Self>,
        budget_key: u32,
        user: u32,
    ) -> Option<SimPrewarm> {
        self.begin_sim_prewarm(budget_key, user).then(|| SimPrewarm {
            cache: Arc::clone(self),
            budget_key,
            user,
        })
    }

    // ---- legacy request-scoped handoff (user_reuse = false) ------------

    pub fn put(&self, key: RequestKey, value: UserAsync) {
        match &self.mode {
            Mode::RequestScoped(c) => c.put(key, value),
            Mode::Shared { .. } => {
                unreachable!("request-scoped put on the shared user cache")
            }
        }
    }

    pub fn take(&self, key: RequestKey) -> Option<UserAsync> {
        match &self.mode {
            Mode::RequestScoped(c) => c.take(key),
            Mode::Shared { .. } => {
                unreachable!("request-scoped take on the shared user cache")
            }
        }
    }

    // ---- introspection --------------------------------------------------

    /// Live cached entries (shared) / parked request results (legacy).
    pub fn entries(&self) -> usize {
        match &self.mode {
            Mode::Shared { lru, .. } => lru.len(),
            Mode::RequestScoped(c) => c.len(),
        }
    }

    /// Flights currently computing.  0 when the system is quiescent —
    /// the leak check the request-scoped `is_empty` used to provide.
    pub fn inflight_len(&self) -> usize {
        match &self.mode {
            Mode::Shared { inflight, .. } => {
                inflight.iter().map(|s| s.lock().unwrap().len()).sum()
            }
            Mode::RequestScoped(c) => c.len(),
        }
    }

    /// Resident bytes of the cached user-side tensors.
    pub fn resident_bytes(&self) -> usize {
        match &self.mode {
            Mode::Shared { lru, .. } => lru.resident_bytes(),
            Mode::RequestScoped(_) => 0,
        }
    }

    /// JSON block for `/metrics` (`composed_epoch` is the full epoch the
    /// serving keys carry: reload bumps + substrate versions).
    pub fn stats_snapshot(
        &self,
        composed_epoch: u64,
    ) -> crate::util::json::Value {
        let mut o = crate::util::json::Object::new();
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        match &self.mode {
            Mode::Shared { lru, .. } => {
                o.insert("mode", "shared");
                o.insert("hits", ld(&self.stats.hits));
                o.insert("misses", ld(&self.stats.misses));
                o.insert(
                    "single_flight_joins",
                    ld(&self.stats.single_flight_joins),
                );
                o.insert("inserts", ld(&self.stats.inserts));
                o.insert(
                    "evictions",
                    ld(&lru.stats.evictions),
                );
                o.insert("expired", ld(&lru.stats.expired));
                o.insert("entries", self.entries());
                o.insert("resident_bytes", self.resident_bytes());
                o.insert("inflight", self.inflight_len());
                o.insert(
                    "sim_prewarm_dedup",
                    ld(&self.stats.sim_prewarm_dedup),
                );
                o.insert("epoch", composed_epoch);
            }
            Mode::RequestScoped(c) => {
                o.insert("mode", "request_scoped");
                o.insert("puts", ld(&c.puts));
                o.insert("takes", ld(&c.takes));
                o.insert("misses", ld(&c.misses));
                o.insert("entries", c.len());
                o.insert("epoch", composed_epoch);
            }
        }
        let wire = match &self.mode {
            Mode::Shared { .. } => ld(&self.stats.bytes_transferred),
            Mode::RequestScoped(c) => ld(&c.bytes_transferred),
        };
        o.insert("bytes_transferred", wire);
        crate::util::json::Value::Obj(o)
    }
}

/// Sharded store of in-flight async results — the legacy request-scoped
/// engine behind [`UserStateCache::request_scoped`] (phase 1 puts, phase 2
/// takes exactly once).
pub struct UserVecCache {
    shards: Vec<Mutex<HashMap<RequestKey, UserAsync>>>,
    pub puts: AtomicU64,
    pub takes: AtomicU64,
    pub misses: AtomicU64,
    pub peak_entries: AtomicU64,
    pub bytes_transferred: AtomicU64,
}

impl UserVecCache {
    pub fn new(n_shards: usize) -> Self {
        UserVecCache {
            shards: (0..n_shards.max(1))
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
            puts: AtomicU64::new(0),
            takes: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            peak_entries: AtomicU64::new(0),
            bytes_transferred: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: RequestKey) -> &Mutex<HashMap<RequestKey, UserAsync>> {
        &self.shards[(key.0 as usize) % self.shards.len()]
    }

    pub fn put(&self, key: RequestKey, value: UserAsync) {
        // Account the Base64 transport of the compact user vectors (the
        // big tensors stay node-local under consistent hashing; only u_vec
        // and bea_v travel with the pre-rank request, §5.3).  Closed-form
        // length: same counter value, no throwaway encode.
        let wire = crate::util::base64::encoded_len_f32(value.u_vec.len())
            + crate::util::base64::encoded_len_f32(value.bea_v.len());
        self.bytes_transferred
            .fetch_add(wire as u64, Ordering::Relaxed);
        let mut shard = self.shard(key).lock().unwrap();
        shard.insert(key, value);
        self.puts.fetch_add(1, Ordering::Relaxed);
        let total: usize = shard.len();
        self.peak_entries
            .fetch_max(total as u64, Ordering::Relaxed);
    }

    /// Remove-and-return (phase 2 consumes the entry exactly once).
    pub fn take(&self, key: RequestKey) -> Option<UserAsync> {
        let out = self.shard(key).lock().unwrap().remove(&key);
        if out.is_some() {
            self.takes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(v: f32) -> UserAsync {
        UserAsync {
            u_vec: Tensor::new(vec![1, 2], vec![v, v]),
            bea_v: Tensor::new(vec![1, 2], vec![v, v]),
            seq_emb: Tensor::new(vec![1, 2], vec![v, v]),
            din_base: Tensor::new(vec![1, 2], vec![v, v]),
            din_g: Tensor::new(vec![1, 2], vec![v, v]),
            seq_sign_packed: std::sync::Arc::new(vec![0xA5]),
            long_seq: vec![1, 2, 3],
        }
    }

    #[test]
    fn request_key_is_stable_and_distinct() {
        let a = RequestKey::new(1, "alice");
        let b = RequestKey::new(1, "alice");
        let c = RequestKey::new(2, "alice");
        let d = RequestKey::new(1, "bob");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn user_key_hash_is_stable_and_distinct() {
        let a = UserKey::new(1, 7, 0);
        assert_eq!(a.hash64(), UserKey::new(1, 7, 0).hash64());
        assert_ne!(a.hash64(), UserKey::new(2, 7, 0).hash64());
        assert_ne!(a.hash64(), UserKey::new(1, 8, 0).hash64());
        assert_ne!(a.hash64(), UserKey::new(1, 7, 1).hash64());
    }

    #[test]
    fn put_take_roundtrip_consumes() {
        let cache = UserVecCache::new(4);
        let k = RequestKey::new(7, "u7");
        cache.put(k, dummy(1.0));
        assert_eq!(cache.len(), 1);
        let got = cache.take(k).unwrap();
        assert_eq!(got.u_vec.data(), &[1.0, 1.0]);
        assert!(cache.take(k).is_none(), "second take misses");
        assert_eq!(cache.misses.load(Ordering::Relaxed), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn transport_bytes_accounted() {
        let cache = UserVecCache::new(1);
        cache.put(RequestKey::new(1, "x"), dummy(2.0));
        assert!(cache.bytes_transferred.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn claim_hit_after_complete() {
        let cache = UserStateCache::shared(64, None, 0, 4);
        let key = UserKey::new(0, 5, 0);
        let Claim::Lead(flight) = cache.claim(key) else {
            panic!("first claim must lead");
        };
        cache.complete(
            key,
            &flight,
            Ok((dummy(3.0), Duration::from_millis(1))),
        );
        let (ua, _) = flight.wait().unwrap();
        assert_eq!(ua.u_vec.data(), &[3.0, 3.0]);
        match cache.claim(key) {
            Claim::Hit(ua) => assert_eq!(ua.u_vec.data(), &[3.0, 3.0]),
            _ => panic!("completed key must hit"),
        }
        assert_eq!(cache.inflight_len(), 0, "flight retired");
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        assert!(cache.resident_bytes() > 0);
    }

    #[test]
    fn concurrent_claims_share_one_flight() {
        let cache = Arc::new(UserStateCache::shared(64, None, 0, 4));
        let key = UserKey::new(1, 9, 0);
        let Claim::Lead(flight) = cache.claim(key) else {
            panic!("first claim must lead");
        };
        // While the leader is "computing", every other claim joins.
        let mut waiters = Vec::new();
        for _ in 0..6 {
            let cache = Arc::clone(&cache);
            waiters.push(std::thread::spawn(move || {
                match cache.claim(key) {
                    Claim::Lead(_) => panic!("duplicate leader"),
                    Claim::Hit(ua) => ua.u_vec.data()[0],
                    Claim::Join(f) => {
                        f.wait().unwrap().0.u_vec.data()[0]
                    }
                }
            }));
        }
        // Give the waiters time to park, then publish.
        std::thread::sleep(Duration::from_millis(20));
        cache.complete(
            key,
            &flight,
            Ok((dummy(4.0), Duration::from_millis(1))),
        );
        for w in waiters {
            assert_eq!(w.join().unwrap(), 4.0);
        }
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        assert_eq!(cache.inflight_len(), 0);
    }

    #[test]
    fn unwound_leader_fails_waiters_instead_of_hanging() {
        let cache = Arc::new(UserStateCache::shared(64, None, 0, 4));
        let key = UserKey::new(0, 4, 0);
        let Claim::Lead(flight) = cache.claim(key) else {
            panic!("lead");
        };
        let guard =
            FlightGuard::new(Arc::clone(&cache), key, Arc::clone(&flight));
        let waiter = {
            let flight = Arc::clone(&flight);
            std::thread::spawn(move || flight.wait())
        };
        // The leader's task panics before completing: the guard must
        // publish an error and retire the flight.
        let leader = std::thread::spawn(move || {
            let _guard = guard;
            panic!("compute exploded");
        });
        assert!(leader.join().is_err());
        assert!(
            waiter.join().unwrap().is_err(),
            "waiters must fail, not hang"
        );
        assert_eq!(cache.inflight_len(), 0, "flight retired by the guard");
        assert!(
            matches!(cache.claim(key), Claim::Lead(_)),
            "next claimant retries as a fresh leader"
        );
    }

    #[test]
    fn errors_propagate_but_are_not_cached() {
        let cache = UserStateCache::shared(64, None, 0, 4);
        let key = UserKey::new(0, 2, 0);
        let Claim::Lead(flight) = cache.claim(key) else {
            panic!("lead");
        };
        cache.complete(key, &flight, Err("tower down".into()));
        assert!(flight.wait().is_err());
        assert_eq!(cache.entries(), 0, "errors must not be cached");
        assert!(
            matches!(cache.claim(key), Claim::Lead(_)),
            "next claimant retries as a fresh leader"
        );
    }

    #[test]
    fn epoch_changes_the_key_space() {
        let cache = UserStateCache::shared(64, None, 0, 4);
        let k0 = UserKey::new(0, 3, cache.epoch());
        let Claim::Lead(f) = cache.claim(k0) else { panic!() };
        cache.complete(k0, &f, Ok((dummy(1.0), Duration::ZERO)));
        assert!(matches!(cache.claim(k0), Claim::Hit(_)));
        let e = cache.bump_epoch();
        let k1 = UserKey::new(0, 3, e);
        assert!(
            matches!(cache.claim(k1), Claim::Lead(_)),
            "bumped epoch must miss (old state invalidated by key)"
        );
    }

    #[test]
    fn sim_prewarm_single_flight() {
        let cache = UserStateCache::shared(64, None, 0, 4);
        assert!(cache.begin_sim_prewarm(7, 1));
        assert!(!cache.begin_sim_prewarm(7, 1), "duplicate deduped");
        assert!(cache.begin_sim_prewarm(7, 2), "other user unaffected");
        cache.end_sim_prewarm(7, 1);
        assert!(cache.begin_sim_prewarm(7, 1), "slot reopens after end");
        assert_eq!(
            cache.stats.sim_prewarm_dedup.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn sim_prewarm_slot_releases_on_drop_and_unwind() {
        let cache = Arc::new(UserStateCache::shared(64, None, 0, 4));
        let slot = cache.sim_prewarm(3, 8).expect("first slot");
        assert!(cache.sim_prewarm(3, 8).is_none(), "in flight: deduped");
        drop(slot);
        let slot = cache.sim_prewarm(3, 8).expect("slot reopened");
        // A panicking warmer must release the slot too.
        let t = std::thread::spawn(move || {
            let _slot = slot;
            panic!("warmer exploded");
        });
        assert!(t.join().is_err());
        assert!(
            cache.sim_prewarm(3, 8).is_some(),
            "slot must reopen after an unwound warmer"
        );
    }
}
