//! Arena memory pool for the pre-rank hot path (paper §3.4: "AIF adopts
//! an Arena memory pool for the high-frequency updates and caching of
//! user-side features ... enhancing the efficiency of feature access and
//! processing").
//!
//! Size-classed free lists of `Vec<f32>` buffers: `get(len)` hands out a
//! zero-length buffer with capacity ≥ len from the smallest fitting class;
//! dropping the [`PooledBuf`] returns it.  The pre-rank hot loop assembles
//! mini-batch tensors into pooled buffers instead of fresh allocations
//! (`Tensor::from_pooled`), and the buffer rides the tensor back to the
//! pool when the RTP call retires.
//!
//! Two tiers keep the pool mutex out of the hot loop (DESIGN.md §14):
//!
//! * a **thread-local cache** of up to [`TL_RETAIN`] buffers per class on
//!   GETTER threads — a same-thread get/put cycle touches no lock at all;
//! * [`N_SHARDS`] **sharded global free lists** behind the thread-local
//!   tier.  A buffer remembers its getter's home shard (by
//!   `util::tls::thread_tag`): when a consumer-only thread drops it (an
//!   RTP worker retiring operands), it returns to that ORIGIN shard, so
//!   the producing thread's next get hits its own shard on the first
//!   probe; stealing across shards is the cold path.
//!
//! Edge cases never alias the size classes: `len == 0` and requests above
//! the top class return an **exact-capacity untracked** buffer that is
//! really freed on drop (accounted in `untracked`, invisible to
//! `outstanding()`).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::tls;

/// Power-of-two size classes from 256 floats up to 16M floats.
const MIN_CLASS_LOG2: u32 = 8;
const N_CLASSES: usize = 17;
/// Global free-list shards behind the thread-local tier.
const N_SHARDS: usize = 8;
/// Buffers per class a thread parks privately before spilling to a shard.
const TL_RETAIN: usize = 4;
/// Distinct pools one thread caches for; the oldest is evicted (dropped).
const TL_POOLS: usize = 4;
/// Class tag of exact-capacity escape-hatch buffers the pool never
/// retains (len == 0 or above the top size class).
const UNTRACKED: usize = usize::MAX;

static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread buffer caches, keyed by pool id (a thread may serve
    /// several pools: tests, multi-Merger processes).
    static TL_CACHE: RefCell<Vec<TlPool>> = const { RefCell::new(Vec::new()) };
}

struct TlPool {
    pool_id: u64,
    classes: Vec<Vec<Vec<f32>>>,
}

struct Shard {
    classes: Vec<Mutex<Vec<Vec<f32>>>>,
}

pub struct ArenaPool {
    /// Process-unique id keying the thread-local caches.
    id: u64,
    shards: Vec<Shard>,
    /// Max buffers retained per (shard, class) beyond the thread-local
    /// tier; spills past this really free ([`Self::trimmed`]).
    retain_per_class: usize,
    /// Fresh heap allocations (pool misses).
    pub allocs: AtomicU64,
    /// Gets served from a free list (thread-local or shard).
    pub reuses: AtomicU64,
    /// Tracked buffers handed back (retained or trimmed).
    pub returns: AtomicU64,
    /// Tracked buffers detached for good via [`PooledBuf::take`].
    pub detached: AtomicU64,
    /// Returns dropped because the shard class sat at `retain_per_class`.
    pub trimmed: AtomicU64,
    /// Exact-capacity escape-hatch buffers (len 0 / above the top class).
    pub untracked: AtomicU64,
    /// Gets served lock-free from the thread-local tier.
    pub tl_hits: AtomicU64,
}

impl ArenaPool {
    pub fn new(retain_per_class: usize) -> Arc<Self> {
        Arc::new(ArenaPool {
            id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            shards: (0..N_SHARDS)
                .map(|_| Shard {
                    classes: (0..N_CLASSES)
                        .map(|_| Mutex::new(Vec::new()))
                        .collect(),
                })
                .collect(),
            retain_per_class,
            allocs: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            returns: AtomicU64::new(0),
            detached: AtomicU64::new(0),
            trimmed: AtomicU64::new(0),
            untracked: AtomicU64::new(0),
            tl_hits: AtomicU64::new(0),
        })
    }

    fn class_of(len: usize) -> usize {
        debug_assert!(len > 0 && len <= Self::class_capacity(N_CLASSES - 1));
        let bits = usize::BITS - (len - 1).leading_zeros();
        bits.saturating_sub(MIN_CLASS_LOG2) as usize
    }

    fn class_capacity(class: usize) -> usize {
        1usize << (class as u32 + MIN_CLASS_LOG2)
    }

    /// Take a buffer with capacity >= len; contents are cleared.  `len`s
    /// of 0 or above the top size class get an exact-capacity buffer the
    /// pool does not track (really freed on drop).
    pub fn get(self: &Arc<Self>, len: usize) -> PooledBuf {
        if len == 0 || len > Self::class_capacity(N_CLASSES - 1) {
            self.untracked.fetch_add(1, Ordering::Relaxed);
            return PooledBuf {
                buf: Vec::with_capacity(len),
                pool: Arc::clone(self),
                class: UNTRACKED,
                home: 0,
            };
        }
        let class = Self::class_of(len);
        let home = tls::thread_shard(N_SHARDS);
        // Thread-local fast path; getting also MARKS this thread as a
        // getter (creates its cache entry), so returns later made by
        // consumer-only threads (RTP workers dropping operands) don't
        // strand buffers in a cache no get() ever drains — they spill to
        // the buffer's origin shard instead (see `put_back`).
        let tl = TL_CACHE.with(|c| {
            let mut caches = c.borrow_mut();
            let slot = match caches
                .iter()
                .position(|p| p.pool_id == self.id)
            {
                Some(i) => i,
                None => {
                    if caches.len() >= TL_POOLS {
                        caches.remove(0); // evicted pool's buffers drop
                    }
                    caches.push(TlPool {
                        pool_id: self.id,
                        classes: (0..N_CLASSES)
                            .map(|_| Vec::new())
                            .collect(),
                    });
                    caches.len() - 1
                }
            };
            caches[slot].classes[class].pop()
        });
        let mut buf = match tl {
            Some(b) => {
                self.tl_hits.fetch_add(1, Ordering::Relaxed);
                self.reuses.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => match self.shard_pop(class) {
                Some(b) => {
                    self.reuses.fetch_add(1, Ordering::Relaxed);
                    b
                }
                None => {
                    self.allocs.fetch_add(1, Ordering::Relaxed);
                    Vec::with_capacity(Self::class_capacity(class))
                }
            },
        };
        buf.clear();
        PooledBuf {
            buf,
            pool: Arc::clone(self),
            class,
            home,
        }
    }

    /// Take a zero-filled buffer of exactly `len`.
    pub fn get_zeroed(self: &Arc<Self>, len: usize) -> PooledBuf {
        let mut b = self.get(len);
        b.buf.resize(len, 0.0);
        b
    }

    /// Home shard first, then steal — stealing is the cold path that
    /// rebalances producer/consumer thread asymmetries.
    fn shard_pop(&self, class: usize) -> Option<Vec<f32>> {
        let home = tls::thread_shard(N_SHARDS);
        for i in 0..N_SHARDS {
            let shard = &self.shards[(home + i) % N_SHARDS];
            if let Some(b) = shard.classes[class].lock().unwrap().pop() {
                return Some(b);
            }
        }
        None
    }

    /// Hand a buffer back.  The thread-local tier takes it ONLY on
    /// threads that also call `get` on this pool (their cache entry
    /// exists); consumer-only threads — RTP workers dropping retired
    /// operands — spill straight to the buffer's ORIGIN shard (`home`,
    /// the getter thread's shard), so the next get on the producing
    /// thread finds it on the first shard probe.
    fn put_back(&self, mut buf: Vec<f32>, class: usize, home: usize) {
        debug_assert_ne!(class, UNTRACKED);
        self.returns.fetch_add(1, Ordering::Relaxed);
        buf.clear();
        let spilled = TL_CACHE.with(|c| {
            let mut caches = c.borrow_mut();
            match caches.iter_mut().find(|p| p.pool_id == self.id) {
                Some(p) if p.classes[class].len() < TL_RETAIN => {
                    p.classes[class].push(buf);
                    None
                }
                _ => Some(buf),
            }
        });
        if let Some(buf) = spilled {
            let mut free =
                self.shards[home].classes[class].lock().unwrap();
            if free.len() < self.retain_per_class {
                free.push(buf);
            } else {
                self.trimmed.fetch_add(1, Ordering::Relaxed);
                // drop really frees
            }
        }
    }

    /// Tracked buffers currently out (taken, neither returned nor
    /// detached).  The leak detector of the accounting tests: after every
    /// response of a request is dropped this must read 0.  Loads are
    /// relaxed and not a consistent set, so a live read (`/metrics`)
    /// racing a get/return cycle could observe returns ahead of takes —
    /// read the give-back counters FIRST and saturate so a transient
    /// race reads 0, never a wrapped u64.
    pub fn outstanding(&self) -> u64 {
        let given_back = self.returns.load(Ordering::Relaxed)
            + self.detached.load(Ordering::Relaxed);
        let taken = self.allocs.load(Ordering::Relaxed)
            + self.reuses.load(Ordering::Relaxed);
        taken.saturating_sub(given_back)
    }

    pub fn reuse_ratio(&self) -> f64 {
        let a = self.allocs.load(Ordering::Relaxed) as f64;
        let r = self.reuses.load(Ordering::Relaxed) as f64;
        if a + r == 0.0 {
            0.0
        } else {
            r / (a + r)
        }
    }

    /// Bytes currently parked in the sharded free lists (§5.3 storage
    /// accounting).  Thread-local caches are not visible cross-thread and
    /// are bounded (`TL_RETAIN` buffers/class/thread), so they are not
    /// counted.
    pub fn pooled_bytes(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.classes.iter())
            .map(|c| {
                c.lock()
                    .unwrap()
                    .iter()
                    .map(|b| b.capacity() * 4)
                    .sum::<usize>()
            })
            .sum()
    }

    /// JSON-ready counter snapshot (`/metrics` arena block).
    pub fn stats_snapshot(&self) -> crate::util::json::Value {
        let mut o = crate::util::json::Object::new();
        o.insert("allocs", self.allocs.load(Ordering::Relaxed));
        o.insert("reuses", self.reuses.load(Ordering::Relaxed));
        o.insert("returns", self.returns.load(Ordering::Relaxed));
        o.insert("trimmed", self.trimmed.load(Ordering::Relaxed));
        o.insert("untracked", self.untracked.load(Ordering::Relaxed));
        o.insert("tl_hits", self.tl_hits.load(Ordering::Relaxed));
        o.insert("outstanding", self.outstanding());
        o.insert("reuse_ratio", self.reuse_ratio());
        o.insert("pooled_bytes", self.pooled_bytes());
        crate::util::json::Value::Obj(o)
    }
}

/// RAII pooled buffer; derefs to `Vec<f32>`.
pub struct PooledBuf {
    buf: Vec<f32>,
    pool: Arc<ArenaPool>,
    class: usize,
    /// Shard of the getter thread — where a cross-thread drop returns it.
    home: usize,
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("len", &self.buf.len())
            .field("capacity", &self.buf.capacity())
            .field("tracked", &(self.class != UNTRACKED))
            .finish()
    }
}

impl std::ops::Deref for PooledBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.buf
    }
}

impl AsRef<[f32]> for PooledBuf {
    fn as_ref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }
}

impl PooledBuf {
    /// Move the contents out for good; the allocation never returns to
    /// the pool (accounted in `detached`, not a leak).
    pub fn take(mut self) -> Vec<f32> {
        if self.class != UNTRACKED {
            self.pool.detached.fetch_add(1, Ordering::Relaxed);
            self.class = UNTRACKED; // Drop skips put_back
        }
        std::mem::take(&mut self.buf)
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if self.class == UNTRACKED {
            return; // exact-capacity escape hatch / detached: really free
        }
        self.pool.put_back(
            std::mem::take(&mut self.buf),
            self.class,
            self.home,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_of_is_monotone() {
        assert_eq!(ArenaPool::class_of(1), 0);
        assert_eq!(ArenaPool::class_of(256), 0);
        assert_eq!(ArenaPool::class_of(257), 1);
        assert_eq!(ArenaPool::class_of(512), 1);
        assert_eq!(ArenaPool::class_of(1 << 24), N_CLASSES - 1);
    }

    #[test]
    fn buffers_are_reused() {
        let pool = ArenaPool::new(8);
        let ptr1 = {
            let mut b = pool.get(1000);
            b.push(1.0);
            b.as_ptr() as usize
        }; // returned to the thread-local tier
        let b2 = pool.get(900); // same class
        assert_eq!(b2.as_ptr() as usize, ptr1, "buffer reused");
        assert!(b2.is_empty(), "reused buffer is cleared");
        assert_eq!(pool.reuses.load(Ordering::Relaxed), 1);
        assert_eq!(pool.tl_hits.load(Ordering::Relaxed), 1, "lock-free hit");
    }

    #[test]
    fn zeroed_has_exact_len() {
        let pool = ArenaPool::new(4);
        let b = pool.get_zeroed(300);
        assert_eq!(b.len(), 300);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zero_len_and_oversize_get_exact_untracked_buffers() {
        let pool = ArenaPool::new(4);
        let b = pool.get(0);
        assert_eq!(b.capacity(), 0, "len 0 never lands in a class");
        drop(b);
        let over = (1 << 24) + 1;
        let b = pool.get(over);
        assert_eq!(
            b.capacity(),
            over,
            "above the top class: exact capacity, no class rounding"
        );
        drop(b);
        assert_eq!(pool.untracked.load(Ordering::Relaxed), 2);
        // Untracked buffers neither count as taken nor as returned.
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.allocs.load(Ordering::Relaxed), 0);
        assert_eq!(pool.returns.load(Ordering::Relaxed), 0);
        assert_eq!(pool.pooled_bytes(), 0, "escape hatch is never parked");
    }

    #[test]
    fn retain_limit_trims_shard_spills() {
        let pool = ArenaPool::new(2);
        // TL_RETAIN park thread-locally; the rest spill to the home
        // shard, which retains retain_per_class and trims the overflow.
        let n = TL_RETAIN + 5;
        let bufs: Vec<_> = (0..n).map(|_| pool.get(1000)).collect();
        drop(bufs);
        assert_eq!(pool.returns.load(Ordering::Relaxed), n as u64);
        assert_eq!(pool.trimmed.load(Ordering::Relaxed), 3, "5 spills - 2 kept");
        let parked = pool.pooled_bytes();
        assert!(parked <= 2 * 1024 * 4, "parked {parked}");
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn take_detaches_contents_without_leak_accounting() {
        let pool = ArenaPool::new(4);
        let mut b = pool.get(10);
        b.extend_from_slice(&[1.0, 2.0]);
        let v = b.take();
        assert_eq!(v, vec![1.0, 2.0]);
        assert_eq!(pool.detached.load(Ordering::Relaxed), 1);
        assert_eq!(pool.outstanding(), 0, "take is not a leak");
    }

    #[test]
    fn cross_thread_returns_balance_the_books() {
        // Buffers allocated here, dropped on another thread (the RTP-
        // retire pattern): outstanding settles to 0, and because the
        // dropping thread never get()s, EVERY buffer lands back in the
        // getter's origin shard — nothing strands in a consumer-only
        // thread-local cache.
        let pool = ArenaPool::new(8);
        let n = TL_RETAIN + 2;
        let bufs: Vec<_> =
            (0..n).map(|_| pool.get_zeroed(2000)).collect();
        std::thread::spawn(move || drop(bufs)).join().unwrap();
        assert_eq!(pool.outstanding(), 0);
        let parked = pool.pooled_bytes();
        assert!(
            parked >= n * 2048 * 4,
            "all {n} cross-thread returns reach the origin shard \
             (parked {parked})"
        );
        let before = pool.reuses.load(Ordering::Relaxed);
        let _b = pool.get(2000);
        assert_eq!(pool.reuses.load(Ordering::Relaxed), before + 1);
        assert_eq!(pool.outstanding(), 1);
    }

    #[test]
    fn outstanding_tracks_live_buffers() {
        let pool = ArenaPool::new(4);
        let a = pool.get(300);
        let b = pool.get(5000);
        assert_eq!(pool.outstanding(), 2);
        drop(a);
        assert_eq!(pool.outstanding(), 1);
        drop(b);
        assert_eq!(pool.outstanding(), 0);
    }
}
