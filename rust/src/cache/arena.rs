//! Arena memory pool for high-frequency user-vector caching (paper §3.4:
//! "AIF adopts an Arena memory pool for the high-frequency updates and
//! caching of user-side features ... enhancing the efficiency of feature
//! access and processing").
//!
//! Size-classed free lists of `Vec<f32>` buffers: `get(len)` hands out a
//! zero-length buffer with capacity ≥ len from the smallest fitting class;
//! dropping the [`PooledBuf`] returns it.  The pre-rank hot loop assembles
//! mini-batch tensors into pooled buffers instead of fresh allocations.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Power-of-two size classes from 256 floats up to 16M floats.
const MIN_CLASS_LOG2: u32 = 8;
const N_CLASSES: usize = 17;

pub struct ArenaPool {
    classes: Vec<Mutex<Vec<Vec<f32>>>>,
    /// Max buffers retained per class (beyond this, drops really free).
    retain_per_class: usize,
    pub allocs: AtomicU64,
    pub reuses: AtomicU64,
}

impl ArenaPool {
    pub fn new(retain_per_class: usize) -> Arc<Self> {
        Arc::new(ArenaPool {
            classes: (0..N_CLASSES).map(|_| Mutex::new(Vec::new())).collect(),
            retain_per_class,
            allocs: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
        })
    }

    fn class_of(len: usize) -> usize {
        let bits = usize::BITS - len.saturating_sub(1).leading_zeros();
        (bits.saturating_sub(MIN_CLASS_LOG2) as usize).min(N_CLASSES - 1)
    }

    fn class_capacity(class: usize) -> usize {
        1usize << (class as u32 + MIN_CLASS_LOG2)
    }

    /// Take a buffer with capacity >= len; contents are cleared.
    pub fn get(self: &Arc<Self>, len: usize) -> PooledBuf {
        let class = Self::class_of(len);
        let mut buf = {
            let mut free = self.classes[class].lock().unwrap();
            free.pop()
        }
        .map(|b| {
            self.reuses.fetch_add(1, Ordering::Relaxed);
            b
        })
        .unwrap_or_else(|| {
            self.allocs.fetch_add(1, Ordering::Relaxed);
            Vec::with_capacity(Self::class_capacity(class))
        });
        buf.clear();
        PooledBuf {
            buf,
            pool: Arc::clone(self),
            class,
        }
    }

    /// Take a zero-filled buffer of exactly `len`.
    pub fn get_zeroed(self: &Arc<Self>, len: usize) -> PooledBuf {
        let mut b = self.get(len);
        b.buf.resize(len, 0.0);
        b
    }

    fn put_back(&self, mut buf: Vec<f32>, class: usize) {
        let mut free = self.classes[class].lock().unwrap();
        if free.len() < self.retain_per_class {
            buf.clear();
            free.push(buf);
        }
        // else: drop frees the memory
    }

    pub fn reuse_ratio(&self) -> f64 {
        let a = self.allocs.load(Ordering::Relaxed) as f64;
        let r = self.reuses.load(Ordering::Relaxed) as f64;
        if a + r == 0.0 {
            0.0
        } else {
            r / (a + r)
        }
    }

    /// Bytes currently parked in free lists (§5.3 storage accounting).
    pub fn pooled_bytes(&self) -> usize {
        self.classes
            .iter()
            .map(|c| {
                c.lock()
                    .unwrap()
                    .iter()
                    .map(|b| b.capacity() * 4)
                    .sum::<usize>()
            })
            .sum()
    }
}

/// RAII pooled buffer; derefs to `Vec<f32>`.
pub struct PooledBuf {
    buf: Vec<f32>,
    pool: Arc<ArenaPool>,
    class: usize,
}

impl std::ops::Deref for PooledBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }
}

impl PooledBuf {
    /// Move the contents out (e.g. into a Tensor), returning an empty
    /// buffer to the pool immediately.
    pub fn take(mut self) -> Vec<f32> {
        std::mem::take(&mut self.buf)
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() > 0 {
            self.pool.put_back(buf, self.class);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_of_is_monotone() {
        assert_eq!(ArenaPool::class_of(1), 0);
        assert_eq!(ArenaPool::class_of(256), 0);
        assert_eq!(ArenaPool::class_of(257), 1);
        assert_eq!(ArenaPool::class_of(512), 1);
        assert!(ArenaPool::class_of(1 << 24) == N_CLASSES - 1);
    }

    #[test]
    fn buffers_are_reused() {
        let pool = ArenaPool::new(8);
        let ptr1 = {
            let mut b = pool.get(1000);
            b.push(1.0);
            b.as_ptr() as usize
        }; // returned to pool
        let b2 = pool.get(900); // same class
        assert_eq!(b2.as_ptr() as usize, ptr1, "buffer reused");
        assert!(b2.is_empty(), "reused buffer is cleared");
        assert_eq!(pool.reuses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zeroed_has_exact_len() {
        let pool = ArenaPool::new(4);
        let b = pool.get_zeroed(300);
        assert_eq!(b.len(), 300);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn retain_limit_bounds_pool() {
        let pool = ArenaPool::new(2);
        let bufs: Vec<_> = (0..5).map(|_| pool.get(1000)).collect();
        drop(bufs);
        // Only 2 retained.
        let parked = pool.pooled_bytes();
        assert!(parked <= 2 * 1024 * 4 + 64, "parked {parked}");
    }

    #[test]
    fn take_detaches_contents() {
        let pool = ArenaPool::new(4);
        let mut b = pool.get(10);
        b.extend_from_slice(&[1.0, 2.0]);
        let v = b.take();
        assert_eq!(v, vec![1.0, 2.0]);
    }
}
