//! Retrieval-stage simulator.
//!
//! AIF's online-async win is the overlap of user-side computation with the
//! *retrieval latency window*, so this substrate models exactly the two
//! things that matter: (a) a realistic latency distribution, (b) candidate
//! sets with zipf-ish popularity skew + user affinity (cross-request item
//! reuse is what makes nearline N2O precomputation pay off).

use std::sync::Arc;
use std::sync::Mutex;
use std::time::Duration;

use crate::features::latency::{spin_wait, LatencyModel};
use crate::features::World;
use crate::util::rng::{Pcg64, Zipf};

pub struct Retriever {
    world: Arc<World>,
    pub n_candidates: usize,
    latency: LatencyModel,
    zipf: Zipf,
    rng: Mutex<Pcg64>,
    /// Fraction of candidates drawn from the user's affinity pool (their
    /// long-term sequence neighborhood) vs global popularity.
    affinity_frac: f64,
}

impl Retriever {
    pub fn new(
        world: Arc<World>,
        n_candidates: usize,
        latency: LatencyModel,
    ) -> Self {
        let n_items = world.n_items;
        Retriever {
            world,
            n_candidates,
            latency,
            zipf: Zipf::new(n_items, 1.05),
            rng: Mutex::new(Pcg64::with_stream(0x9E7, 5)),
            affinity_frac: 0.5,
        }
    }

    /// Run retrieval for a user: blocks for the modeled latency, returns
    /// the candidate set.  The Merger calls this on a separate thread while
    /// the user-side async inference runs (paper Figure 3).
    pub fn retrieve(&self, user: usize) -> Vec<u32> {
        let (delay, cands) = {
            let mut rng = self.rng.lock().unwrap();
            let delay = self.latency.sample(self.n_candidates * 4, &mut rng);
            (delay, self.sample_candidates(user, &mut rng))
        };
        spin_wait(delay);
        cands
    }

    /// Candidate sampling only (no latency) — used by the workload
    /// generator when pre-building traces.
    pub fn sample_candidates(&self, user: usize, rng: &mut Pcg64) -> Vec<u32> {
        let n = self.n_candidates;
        let n_aff = (n as f64 * self.affinity_frac) as usize;
        let mut out = Vec::with_capacity(n);
        let mut seen = vec![false; self.world.n_items];
        // Affinity half: neighborhood of the user's long-term sequence.
        let seq = self.world.users_long_seq.u32_row(user);
        while out.len() < n_aff {
            let item = seq[rng.below(seq.len() as u64) as usize];
            if !seen[item as usize] {
                seen[item as usize] = true;
                out.push(item);
            } else {
                // Collision: jump to a popularity sample to guarantee progress.
                let item = self.zipf.sample(rng) as u32;
                if !seen[item as usize] {
                    seen[item as usize] = true;
                    out.push(item);
                }
            }
        }
        // Popularity half: zipf over the catalog (head reuse across requests).
        while out.len() < n {
            let item = self.zipf.sample(rng) as u32;
            if !seen[item as usize] {
                seen[item as usize] = true;
                out.push(item);
            }
        }
        out
    }

    pub fn expected_latency(&self) -> Duration {
        Duration::from_nanos((self.latency.base_us * 1000.0) as u64)
    }
}
