//! Typed serving API (DESIGN.md §10): the request/response contract every
//! entry point programs against — the HTTP surface, the load runner, the
//! A/B harness, the experiment drivers, the benches and the examples.
//!
//! The paper's claim is that ONE config-driven pipeline serves every
//! Table-4 variant; the serving contract therefore lives here, independent
//! of any concrete pipeline: [`ScoreRequest`] (builder: user, `top_k`,
//! candidate override, deadline budget, trace flag) in, [`ScoreResponse`]
//! (scored items, [`PhaseTimings`], variant + request id, optional
//! per-stage trace) out, and a closed [`ServeError`] enum with a defined
//! HTTP status mapping instead of `anyhow` leaking to callers.  Any
//! pipeline that implements [`PreRanker`] plugs into every harness.

use std::sync::Arc;
use std::time::Duration;

use crate::config::{parse_sla, SlaClass};
use crate::metrics::ServingMetrics;
use crate::server::http::FrontendStats;
use crate::util::json::{Object, Value};

/// Per-request phase timings.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTimings {
    pub total: Duration,
    pub retrieval: Duration,
    pub user_async: Option<Duration>,
    pub prerank: Duration,
}

/// One pre-ranking request.  Construct with [`ScoreRequest::user`] and
/// chain `with_*` builders for the optional knobs:
///
/// ```ignore
/// let resp = merger.score(
///     ScoreRequest::user(42).with_top_k(10).with_trace(true),
/// )?;
/// ```
#[derive(Debug, Clone, Default)]
pub struct ScoreRequest {
    /// The user to pre-rank for (must be `< n_users`).
    pub user: usize,
    /// Caller-supplied request id, for in-process drivers (load runner,
    /// A/B harness); must be `< 2^63` — the top half is the service's
    /// auto-id space.  Not accepted on the wire: the HTTP surface lets
    /// the service allocate, so remote clients can never alias the
    /// async-variant cache keys derived from it.
    pub request_id: Option<u64>,
    /// Result-size override; defaults to the pipeline's configured top-K.
    /// Clamped to the candidate count, rejected when 0.
    pub top_k: Option<usize>,
    /// Candidate-list override: score exactly these items instead of
    /// running the retrieval stage (re-ranking / debugging hook).
    pub candidates: Option<Vec<u32>>,
    /// End-to-end latency budget; exceeding it fails the request with
    /// [`ServeError::DeadlineExceeded`] instead of returning late.
    pub deadline: Option<Duration>,
    /// Attach a per-stage [`ScoreTrace`] to the response.
    pub trace: bool,
    /// Which registered scenario serves this request; `None` routes to
    /// the configured default.  Unknown names fail with
    /// [`ServeError::UnknownScenario`].
    pub scenario: Option<String>,
    /// SLA class under overload tiering (DESIGN.md §20): `guaranteed`
    /// always serves at the top tier, `degradable` at the controller's
    /// tier, `best_effort` degrades first and recovers last.  `None`
    /// takes the configured `overload.default_sla`.
    pub sla: Option<SlaClass>,
}

impl ScoreRequest {
    pub fn user(user: usize) -> ScoreRequest {
        ScoreRequest {
            user,
            ..Default::default()
        }
    }

    pub fn with_request_id(mut self, id: u64) -> Self {
        self.request_id = Some(id);
        self
    }

    pub fn with_top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    pub fn with_candidates(mut self, candidates: Vec<u32>) -> Self {
        self.candidates = Some(candidates);
        self
    }

    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(budget);
        self
    }

    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    pub fn with_scenario(mut self, scenario: impl Into<String>) -> Self {
        self.scenario = Some(scenario.into());
        self
    }

    pub fn with_sla(mut self, sla: SlaClass) -> Self {
        self.sla = Some(sla);
        self
    }

    /// Parse one request object from a `POST /v1/score` JSON body.
    pub fn from_json(v: &Value) -> Result<ScoreRequest, ServeError> {
        let o = v.as_obj().ok_or_else(|| {
            ServeError::BadRequest("body must be a JSON object".into())
        })?;
        let mut req = Self::options_from_json(o)?;
        req.user = parse_user(o.get("user").ok_or_else(|| {
            ServeError::BadRequest("missing \"user\"".into())
        })?)?;
        Ok(req)
    }

    /// Parse only the optional knobs (everything except `user`/`users`) —
    /// the shared template of a batch body.
    pub fn options_from_json(o: &Object) -> Result<ScoreRequest, ServeError> {
        for (key, _) in o.iter() {
            if !matches!(
                key,
                "user" | "users" | "top_k" | "candidates" | "deadline_ms"
                    | "trace" | "scenario" | "sla"
            ) {
                return Err(ServeError::BadRequest(format!(
                    "unknown field {key:?}"
                )));
            }
        }
        let mut req = ScoreRequest::default();
        if let Some(v) = o.get("top_k") {
            let k = v
                .as_f64()
                .filter(|x| *x >= 1.0 && x.fract() == 0.0)
                .ok_or_else(|| {
                    ServeError::BadRequest(
                        "\"top_k\" must be a positive integer".into(),
                    )
                })?;
            req.top_k = Some(k as usize);
        }
        if let Some(v) = o.get("deadline_ms") {
            let ms = v.as_f64().filter(|x| *x > 0.0).ok_or_else(|| {
                ServeError::BadRequest(
                    "\"deadline_ms\" must be a positive number".into(),
                )
            })?;
            req.deadline = Some(Duration::from_secs_f64(ms / 1e3));
        }
        if let Some(v) = o.get("trace") {
            req.trace = v.as_bool().ok_or_else(|| {
                ServeError::BadRequest("\"trace\" must be a boolean".into())
            })?;
        }
        if let Some(v) = o.get("scenario") {
            let s = v.as_str().ok_or_else(|| {
                ServeError::BadRequest(
                    "\"scenario\" must be a string".into(),
                )
            })?;
            if s.is_empty() {
                return Err(ServeError::BadRequest(
                    "\"scenario\" must be non-empty".into(),
                ));
            }
            req.scenario = Some(s.to_string());
        }
        if let Some(v) = o.get("sla") {
            let s = v.as_str().ok_or_else(|| {
                ServeError::BadRequest("\"sla\" must be a string".into())
            })?;
            req.sla = Some(parse_sla(s).map_err(|e| {
                ServeError::BadRequest(format!("{e:#}"))
            })?);
        }
        if let Some(v) = o.get("candidates") {
            let arr = v.as_arr().ok_or_else(|| {
                ServeError::BadRequest(
                    "\"candidates\" must be an array of item ids".into(),
                )
            })?;
            if arr.is_empty() {
                return Err(ServeError::BadRequest(
                    "\"candidates\" must be non-empty".into(),
                ));
            }
            let mut ids = Vec::with_capacity(arr.len());
            for e in arr {
                let id = e
                    .as_f64()
                    .filter(|x| {
                        *x >= 0.0
                            && x.fract() == 0.0
                            && *x <= u32::MAX as f64
                    })
                    .ok_or_else(|| {
                        ServeError::BadRequest(
                            "\"candidates\" entries must be item ids".into(),
                        )
                    })?;
                ids.push(id as u32);
            }
            req.candidates = Some(ids);
        }
        Ok(req)
    }
}

impl ScoreRequest {
    /// The wire shape of a `POST /v1/score` body — the client half of
    /// [`ScoreRequest::from_json`], used by the cluster router to forward
    /// requests to worker shards.  `request_id` is intentionally NOT
    /// serialized (the wire rejects it; each worker allocates its own),
    /// and the deadline is whatever *remaining* budget the caller put in
    /// `self.deadline` — hop-time subtraction happens in the client, not
    /// here.
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("user", self.user);
        if let Some(k) = self.top_k {
            o.insert("top_k", k);
        }
        if let Some(c) = &self.candidates {
            let arr: Vec<Value> =
                c.iter().map(|&id| Value::Num(id as f64)).collect();
            o.insert("candidates", Value::Arr(arr));
        }
        if let Some(d) = self.deadline {
            o.insert("deadline_ms", d.as_secs_f64() * 1e3);
        }
        if self.trace {
            o.insert("trace", true);
        }
        if let Some(s) = &self.scenario {
            o.insert("scenario", s.as_str());
        }
        if let Some(sla) = self.sla {
            o.insert("sla", sla.as_str());
        }
        Value::Obj(o)
    }
}

fn parse_user(v: &Value) -> Result<usize, ServeError> {
    v.as_f64()
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as usize)
        .ok_or_else(|| {
            ServeError::BadRequest(
                "\"user\" must be a non-negative integer".into(),
            )
        })
}

/// One scored candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoredItem {
    pub item: u32,
    pub score: f32,
}

/// One stage of the request lifecycle, for traced requests.
#[derive(Debug, Clone, Copy)]
pub struct StageSpan {
    pub stage: &'static str,
    pub elapsed: Duration,
}

/// Per-stage breakdown attached to a response when the request asked for
/// `trace`.
#[derive(Debug, Clone, Default)]
pub struct ScoreTrace {
    pub n_candidates: usize,
    pub n_batches: usize,
    /// Mini-batches dispatched through the cross-request coalescer (0 on
    /// the sequential baseline path).  When nonzero, `stages` carries a
    /// `coalesce_wait` span with the worst queue dwell paid.
    pub coalesced_batches: usize,
    /// How the user-side tensors were obtained on an async-user variant
    /// (DESIGN.md §15): `"hit"` (cache probe, phase 1 skipped), `"miss"`
    /// (this request led the single-flight and paid the tower call) or
    /// `"joined"` (parked on another request's in-flight computation).
    /// `None` on variants without an async user side.
    pub user_side: Option<&'static str>,
    /// Ladder tier that served the request (0 = full fidelity); `None`
    /// when the service has no overload tiering.
    pub tier: Option<usize>,
    pub stages: Vec<StageSpan>,
}

/// The result of one pre-ranking request.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub request_id: u64,
    pub user: usize,
    /// Registered scenario that served the request.
    pub scenario: String,
    /// Pipeline variant that served the request (Table-4 row name).
    pub variant: String,
    /// Ladder tier that served the request (0 = full fidelity; on a
    /// scatter-gather response the *most degraded* tier any shard used).
    /// `None` when the service has no overload tiering.
    pub tier: Option<usize>,
    /// Top-K scored items, descending score.
    pub items: Vec<ScoredItem>,
    pub timings: PhaseTimings,
    pub trace: Option<ScoreTrace>,
}

impl ScoreResponse {
    /// The wire shape of `GET/POST /v1/score` responses.
    pub fn to_json(&self) -> Value {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut o = Object::new();
        o.insert("request_id", self.request_id);
        o.insert("user", self.user);
        o.insert("scenario", self.scenario.as_str());
        o.insert("variant", self.variant.as_str());
        if let Some(t) = self.tier {
            o.insert("tier", t);
        }
        o.insert("total_ms", ms(self.timings.total));
        o.insert("retrieval_ms", ms(self.timings.retrieval));
        if let Some(ua) = self.timings.user_async {
            o.insert("user_async_ms", ms(ua));
        }
        o.insert("prerank_ms", ms(self.timings.prerank));
        let items: Vec<Value> = self
            .items
            .iter()
            .map(|s| {
                let mut e = Object::new();
                e.insert("item", s.item as u64);
                e.insert("score", s.score as f64);
                Value::Obj(e)
            })
            .collect();
        o.insert("items", Value::Arr(items));
        if let Some(trace) = &self.trace {
            let mut t = Object::new();
            t.insert("n_candidates", trace.n_candidates);
            t.insert("n_batches", trace.n_batches);
            t.insert("coalesced_batches", trace.coalesced_batches);
            if let Some(side) = trace.user_side {
                t.insert("user_side", side);
            }
            if let Some(tier) = trace.tier {
                t.insert("tier", tier);
            }
            let stages: Vec<Value> = trace
                .stages
                .iter()
                .map(|s| {
                    let mut e = Object::new();
                    e.insert("stage", s.stage);
                    e.insert("ms", ms(s.elapsed));
                    Value::Obj(e)
                })
                .collect();
            t.insert("stages", Value::Arr(stages));
            o.insert("trace", Value::Obj(t));
        }
        Value::Obj(o)
    }

    /// Parse a `/v1/score` response body back into a [`ScoreResponse`] —
    /// the client half of [`ScoreResponse::to_json`], used by
    /// `RemotePreRanker`.  Scores survive the f32 -> f64 -> shortest-repr
    /// -> f64 -> f32 round trip bit-for-bit (the serializer emits the
    /// shortest representation that parses back exactly), which is what
    /// makes router-served top-K bitwise-comparable to single-node runs.
    pub fn from_json(v: &Value) -> Result<ScoreResponse, ServeError> {
        let bad = |what: &str| {
            ServeError::Internal(format!("malformed worker response: {what}"))
        };
        let o = v.as_obj().ok_or_else(|| bad("not an object"))?;
        let num =
            |key: &str| o.get(key).and_then(Value::as_f64).ok_or_else(|| bad(key));
        let dur = |ms: f64| Duration::from_secs_f64(ms.max(0.0) / 1e3);
        let items_v = o
            .get("items")
            .and_then(Value::as_arr)
            .ok_or_else(|| bad("items"))?;
        let mut items = Vec::with_capacity(items_v.len());
        for e in items_v {
            let item = e
                .get("item")
                .and_then(Value::as_f64)
                .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                .ok_or_else(|| bad("items[].item"))? as u32;
            let score = e
                .get("score")
                .and_then(Value::as_f64)
                .ok_or_else(|| bad("items[].score"))? as f32;
            items.push(ScoredItem { item, score });
        }
        let trace = match o.get("trace") {
            None => None,
            Some(t) => Some(ScoreTrace {
                n_candidates: t
                    .get("n_candidates")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
                n_batches: t
                    .get("n_batches")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
                coalesced_batches: t
                    .get("coalesced_batches")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
                user_side: t
                    .get("user_side")
                    .and_then(Value::as_str)
                    .and_then(intern_user_side),
                tier: t.get("tier").and_then(Value::as_usize),
                stages: t
                    .get("stages")
                    .and_then(Value::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|s| {
                                Some(StageSpan {
                                    stage: intern_stage(
                                        s.get("stage")?.as_str()?,
                                    )?,
                                    elapsed: dur(s.get("ms")?.as_f64()?),
                                })
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
            }),
        };
        Ok(ScoreResponse {
            request_id: num("request_id")? as u64,
            user: num("user")? as usize,
            scenario: o
                .get("scenario")
                .and_then(Value::as_str)
                .ok_or_else(|| bad("scenario"))?
                .to_string(),
            variant: o
                .get("variant")
                .and_then(Value::as_str)
                .ok_or_else(|| bad("variant"))?
                .to_string(),
            // Tolerant: absent on workers without overload tiering.
            tier: o.get("tier").and_then(Value::as_usize),
            items,
            timings: PhaseTimings {
                total: dur(num("total_ms")?),
                retrieval: dur(num("retrieval_ms")?),
                user_async: o
                    .get("user_async_ms")
                    .and_then(Value::as_f64)
                    .map(dur),
                prerank: dur(num("prerank_ms")?),
            },
            trace,
        })
    }
}

/// `StageSpan.stage` is a `&'static str`; re-materializing a trace from
/// the wire interns the known stage vocabulary (unknown stages from a
/// newer worker are dropped rather than leaked or mislabeled).
fn intern_stage(s: &str) -> Option<&'static str> {
    const STAGES: &[&str] = &[
        "user_async",
        "retrieval",
        "prerank",
        "coalesce_wait",
        "remote_hop",
        "scatter_gather",
    ];
    STAGES.iter().find(|&&k| k == s).copied()
}

fn intern_user_side(s: &str) -> Option<&'static str> {
    const SIDES: &[&str] = &["hit", "miss", "joined"];
    SIDES.iter().find(|&&k| k == s).copied()
}

/// Closed error set of the request path, with a defined HTTP mapping —
/// callers match on causes instead of string-probing `anyhow` chains.
#[derive(Debug, Clone, thiserror::Error)]
pub enum ServeError {
    #[error("unknown user {0}")]
    UnknownUser(usize),
    #[error("unknown scenario {0:?}")]
    UnknownScenario(String),
    #[error(
        "deadline exceeded: {elapsed_ms:.2}ms elapsed of a \
         {budget_ms:.2}ms budget"
    )]
    DeadlineExceeded { budget_ms: f64, elapsed_ms: f64 },
    #[error("bad request: {0}")]
    BadRequest(String),
    #[error("overloaded: {0}")]
    Overloaded(String),
    #[error("internal: {0}")]
    Internal(String),
}

impl ServeError {
    /// The status a `/v1` endpoint answers with for this error.
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::UnknownUser(_) => 404,
            ServeError::UnknownScenario(_) => 404,
            ServeError::DeadlineExceeded { .. } => 504,
            ServeError::BadRequest(_) => 400,
            ServeError::Overloaded(_) => 429,
            ServeError::Internal(_) => 500,
        }
    }
}

/// Pipeline internals (runtime, stores, nearline) still speak `anyhow`;
/// whatever escapes them surfaces as an opaque `Internal`.
impl From<anyhow::Error> for ServeError {
    fn from(e: anyhow::Error) -> Self {
        ServeError::Internal(format!("{e:#}"))
    }
}

/// A pre-ranking service: one config-driven pipeline serving the typed
/// contract.  Implemented by [`super::Merger`] for every Table-4 variant
/// (the sequential baseline is just the `base` configuration); harnesses
/// and the HTTP surface accept any implementation.
pub trait PreRanker: Send + Sync {
    /// Serve one request end to end.
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError>;

    /// Name of the pipeline variant this service runs.
    fn variant_name(&self) -> &str;

    /// Number of known users; `user >= n_users()` is `UnknownUser`.
    fn n_users(&self) -> usize;

    /// Shared serving metrics (drives `/metrics` and load reports).
    fn metrics(&self) -> &ServingMetrics;

    /// §5.3 accounting: extra resident bytes vs the sequential baseline.
    /// Multi-scenario services report shared-core bytes once plus
    /// per-scenario deltas — never shared memory re-counted per ranker.
    fn extra_storage_bytes(&self) -> usize {
        0
    }
}

/// One row of the `GET /v1/scenarios` admin listing.
#[derive(Debug, Clone)]
pub struct ScenarioInfo {
    pub name: String,
    pub variant: String,
    pub is_default: bool,
    /// Bumped on every hot reload of this scenario.
    pub generation: u64,
    /// Requests this scenario has served.
    pub requests: u64,
    /// Whether its head executions route through the coalescer.
    pub coalescing: bool,
}

impl ScenarioInfo {
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("name", self.name.as_str());
        o.insert("variant", self.variant.as_str());
        o.insert("default", self.is_default);
        o.insert("generation", self.generation);
        o.insert("requests", self.requests);
        o.insert("coalescing", self.coalescing);
        Value::Obj(o)
    }

    /// Parse one row of a worker's `GET /v1/scenarios` listing — used by
    /// the cluster router to proxy the admin surface.
    pub fn from_json(v: &Value) -> Result<ScenarioInfo, ServeError> {
        let bad = |what: &str| {
            ServeError::Internal(format!("malformed scenario row: {what}"))
        };
        Ok(ScenarioInfo {
            name: v
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| bad("name"))?
                .to_string(),
            variant: v
                .get("variant")
                .and_then(Value::as_str)
                .ok_or_else(|| bad("variant"))?
                .to_string(),
            is_default: v
                .get("default")
                .and_then(Value::as_bool)
                .unwrap_or(false),
            generation: v
                .get("generation")
                .and_then(Value::as_f64)
                .unwrap_or(0.0) as u64,
            requests: v.get("requests").and_then(Value::as_f64).unwrap_or(0.0)
                as u64,
            coalescing: v
                .get("coalescing")
                .and_then(Value::as_bool)
                .unwrap_or(false),
        })
    }
}

/// Admin surface of a multi-scenario service (drives `GET /v1/scenarios`,
/// `POST /v1/scenarios/{name}/reload` and the per-scenario `/metrics`
/// blocks).  Implemented by [`super::Merger`] over its registry; services
/// without a registry simply don't offer it.
pub trait ScenarioAdmin: Send + Sync {
    /// Registered scenarios, registration order.
    fn list_scenarios(&self) -> Vec<ScenarioInfo>;

    /// Name of the scenario serving unrouted requests.
    fn default_scenario(&self) -> String;

    /// Hot-reload one scenario (rebuild from its spec, atomic swap).
    fn reload_scenario(&self, name: &str) -> Result<ScenarioInfo, ServeError>;

    /// Per-scenario metrics snapshots for `/metrics`.
    fn scenario_metrics(&self, wall: Duration) -> Vec<(String, Value)>;

    /// Requests that failed routing (unknown scenario) — attributed here
    /// instead of to any scenario's error metric.
    fn routing_errors(&self) -> u64 {
        0
    }

    /// Shared arena-pool counters for the `/metrics` `arena` block
    /// (`None` when the service has no pool to report).
    fn arena_stats(&self) -> Option<Value> {
        None
    }

    /// Cross-request user-state cache counters for the `/metrics`
    /// `user_cache` block (hits, misses, single-flight joins, evictions,
    /// resident bytes, epoch; `None` when the service has no such cache).
    fn user_cache_stats(&self) -> Option<Value> {
        None
    }

    /// Durable-store counters for the `/metrics` `storage` block and
    /// `GET /v1/storage` (snapshots written, bytes, checkpoint age,
    /// restore duration, delta replays; `None` when no backend is
    /// configured).
    fn storage_stats(&self) -> Option<Value> {
        None
    }

    /// Readiness report for `GET /readyz` — `{"ready": bool, "state":
    /// name}` per the DESIGN.md §16 warm-boot state machine.  Services
    /// without a boot sequence are born ready.
    fn readiness(&self) -> Value {
        let mut o = Object::new();
        o.insert("ready", true);
        o.insert("state", "ready");
        Value::Obj(o)
    }

    /// Nearline pipeline counters for the `/metrics` `nearline` block
    /// (table shape/fragmentation, heat-lane stats, update-queue depth/
    /// backpressure/staleness; `None` when the service has no nearline
    /// substrate).
    fn nearline_stats(&self) -> Option<Value> {
        None
    }

    /// Force a checkpoint now (`POST /v1/checkpoint`); answers with the
    /// outcome and fresh storage counters, or `BadRequest` when no
    /// backend is configured.
    fn trigger_checkpoint(&self) -> Result<Value, ServeError> {
        Err(ServeError::BadRequest(
            "no storage backend configured".into(),
        ))
    }

    /// Per-scenario overload-tiering snapshots for the `/metrics`
    /// `overload` block (current tier, transitions, dwell, per-tier
    /// request counts, controller inputs); `None` when the service has
    /// no tier ladder / controller.
    fn overload_stats(&self) -> Option<Value> {
        None
    }

    /// Front ends announce their stats block here so the overload
    /// controller can sample queue depth and in-flight counts.  Default:
    /// the service has no controller and ignores the registration.
    fn register_frontend(&self, _stats: &Arc<FrontendStats>) {}

    /// Cluster membership + per-shard counters for the `/metrics`
    /// `cluster` block and `GET /v1/cluster` (`None` on single-process
    /// services — only the router tier has a cluster to report).
    fn cluster_stats(&self) -> Option<Value> {
        None
    }

    /// Admit a worker (`POST /v1/cluster/join`): adds `addr` to the
    /// membership set in `Draining`-cleared, probe-pending state; the
    /// ring picks it up once it probes healthy.  `BadRequest` on
    /// services without a cluster tier.
    fn cluster_join(&self, _addr: &str) -> Result<Value, ServeError> {
        Err(ServeError::BadRequest("not a cluster router".into()))
    }

    /// Drain a worker (`POST /v1/cluster/drain`): removes `addr` from
    /// the ring immediately (in-flight requests finish; new ones remap)
    /// and pins it out of probe re-admission until a `join` readmits it.
    /// `BadRequest` on services without a cluster tier.
    fn cluster_drain(&self, _addr: &str) -> Result<Value, ServeError> {
        Err(ServeError::BadRequest("not a cluster router".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_knobs() {
        let req = ScoreRequest::user(7)
            .with_request_id(99)
            .with_top_k(5)
            .with_candidates(vec![1, 2, 3])
            .with_deadline(Duration::from_millis(50))
            .with_trace(true);
        assert_eq!(req.user, 7);
        assert_eq!(req.request_id, Some(99));
        assert_eq!(req.top_k, Some(5));
        assert_eq!(req.candidates.as_deref(), Some(&[1, 2, 3][..]));
        assert_eq!(req.deadline, Some(Duration::from_millis(50)));
        assert!(req.trace);
    }

    #[test]
    fn defaults_are_absent() {
        let req = ScoreRequest::user(3);
        assert!(req.request_id.is_none());
        assert!(req.top_k.is_none());
        assert!(req.candidates.is_none());
        assert!(req.deadline.is_none());
        assert!(!req.trace);
        assert!(req.scenario.is_none(), "unrouted -> default scenario");
    }

    #[test]
    fn scenario_routing_knob() {
        let req = ScoreRequest::user(3).with_scenario("video");
        assert_eq!(req.scenario.as_deref(), Some("video"));

        let v = Value::parse(r#"{"user": 1, "scenario": "video"}"#).unwrap();
        let req = ScoreRequest::from_json(&v).unwrap();
        assert_eq!(req.scenario.as_deref(), Some("video"));

        for bad in [
            r#"{"user": 1, "scenario": 7}"#,
            r#"{"user": 1, "scenario": ""}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(matches!(
                ScoreRequest::from_json(&v),
                Err(ServeError::BadRequest(_))
            ));
        }
    }

    #[test]
    fn sla_knob_parses_and_rejects() {
        let v = Value::parse(r#"{"user": 1, "sla": "guaranteed"}"#).unwrap();
        let req = ScoreRequest::from_json(&v).unwrap();
        assert_eq!(req.sla, Some(SlaClass::Guaranteed));
        for bad in [
            r#"{"user": 1, "sla": "platinum"}"#,
            r#"{"user": 1, "sla": 3}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(matches!(
                ScoreRequest::from_json(&v),
                Err(ServeError::BadRequest(_))
            ));
        }
        // Absent -> None -> the configured default class applies.
        let v = Value::parse(r#"{"user": 1}"#).unwrap();
        assert_eq!(ScoreRequest::from_json(&v).unwrap().sla, None);
    }

    #[test]
    fn http_status_mapping() {
        assert_eq!(ServeError::UnknownUser(1).http_status(), 404);
        assert_eq!(
            ServeError::UnknownScenario("x".into()).http_status(),
            404
        );
        assert_eq!(
            ServeError::DeadlineExceeded {
                budget_ms: 1.0,
                elapsed_ms: 2.0
            }
            .http_status(),
            504
        );
        assert_eq!(ServeError::BadRequest("x".into()).http_status(), 400);
        assert_eq!(ServeError::Overloaded("x".into()).http_status(), 429);
        assert_eq!(ServeError::Internal("x".into()).http_status(), 500);
    }

    #[test]
    fn from_json_parses_full_request() {
        let v = Value::parse(
            r#"{"user": 3, "top_k": 5, "trace": true,
                "candidates": [4, 5, 6], "deadline_ms": 50}"#,
        )
        .unwrap();
        let req = ScoreRequest::from_json(&v).unwrap();
        assert_eq!(req.user, 3);
        assert_eq!(req.top_k, Some(5));
        assert!(req.trace);
        assert_eq!(req.candidates.as_deref(), Some(&[4, 5, 6][..]));
        assert_eq!(req.deadline, Some(Duration::from_millis(50)));
        // The wire cannot pick cache-key-bearing ids; the service does.
        assert_eq!(req.request_id, None);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let bad = [
            r#"{}"#,                          // missing user
            r#"{"user": "three"}"#,           // non-numeric user
            r#"{"user": 1.5}"#,               // fractional user
            r#"{"user": -1}"#,                // negative user
            r#"{"user": 1, "top_k": 0}"#,     // zero top_k
            r#"{"user": 1, "top_k": "all"}"#, // non-numeric top_k
            r#"{"user": 1, "bogus": 2}"#,     // unknown field
            r#"{"user": 1, "request_id": 5}"#, // ids are server-allocated
            r#"{"user": 1, "trace": "yes"}"#, // non-bool trace
            r#"{"user": 1, "candidates": 3}"#, // non-array candidates
            r#"{"user": 1, "candidates": []}"#, // empty override
            r#"{"user": 1, "candidates": [-2]}"#, // negative item id
            r#"{"user": 1, "deadline_ms": 0}"#, // zero budget
            r#"[1, 2]"#,                      // not an object
        ];
        for src in bad {
            let v = Value::parse(src).unwrap();
            let e = ScoreRequest::from_json(&v).unwrap_err();
            assert!(
                matches!(e, ServeError::BadRequest(_)),
                "{src} -> {e:?}"
            );
        }
    }

    #[test]
    fn request_wire_round_trips() {
        let req = ScoreRequest::user(9)
            .with_top_k(4)
            .with_candidates(vec![7, 1, 42])
            .with_deadline(Duration::from_millis(35))
            .with_trace(true)
            .with_scenario("video")
            .with_sla(SlaClass::BestEffort);
        let wire = Value::parse(&req.to_json().to_string()).unwrap();
        let back = ScoreRequest::from_json(&wire).unwrap();
        assert_eq!(back.user, 9);
        assert_eq!(back.top_k, Some(4));
        assert_eq!(back.candidates.as_deref(), Some(&[7, 1, 42][..]));
        assert_eq!(back.deadline, Some(Duration::from_millis(35)));
        assert!(back.trace);
        assert_eq!(back.scenario.as_deref(), Some("video"));
        assert_eq!(back.sla, Some(SlaClass::BestEffort));
        // request_id never crosses the wire — workers allocate their own.
        let req = ScoreRequest::user(1).with_request_id(77);
        assert!(req.to_json().get("request_id").is_none());
        // A bare request serializes to just the user (defaults omitted).
        assert_eq!(
            ScoreRequest::user(3).to_json().to_string(),
            r#"{"user":3}"#
        );
    }

    #[test]
    fn response_wire_round_trips_scores_bitwise() {
        // Awkward f32 values must survive serialize -> parse exactly:
        // the cluster bitwise-identity gate rides on this.
        let scores: Vec<f32> = (0..200)
            .map(|i| ((i as f32 * 0.7311).sin() * 30.0).exp() / 3.0_f32)
            .chain([f32::MIN_POSITIVE, 1e-40, 0.1, 1.0 / 3.0])
            .collect();
        let resp = ScoreResponse {
            request_id: 5,
            user: 2,
            scenario: "main".into(),
            variant: "aif".into(),
            tier: Some(1),
            items: scores
                .iter()
                .enumerate()
                .map(|(i, &s)| ScoredItem {
                    item: i as u32,
                    score: s,
                })
                .collect(),
            timings: PhaseTimings {
                total: Duration::from_micros(20_500),
                retrieval: Duration::from_micros(12_250),
                user_async: None,
                prerank: Duration::from_micros(8_125),
            },
            trace: Some(ScoreTrace {
                n_candidates: 64,
                n_batches: 4,
                coalesced_batches: 0,
                user_side: Some("miss"),
                tier: Some(1),
                stages: vec![
                    StageSpan {
                        stage: "retrieval",
                        elapsed: Duration::from_millis(12),
                    },
                    StageSpan {
                        stage: "prerank",
                        elapsed: Duration::from_millis(8),
                    },
                ],
            }),
        };
        let wire = Value::parse(&resp.to_json().to_string()).unwrap();
        let back = ScoreResponse::from_json(&wire).unwrap();
        assert_eq!(back.request_id, 5);
        assert_eq!(back.user, 2);
        assert_eq!(back.scenario, "main");
        assert_eq!(back.variant, "aif");
        assert_eq!(back.items.len(), resp.items.len());
        for (a, b) in resp.items.iter().zip(&back.items) {
            assert_eq!(a.item, b.item);
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "score {} not bitwise after round trip",
                a.score
            );
        }
        assert!(back.timings.user_async.is_none());
        assert_eq!(back.tier, Some(1), "tier survives the wire");
        let t = back.trace.expect("trace survives");
        assert_eq!(t.n_candidates, 64);
        assert_eq!(t.user_side, Some("miss"));
        assert_eq!(t.tier, Some(1));
        assert_eq!(t.stages.len(), 2);
        assert_eq!(t.stages[0].stage, "retrieval");

        // Malformed worker bodies surface as Internal, not panics.
        for bad in [
            r#"[1]"#,
            r#"{"user": 1}"#,
            r#"{"request_id":1,"user":1,"scenario":"s","variant":"v",
                "total_ms":1,"retrieval_ms":1,"prerank_ms":1,
                "items":[{"item":-3,"score":0.5}]}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(matches!(
                ScoreResponse::from_json(&v),
                Err(ServeError::Internal(_))
            ));
        }
    }

    #[test]
    fn scenario_info_round_trips() {
        let info = ScenarioInfo {
            name: "video".into(),
            variant: "t4_lsh".into(),
            is_default: true,
            generation: 3,
            requests: 91,
            coalescing: true,
        };
        let wire = Value::parse(&info.to_json().to_string()).unwrap();
        let back = ScenarioInfo::from_json(&wire).unwrap();
        assert_eq!(back.name, "video");
        assert_eq!(back.variant, "t4_lsh");
        assert!(back.is_default);
        assert_eq!(back.generation, 3);
        assert_eq!(back.requests, 91);
        assert!(back.coalescing);
        assert!(ScenarioInfo::from_json(&Value::Null).is_err());
    }

    #[test]
    fn response_json_round_trips() {
        let resp = ScoreResponse {
            request_id: 7,
            user: 3,
            scenario: "main".into(),
            variant: "aif".into(),
            tier: None,
            items: vec![
                ScoredItem {
                    item: 10,
                    score: 0.9,
                },
                ScoredItem {
                    item: 11,
                    score: 0.8,
                },
            ],
            timings: PhaseTimings {
                total: Duration::from_millis(20),
                retrieval: Duration::from_millis(12),
                user_async: Some(Duration::from_millis(5)),
                prerank: Duration::from_millis(8),
            },
            trace: Some(ScoreTrace {
                n_candidates: 512,
                n_batches: 2,
                coalesced_batches: 2,
                user_side: Some("hit"),
                tier: None,
                stages: vec![StageSpan {
                    stage: "prerank",
                    elapsed: Duration::from_millis(8),
                }],
            }),
        };
        let v = Value::parse(&resp.to_json().to_string()).unwrap();
        assert_eq!(v.req("user").as_usize(), Some(3));
        assert_eq!(v.req("scenario").as_str(), Some("main"));
        assert_eq!(v.req("variant").as_str(), Some("aif"));
        assert_eq!(v.req("items").as_arr().unwrap().len(), 2);
        assert_eq!(
            v.req("items").as_arr().unwrap()[0].req("item").as_usize(),
            Some(10)
        );
        assert_eq!(
            v.req("trace").req("n_candidates").as_usize(),
            Some(512)
        );
        assert_eq!(
            v.req("trace").req("coalesced_batches").as_usize(),
            Some(2)
        );
        assert_eq!(v.req("trace").req("user_side").as_str(), Some("hit"));
        assert!(v.req("user_async_ms").as_f64().unwrap() > 4.0);
    }
}
