//! The Merger — the system's central coordinator (paper §3.1, Figures 2-5).
//!
//! One config-driven request pipeline covers the sequential baseline and
//! every AIF increment of Table 4:
//!
//! ```text
//! score(request):
//!   phase 1 (only if variant.user == "async"):
//!       ├─ fetch user features ─ user_tower on the consistent-hashed RTP
//!       │  worker ─ cache UserAsync under hash(request_id, nickname)
//!       ├─ pre-warm the SIM LRU for every user-category combination
//!       └─ ... all OVERLAPPED with the retrieval stage
//!   retrieval (blocks for the modeled upstream latency)
//!   phase 2 (real-time pre-rank):
//!       ├─ take cached UserAsync (or fetch/compute user-side inline —
//!       │  the sequential baseline path)
//!       ├─ split candidates into mini-batches; per batch, concurrently:
//!       │    fetch item features (inline variants) or read the N2O
//!       │    snapshot (nearline variants), assemble head inputs, execute
//!       │    the head artifact on the RTP fleet
//!       └─ merge scores, top-K
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher;
use super::router::Router;
use super::service::{
    PreRanker, ScoreRequest, ScoreResponse, ScoreTrace, ScoredItem,
    ServeError, StageSpan,
};
use crate::cache::{ArenaPool, RequestKey, ShardedLru, UserAsync, UserVecCache};
use crate::config::{ServingConfig, SimMode};
use crate::features::{assembly, FeatureStore, World};
use crate::lsh::{self, Hasher};
use crate::metrics::ServingMetrics;
use crate::nearline::{N2oSnapshot, N2oTable, NearlineWorker};
use crate::retrieval::Retriever;
use crate::runtime::{Manifest, RtpPool, Tensor, VariantSpec};
use crate::util::threadpool::ThreadPool;

/// Auto-allocated request ids live at and above this bound; callers must
/// stay below it so the two spaces can never alias a `RequestKey`.
pub const AUTO_REQUEST_ID_BASE: u64 = 1 << 63;

/// Per-request phase timings.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTimings {
    pub total: Duration,
    pub retrieval: Duration,
    pub user_async: Option<Duration>,
    pub prerank: Duration,
}

#[derive(Debug)]
pub struct RequestResult {
    pub top_k: Vec<(u32, f32)>,
    pub timings: PhaseTimings,
}

pub struct Merger {
    pub cfg: ServingConfig,
    pub manifest: Arc<Manifest>,
    pub variant: VariantSpec,
    pub world: Arc<World>,
    pub store: Arc<FeatureStore>,
    pub retriever: Arc<Retriever>,
    pub rtp: Arc<RtpPool>,
    pub router: Router,
    pub user_cache: Arc<UserVecCache>,
    /// (user, category) -> parsed SIM subsequence.
    pub sim_cache: Arc<ShardedLru<(u32, u32), Arc<Vec<u32>>>>,
    pub n2o: Arc<N2oTable>,
    pub hasher: Arc<Hasher>,
    pub arena: Arc<ArenaPool>,
    pub metrics: Arc<ServingMetrics>,
    async_pool: Arc<ThreadPool>,
    score_pool: Arc<ThreadPool>,
    pub batch: usize,
    head_artifact: String,
    /// Request-id allocator for requests that don't bring their own.
    /// Lives in the top half of the id space so auto-allocated ids can
    /// never collide with caller-supplied ones (which would alias
    /// `RequestKey`s in the async-variant user cache).
    req_ids: AtomicU64,
}

impl Merger {
    /// Bring up the full serving stack for one pipeline configuration.
    /// Runs the nearline full build when the variant reads the N2O table.
    pub fn build(cfg: ServingConfig) -> Result<Merger> {
        let manifest = Arc::new(Manifest::load(&cfg.artifacts_dir)?);
        let variant = manifest.variant(&cfg.variant)?.clone();
        let world = Arc::new(World::load(&manifest)?);
        let store = Arc::new(FeatureStore::new(
            Arc::clone(&world),
            cfg.user_store_latency.clone(),
            cfg.item_store_latency.clone(),
        ));
        let retriever = Arc::new(Retriever::new(
            Arc::clone(&world),
            cfg.n_candidates,
            cfg.retrieval_latency.clone(),
        ));

        // Artifact set this pipeline needs.
        let mut artifacts = vec![variant.artifact.clone()];
        if variant.user == "async" || variant.has_long() {
            // The user tower also supplies seq_emb for the non-async
            // long-term rows (computed on the request path there).
            artifacts.push("user_tower".into());
        }
        if variant.item == "nearline" {
            artifacts.push("item_tower".into());
        }
        let rtp = Arc::new(RtpPool::new(
            Arc::clone(&manifest),
            artifacts,
            cfg.n_rtp_workers,
        ));

        let hasher = Arc::new(Hasher::from_table(&world.w_hash));
        let batch = manifest.batch;
        let n2o = Arc::new(N2oTable::new(
            world.n_items,
            manifest.dim("D"),
            manifest.dim("N_BRIDGE"),
            manifest.dim("D_LSH_BITS"),
        ));
        if variant.item == "nearline" {
            let worker = NearlineWorker::new(
                Arc::clone(&rtp),
                Arc::clone(&world),
                Arc::clone(&hasher),
                Arc::clone(&n2o),
                batch,
            );
            let report = worker.full_build(1).context("nearline full build")?;
            log::info!(
                "N2O full build: {} items, {} executions, {:?}, {} bytes",
                report.n_items,
                report.executions,
                report.elapsed,
                report.table_bytes
            );
        }

        // Validate the head signature against what we will assemble.
        let expected = expected_input_names(&variant);
        let actual: Vec<String> = manifest
            .artifact(&variant.artifact)?
            .inputs
            .iter()
            .map(|s| s.name.clone())
            .collect();
        anyhow::ensure!(
            expected == actual,
            "head {} signature mismatch: assembling {expected:?}, \
             manifest says {actual:?}",
            variant.artifact
        );

        Ok(Merger {
            router: Router::new(cfg.n_rtp_workers, 64),
            user_cache: Arc::new(UserVecCache::new(cfg.user_cache_shards)),
            sim_cache: Arc::new(ShardedLru::new(
                cfg.lru_capacity,
                cfg.lru_shards,
            )),
            arena: ArenaPool::new(cfg.arena_retain),
            metrics: Arc::new(ServingMetrics::new()),
            async_pool: Arc::new(ThreadPool::new(cfg.n_async_workers)),
            // Batch-scoring tasks block on RTP replies; give them their own
            // pool (2x the fleet) so they never starve the phase-1 tasks.
            score_pool: Arc::new(ThreadPool::new(cfg.n_rtp_workers + 2)),
            head_artifact: variant.artifact.clone(),
            req_ids: AtomicU64::new(AUTO_REQUEST_ID_BASE),
            manifest,
            variant,
            world,
            store,
            retriever,
            rtp,
            n2o,
            hasher,
            batch,
            cfg,
        })
    }

    fn nickname(user: usize) -> String {
        format!("user-{user}")
    }

    /// Pre-typed-API entry point, kept as a one-line compatibility shim.
    /// The old API accepted the full u64 id space; ids are masked into
    /// the caller half so the typed path's auto-id guard holds.
    #[deprecated(note = "use `score(ScoreRequest::user(user))`")]
    pub fn handle(&self, request_id: u64, user: usize) -> Result<RequestResult> {
        let id = request_id % AUTO_REQUEST_ID_BASE;
        let resp =
            self.score(ScoreRequest::user(user).with_request_id(id))?;
        Ok(RequestResult {
            top_k: resp.items.iter().map(|s| (s.item, s.score)).collect(),
            timings: resp.timings,
        })
    }

    /// Serve one request end to end through the typed contract.
    pub fn score(
        &self,
        req: ScoreRequest,
    ) -> Result<ScoreResponse, ServeError> {
        let result = self.serve(&req);
        if result.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn serve(&self, req: &ScoreRequest) -> Result<ScoreResponse, ServeError> {
        let t_total = Instant::now();

        // ---- validation (before any work is scheduled) -------------------
        let user = req.user;
        if user >= self.world.n_users {
            return Err(ServeError::UnknownUser(user));
        }
        let top_k = req.top_k.unwrap_or(self.cfg.top_k);
        if top_k == 0 {
            return Err(ServeError::BadRequest("top_k must be >= 1".into()));
        }
        if let Some(cands) = &req.candidates {
            if cands.is_empty() {
                return Err(ServeError::BadRequest(
                    "candidate override must be non-empty".into(),
                ));
            }
            if let Some(&bad) =
                cands.iter().find(|&&i| (i as usize) >= self.world.n_items)
            {
                return Err(ServeError::BadRequest(format!(
                    "unknown candidate item {bad}"
                )));
            }
        }
        if let Some(id) = req.request_id {
            if id >= AUTO_REQUEST_ID_BASE {
                return Err(ServeError::BadRequest(format!(
                    "request_id must be < 2^63 (got {id}; the top half \
                     is the auto-id space)"
                )));
            }
        }
        let request_id = req
            .request_id
            .unwrap_or_else(|| self.req_ids.fetch_add(1, Ordering::Relaxed));
        let key = RequestKey::new(request_id, &Self::nickname(user));
        let worker = self.router.route(key.0);

        // ---- phase 1: online asynchronous user-side inference -----------
        let async_done = if self.variant.user == "async" {
            let (tx, rx) = channel::<Result<Duration>>();
            let store = Arc::clone(&self.store);
            let world = Arc::clone(&self.world);
            let rtp = Arc::clone(&self.rtp);
            let cache = Arc::clone(&self.user_cache);
            let key2 = key;
            self.async_pool.spawn(move || {
                let t0 = Instant::now();
                let result = (|| -> Result<()> {
                    let uf = store.fetch_user(user);
                    // Signatures of the long-term sequence (static table):
                    // packed bytes feed the SimTier popcount path; the ±1
                    // plane goes into the tower so it can emit the
                    // linearized DIN factors.
                    let packed = packed_signs(&world, &uf.long_seq);
                    let plane = lsh::unpack_plane(
                        &packed,
                        uf.long_seq.len(),
                        world.w_hash.shape()[0],
                    );
                    let mut inputs =
                        assembly::user_tower_inputs(&world, &uf);
                    inputs.push(plane);
                    let rx2 = rtp.call_async_on(worker, "user_tower", inputs);
                    let out = rx2
                        .recv()
                        .map_err(|_| anyhow::anyhow!("RTP reply dropped"))??;
                    cache.put(
                        key2,
                        UserAsync {
                            u_vec: out[0].clone(),
                            bea_v: out[1].clone(),
                            seq_emb: out[2].clone(),
                            din_base: out[3].clone(),
                            din_g: out[4].clone(),
                            seq_sign_packed: Arc::new(packed),
                            long_seq: uf.long_seq,
                        },
                    );
                    Ok(())
                })();
                let _ = tx.send(result.map(|()| t0.elapsed()));
            });
            Some(rx)
        } else {
            None
        };

        // SIM pre-warming runs alongside retrieval too.
        if self.variant.sim_cross && self.cfg.sim_mode == SimMode::Precached {
            let store = Arc::clone(&self.store);
            let world = Arc::clone(&self.world);
            let sim_cache = Arc::clone(&self.sim_cache);
            let budget = self.cfg.sim_budget;
            let parse_us = self.cfg.sim_parse_us;
            self.async_pool.spawn(move || {
                // Only hit the remote store if any of the user's categories
                // is cold; one multi-get covers them all (Figure 5).
                let cats = world.user_sim_categories(user);
                let cold = cats.iter().any(|&c| {
                    sim_cache.get(&(user as u32, c)).is_none()
                });
                if cold {
                    for (cat, sub) in
                        store.fetch_sim_all(user, budget, parse_us)
                    {
                        sim_cache.insert((user as u32, cat), Arc::new(sub));
                    }
                }
            });
        }

        // ---- retrieval (upstream stage; blocks) -------------------------
        // A candidate override skips the retrieval stage entirely (the
        // caller already knows what to score) but keeps the phase-1 overlap.
        let t_r = Instant::now();
        let candidates = match &req.candidates {
            Some(c) => c.clone(),
            None => self.retriever.retrieve(user),
        };
        let retrieval = t_r.elapsed();

        // ---- join phase 1 -------------------------------------------------
        let user_async = match async_done {
            Some(rx) => Some(rx.recv().map_err(|_| {
                ServeError::Internal("async phase died".into())
            })??),
            None => None,
        };

        // ---- deadline gate before the pre-rank phase ---------------------
        if let Err(e) = check_deadline(req.deadline, t_total) {
            // The async result was parked for phase 2; drop it so an
            // abandoned request doesn't leak a cache entry.
            if self.variant.user == "async" {
                let _ = self.user_cache.take(key);
            }
            return Err(e);
        }

        // ---- phase 2: real-time pre-ranking ------------------------------
        let t_p = Instant::now();
        let scores = self.prerank(key, user, &candidates)?;
        let prerank = t_p.elapsed();
        check_deadline(req.deadline, t_total)?;

        let top = batcher::top_k(&candidates, &scores, top_k);
        let timings = PhaseTimings {
            total: t_total.elapsed(),
            retrieval,
            user_async,
            prerank,
        };
        self.metrics.record_request(
            timings.total,
            timings.prerank,
            timings.user_async,
            timings.retrieval,
        );
        self.metrics
            .items_scored
            .fetch_add(candidates.len() as u64, Ordering::Relaxed);

        let trace = if req.trace {
            let mut stages = Vec::new();
            if let Some(ua) = user_async {
                stages.push(StageSpan {
                    stage: "user_async",
                    elapsed: ua,
                });
            }
            stages.push(StageSpan {
                stage: "retrieval",
                elapsed: retrieval,
            });
            stages.push(StageSpan {
                stage: "prerank",
                elapsed: prerank,
            });
            Some(ScoreTrace {
                n_candidates: candidates.len(),
                n_batches: candidates.len().div_ceil(self.batch),
                stages,
            })
        } else {
            None
        };

        Ok(ScoreResponse {
            request_id,
            user,
            variant: self.cfg.variant.clone(),
            items: top
                .into_iter()
                .map(|(item, score)| ScoredItem { item, score })
                .collect(),
            timings,
            trace,
        })
    }

    /// The real-time phase: score all candidates through the head artifact.
    fn prerank(
        &self,
        key: RequestKey,
        user: usize,
        candidates: &[u32],
    ) -> Result<Vec<f32>> {
        let v = &self.variant;

        // -- request-level user-side tensors --------------------------------
        let ua: Option<UserAsync> = if v.user == "async" {
            Some(self.user_cache.take(key).ok_or_else(|| {
                anyhow::anyhow!("user async result missing for {key:?}")
            })?)
        } else {
            None
        };

        // Sequential-baseline user-side work (on the critical path).
        let mut profile_t = None;
        let mut seq_short_t = None;
        let mut seq_emb_t = None;
        let mut din_base_t = None;
        let mut din_g_t = None;
        let mut seq_sign_packed: Option<Arc<Vec<u8>>> = None;
        let mut seq_len = 0usize;
        let mut seq_mm_t = None;
        if v.user != "async" {
            let uf = self.store.fetch_user(user);
            profile_t = Some(Tensor::new(
                vec![1, uf.profile.len()],
                uf.profile.clone(),
            ));
            seq_short_t =
                Some(assembly::gather_seq_emb(&self.world, &uf.short_seq));
            if v.has_long() {
                // The user-side long-term projections run here, on the
                // request path, via a synchronous user_tower call
                // (Table 4 "+LSH"/"+Long-term" rows).
                let packed = packed_signs(&self.world, &uf.long_seq);
                let plane = lsh::unpack_plane(
                    &packed,
                    uf.long_seq.len(),
                    self.world.w_hash.shape()[0],
                );
                let mut inputs =
                    assembly::user_tower_inputs(&self.world, &uf);
                inputs.push(plane);
                let out = self.rtp.call("user_tower", inputs)?;
                self.metrics
                    .rtp_calls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                seq_emb_t = Some(out[2].clone());
                din_base_t = Some(out[3].clone());
                din_g_t = Some(out[4].clone());
                seq_len = uf.long_seq.len();
                seq_sign_packed = Some(Arc::new(packed));
                if v.needs_mm() {
                    seq_mm_t =
                        Some(assembly::gather_mm(&self.world, &uf.long_seq));
                }
            }
        } else if let Some(ua) = &ua {
            seq_emb_t = Some(ua.seq_emb.clone());
            din_base_t = Some(ua.din_base.clone());
            din_g_t = Some(ua.din_g.clone());
            seq_sign_packed = Some(Arc::clone(&ua.seq_sign_packed));
            seq_len = ua.long_seq.len();
            if v.needs_mm() {
                seq_mm_t =
                    Some(assembly::gather_mm(&self.world, &ua.long_seq));
            }
        }

        let (u_vec_t, bea_v_t) = match &ua {
            Some(ua) => (Some(ua.u_vec.clone()), Some(ua.bea_v.clone())),
            None => (None, None),
        };

        // -- N2O snapshot (one consistent generation per request) -----------
        let snapshot: Option<Arc<N2oSnapshot>> = if v.item == "nearline" {
            Some(Arc::new(self.n2o.snapshot()))
        } else {
            None
        };

        // -- per-mini-batch fan-out -----------------------------------------
        let batches = batcher::split(candidates, self.batch);
        let n_batches = batches.len();
        let (tx, rx) = channel::<(usize, Result<Vec<f32>>)>();
        for mb in &batches {
            let items: Vec<u32> = mb.items.to_vec();
            let index = mb.index;
            let tx = tx.clone();
            let this = self.clone_shared();
            let snapshot = snapshot.clone();
            let profile_t = profile_t.clone();
            let seq_short_t = seq_short_t.clone();
            let u_vec_t = u_vec_t.clone();
            let bea_v_t = bea_v_t.clone();
            let seq_emb_t = seq_emb_t.clone();
            let din_base_t = din_base_t.clone();
            let din_g_t = din_g_t.clone();
            let seq_sign_packed = seq_sign_packed.clone();
            let seq_mm_t = seq_mm_t.clone();
            self.score_pool.spawn(move || {
                let result = this.score_batch(
                    user,
                    &items,
                    snapshot.as_deref(),
                    BatchCtx {
                        profile: profile_t,
                        seq_short: seq_short_t,
                        u_vec: u_vec_t,
                        bea_v: bea_v_t,
                        seq_emb: seq_emb_t,
                        din_base: din_base_t,
                        din_g: din_g_t,
                        seq_sign_packed,
                        seq_len,
                        seq_mm: seq_mm_t,
                    },
                );
                let _ = tx.send((index, result));
            });
        }
        drop(tx);

        let mut per_batch: Vec<Option<Vec<f32>>> = vec![None; n_batches];
        for _ in 0..n_batches {
            let (idx, result) = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("batch worker died"))?;
            per_batch[idx] = Some(result?);
        }
        let per_batch: Vec<Vec<f32>> =
            per_batch.into_iter().map(|b| b.unwrap()).collect();
        Ok(batcher::merge_scores(candidates.len(), self.batch, &per_batch))
    }

    /// Clone the shared handles needed inside batch tasks.
    fn clone_shared(&self) -> BatchScorer {
        BatchScorer {
            variant: self.variant.clone(),
            world: Arc::clone(&self.world),
            store: Arc::clone(&self.store),
            rtp: Arc::clone(&self.rtp),
            sim_cache: Arc::clone(&self.sim_cache),
            metrics: Arc::clone(&self.metrics),
            sim_mode: self.cfg.sim_mode,
            sim_budget: self.cfg.sim_budget,
            sim_parse_us: self.cfg.sim_parse_us,
            batch: self.batch,
            n_tiers: self.manifest.dim("N_TIERS"),
            head_artifact: self.head_artifact.clone(),
        }
    }

    /// §5.3 storage accounting: extra resident bytes vs the baseline.
    pub fn extra_storage_bytes(&self) -> usize {
        let mut total = 0;
        if self.variant.item == "nearline" {
            total += self.n2o.size_bytes();
        }
        if self.cfg.sim_mode == SimMode::Precached {
            // LRU entries: ids only (parsed subsequences).
            total += self.sim_cache.len() * self.world.l_sim_sub * 4;
        }
        total += self.arena.pooled_bytes();
        total
    }
}

impl PreRanker for Merger {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        Merger::score(self, req)
    }

    fn variant_name(&self) -> &str {
        &self.cfg.variant
    }

    fn n_users(&self) -> usize {
        self.world.n_users
    }

    fn metrics(&self) -> &ServingMetrics {
        self.metrics.as_ref()
    }

    fn extra_storage_bytes(&self) -> usize {
        Merger::extra_storage_bytes(self)
    }
}

fn check_deadline(
    deadline: Option<Duration>,
    t0: Instant,
) -> Result<(), ServeError> {
    match deadline {
        Some(budget) if t0.elapsed() > budget => {
            Err(ServeError::DeadlineExceeded {
                budget_ms: budget.as_secs_f64() * 1e3,
                elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
            })
        }
        _ => Ok(()),
    }
}

/// Request-level tensors shared by every mini-batch of the request.
struct BatchCtx {
    profile: Option<Tensor>,
    seq_short: Option<Tensor>,
    u_vec: Option<Tensor>,
    bea_v: Option<Tensor>,
    seq_emb: Option<Tensor>,
    din_base: Option<Tensor>,
    din_g: Option<Tensor>,
    seq_sign_packed: Option<Arc<Vec<u8>>>,
    seq_len: usize,
    seq_mm: Option<Tensor>,
}

/// The Send-able subset of the Merger used inside batch tasks.
struct BatchScorer {
    variant: VariantSpec,
    world: Arc<World>,
    store: Arc<FeatureStore>,
    rtp: Arc<RtpPool>,
    sim_cache: Arc<ShardedLru<(u32, u32), Arc<Vec<u32>>>>,
    metrics: Arc<ServingMetrics>,
    sim_mode: SimMode,
    sim_budget: f64,
    sim_parse_us: f64,
    batch: usize,
    n_tiers: usize,
    head_artifact: String,
}

impl BatchScorer {
    fn score_batch(
        &self,
        user: usize,
        items: &[u32],
        snapshot: Option<&N2oSnapshot>,
        ctx: BatchCtx,
    ) -> Result<Vec<f32>> {
        let v = &self.variant;
        let mut inputs: Vec<Tensor> = Vec::with_capacity(8);

        // user slot
        if v.user == "async" {
            inputs.push(ctx.u_vec.clone().expect("u_vec"));
        } else {
            inputs.push(ctx.profile.clone().expect("profile"));
            inputs.push(ctx.seq_short.clone().expect("seq_short"));
        }

        // item slot (+ fetched features for inline/mm needs)
        let needs_fetch = v.item == "inline" || v.needs_mm() || v.sim_cross;
        let feats = if needs_fetch {
            Some(self.store.fetch_items(items))
        } else {
            None
        };
        let mut bea_w_nearline = None;
        let mut sign_nearline = None;
        if v.item == "nearline" {
            let snap = snapshot.expect("nearline snapshot");
            let (vec_t, w_t, s_t) = snap
                .assemble(items, self.batch)
                .ok_or_else(|| anyhow::anyhow!("N2O rows missing"))?;
            inputs.push(vec_t);
            bea_w_nearline = Some(w_t);
            sign_nearline = Some(s_t);
        } else {
            inputs.push(assembly::item_raw_batch(
                feats.as_ref().unwrap(),
                self.batch,
            ));
        }

        // BEA slot
        if v.bea == "bridge" {
            inputs.push(ctx.bea_v.clone().expect("bea_v"));
            if v.item == "nearline" {
                inputs.push(bea_w_nearline.clone().expect("bea_w"));
            }
        }

        // long-term slot
        if v.tiers_precomputed() {
            // Hoisted serving split: DIN factors from the async pass +
            // SimTier via uint8 XNOR + popcount LUT (§4.2).  No [L, .]
            // operand is assembled at all.
            let item_packed =
                packed_signs_padded(&self.world, items, self.batch);
            let n_bits = self.world.w_hash.shape()[0];
            let item_sign = match &sign_nearline {
                Some(s) => s.clone(),
                None => lsh::unpack_plane(&item_packed, self.batch, n_bits),
            };
            inputs.push(ctx.din_base.clone().expect("din_base"));
            inputs.push(ctx.din_g.clone().expect("din_g"));
            inputs.push(item_sign);
            let seq_packed =
                ctx.seq_sign_packed.as_ref().expect("seq packed");
            let hist = lsh::tier_histogram(
                &item_packed,
                self.batch,
                seq_packed,
                ctx.seq_len,
                n_bits,
                self.n_tiers,
            );
            inputs.push(Tensor::new(vec![self.batch, self.n_tiers], hist));
        } else if v.has_long() {
            inputs.push(ctx.seq_emb.clone().expect("seq_emb"));
            if v.needs_lsh() {
                unreachable!("mixed lsh variants are not served");
            }
            if v.needs_mm() {
                inputs.push(assembly::item_mm_batch(
                    feats.as_ref().unwrap(),
                    self.batch,
                ));
                inputs.push(ctx.seq_mm.clone().expect("seq_mm"));
            }
        }

        // SIM cross slot
        if v.sim_cross {
            let cats: Vec<u32> = items
                .iter()
                .map(|&i| self.world.category_of(i))
                .collect();
            let store = &self.store;
            let world = &self.world;
            let sim_cache = &self.sim_cache;
            let (mode, budget, parse_us) =
                (self.sim_mode, self.sim_budget, self.sim_parse_us);
            let t = assembly::sim_cross_batch(
                world,
                &cats,
                self.batch,
                |cat| match mode {
                    SimMode::Off => Vec::new(),
                    SimMode::Sync => store.fetch_sim_subsequence(
                        user, cat, budget, parse_us,
                    ),
                    SimMode::Precached => sim_cache
                        .get_or_insert_with((user as u32, cat), || {
                            Arc::new(store.fetch_sim_subsequence(
                                user, cat, budget, parse_us,
                            ))
                        })
                        .as_ref()
                        .clone(),
                },
            );
            inputs.push(t);
        }

        let scores = self.rtp.call1(&self.head_artifact, inputs)?;
        self.metrics
            .rtp_calls
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(scores.data().to_vec())
    }
}

/// Expected head-input names, mirroring python `model.serving_inputs`.
pub fn expected_input_names(v: &VariantSpec) -> Vec<String> {
    let mut sig: Vec<&str> = Vec::new();
    if v.user == "async" {
        sig.push("u_vec");
    } else {
        sig.push("profile");
        sig.push("seq_short");
    }
    if v.item == "nearline" {
        sig.push("item_vec");
    } else {
        sig.push("item_raw");
    }
    if v.bea == "bridge" {
        sig.push("bea_v");
        if v.item == "nearline" {
            sig.push("bea_w");
        }
    }
    if v.tiers_precomputed() {
        sig.push("din_base");
        sig.push("din_g");
        sig.push("item_sign");
        sig.push("tiers_in");
    } else if v.has_long() {
        sig.push("seq_emb");
        if v.needs_lsh() {
            sig.push("item_sign");
            sig.push("seq_sign");
        }
        if v.needs_mm() {
            sig.push("item_mm");
            sig.push("seq_mm");
        }
    }
    if v.sim_cross {
        sig.push("sim_cross");
    }
    sig.into_iter().map(String::from).collect()
}

/// Packed signature rows for a sequence of item ids (static table).
pub fn packed_signs(world: &World, items: &[u32]) -> Vec<u8> {
    let pl = world.w_hash.shape()[0].div_ceil(8);
    let mut packed = Vec::with_capacity(items.len() * pl);
    for &i in items {
        packed.extend_from_slice(world.items_sign_packed.u8_row(i as usize));
    }
    packed
}

/// Same, padded to `batch` rows by repeating the last item.
pub fn packed_signs_padded(world: &World, items: &[u32], batch: usize) -> Vec<u8> {
    let mut packed = packed_signs(world, items);
    let last = world
        .items_sign_packed
        .u8_row(items[items.len() - 1] as usize);
    for _ in items.len()..batch {
        packed.extend_from_slice(last);
    }
    packed
}
