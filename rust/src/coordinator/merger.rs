//! The Merger — the system's central coordinator (paper §3.1, Figures 2-5).
//!
//! One config-driven request pipeline covers the sequential baseline and
//! every AIF increment of Table 4:
//!
//! ```text
//! score(request):
//!   phase 1 (only if variant.user == "async"):
//!       ├─ fetch user features ─ user_tower on the consistent-hashed RTP
//!       │  worker ─ cache UserAsync under hash(request_id, nickname)
//!       ├─ pre-warm the SIM LRU for every user-category combination
//!       └─ ... all OVERLAPPED with the retrieval stage
//!   retrieval (blocks for the modeled upstream latency)
//!   phase 2 (real-time pre-rank):
//!       ├─ take cached UserAsync (or fetch/compute user-side inline —
//!       │  the sequential baseline path)
//!       ├─ split candidates into mini-batches; per batch, concurrently:
//!       │    fetch item features (inline variants) or read the N2O
//!       │    snapshot (nearline variants), assemble head inputs, execute
//!       │    the head artifact on the RTP fleet
//!       └─ merge scores, top-K
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher;
use super::router::Router;
use super::service::{
    PreRanker, ScoreRequest, ScoreResponse, ScoreTrace, ScoredItem,
    ServeError, StageSpan,
};
use crate::cache::{ArenaPool, RequestKey, ShardedLru, UserAsync, UserVecCache};
use crate::config::{ServingConfig, SimMode};
use crate::features::{assembly, FeatureStore, World};
use crate::lsh::{self, Hasher};
use crate::metrics::ServingMetrics;
use crate::nearline::{N2oSnapshot, N2oTable, NearlineWorker};
use crate::retrieval::Retriever;
use crate::runtime::{
    BatchCoalescer, CoalescerConfig, HeadExecutor, HeadJob, Manifest,
    RtpPool, Tensor, VariantSpec,
};
use crate::util::threadpool::ThreadPool;

/// Auto-allocated request ids live at and above this bound; callers must
/// stay below it so the two spaces can never alias a `RequestKey`.
pub const AUTO_REQUEST_ID_BASE: u64 = 1 << 63;

/// Per-request phase timings.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTimings {
    pub total: Duration,
    pub retrieval: Duration,
    pub user_async: Option<Duration>,
    pub prerank: Duration,
}

#[derive(Debug)]
pub struct RequestResult {
    pub top_k: Vec<(u32, f32)>,
    pub timings: PhaseTimings,
}

pub struct Merger {
    pub cfg: ServingConfig,
    pub manifest: Arc<Manifest>,
    pub variant: VariantSpec,
    pub world: Arc<World>,
    pub store: Arc<FeatureStore>,
    pub retriever: Arc<Retriever>,
    pub rtp: Arc<RtpPool>,
    pub router: Router,
    pub user_cache: Arc<UserVecCache>,
    /// (user, category) -> parsed SIM subsequence.
    pub sim_cache: Arc<ShardedLru<(u32, u32), Arc<Vec<u32>>>>,
    pub n2o: Arc<N2oTable>,
    pub hasher: Arc<Hasher>,
    pub arena: Arc<ArenaPool>,
    pub metrics: Arc<ServingMetrics>,
    async_pool: Arc<ThreadPool>,
    score_pool: Arc<ThreadPool>,
    pub batch: usize,
    head_artifact: String,
    /// Cross-request dispatch scheduler + the `*_mu` artifact it serves
    /// (None = sequential per-request executions, the baseline path).
    coalescer: Option<Arc<BatchCoalescer>>,
    mu_artifact: Option<String>,
    /// Request-id allocator for requests that don't bring their own.
    /// Lives in the top half of the id space so auto-allocated ids can
    /// never collide with caller-supplied ones (which would alias
    /// `RequestKey`s in the async-variant user cache).
    req_ids: AtomicU64,
}

impl Merger {
    /// Bring up the full serving stack for one pipeline configuration.
    /// Runs the nearline full build when the variant reads the N2O table.
    pub fn build(cfg: ServingConfig) -> Result<Merger> {
        let manifest = Arc::new(Manifest::load(&cfg.artifacts_dir)?);
        let variant = manifest.variant(&cfg.variant)?.clone();
        let world = Arc::new(World::load(&manifest)?);
        let store = Arc::new(FeatureStore::new(
            Arc::clone(&world),
            cfg.user_store_latency.clone(),
            cfg.item_store_latency.clone(),
        ));
        let retriever = Arc::new(Retriever::new(
            Arc::clone(&world),
            cfg.n_candidates,
            cfg.retrieval_latency.clone(),
        ));

        // Artifact set this pipeline needs.
        let mut artifacts = vec![variant.artifact.clone()];
        if variant.user == "async" || variant.has_long() {
            // The user tower also supplies seq_emb for the non-async
            // long-term rows (computed on the request path there).
            artifacts.push("user_tower".into());
        }
        if variant.item == "nearline" {
            artifacts.push("item_tower".into());
        }
        // Cross-request coalescing rides on the multi-user (`*_mu`) head
        // flavor; resolve it before the fleet spins up so every worker
        // compiles it.  Absence (older artifact sets) degrades to the
        // per-request path with a warning instead of failing startup.
        let mu_artifact = if cfg.coalesce.enabled {
            let name = format!("{}_mu", variant.artifact);
            if !coalesce_eligible(&variant) {
                log::warn!(
                    "coalescing requested but variant {} is not eligible \
                     (needs async user + precomputable long-term head); \
                     serving per-request executions",
                    variant.name
                );
                None
            } else if !manifest.artifacts.contains_key(&name) {
                log::warn!(
                    "coalescing requested but artifact {name:?} is not in \
                     the manifest (re-run `make artifacts`); serving \
                     per-request executions"
                );
                None
            } else {
                Some(name)
            }
        } else {
            None
        };
        if let Some(name) = &mu_artifact {
            artifacts.push(name.clone());
        }
        let rtp = Arc::new(RtpPool::new(
            Arc::clone(&manifest),
            artifacts,
            cfg.n_rtp_workers,
        ));

        let hasher = Arc::new(Hasher::from_table(&world.w_hash));
        let batch = manifest.batch;
        let n2o = Arc::new(N2oTable::new(
            world.n_items,
            manifest.dim("D"),
            manifest.dim("N_BRIDGE"),
            manifest.dim("D_LSH_BITS"),
        ));
        if variant.item == "nearline" {
            let worker = NearlineWorker::new(
                Arc::clone(&rtp),
                Arc::clone(&world),
                Arc::clone(&hasher),
                Arc::clone(&n2o),
                batch,
            );
            let report = worker.full_build(1).context("nearline full build")?;
            log::info!(
                "N2O full build: {} items, {} executions, {:?}, {} bytes",
                report.n_items,
                report.executions,
                report.elapsed,
                report.table_bytes
            );
        }

        // Validate the head signature against what we will assemble.
        let expected = expected_input_names(&variant);
        let actual: Vec<String> = manifest
            .artifact(&variant.artifact)?
            .inputs
            .iter()
            .map(|s| s.name.clone())
            .collect();
        anyhow::ensure!(
            expected == actual,
            "head {} signature mismatch: assembling {expected:?}, \
             manifest says {actual:?}",
            variant.artifact
        );

        // Bring up the coalescer against the validated `_mu` signature.
        let metrics = Arc::new(ServingMetrics::new());
        let coalescer = match &mu_artifact {
            Some(name) => {
                let spec = manifest.artifact(name)?;
                let expected_mu = expected_input_names_mu(&variant);
                let actual_mu: Vec<String> =
                    spec.inputs.iter().map(|s| s.name.clone()).collect();
                anyhow::ensure!(
                    expected_mu == actual_mu,
                    "coalesced head {name} signature mismatch: assembling \
                     {expected_mu:?}, manifest says {actual_mu:?}"
                );
                let exec_rows = spec.outputs[0].shape[0];
                let max_slots = spec.inputs[0].shape[0];
                anyhow::ensure!(
                    exec_rows >= batch && max_slots >= 1,
                    "coalesced head {name}: {exec_rows} rows / {max_slots} \
                     slots cannot hold a {batch}-row mini-batch"
                );
                let max_rows = match cfg.coalesce.max_coalesced_batch {
                    0 => exec_rows,
                    n => n.clamp(batch, exec_rows),
                };
                Some(Arc::new(BatchCoalescer::new(
                    Arc::clone(&rtp) as Arc<dyn HeadExecutor>,
                    CoalescerConfig {
                        exec_rows,
                        max_rows,
                        max_slots,
                        window: Duration::from_micros(
                            cfg.coalesce.window_us,
                        ),
                        bypass_margin: Duration::from_secs_f64(
                            cfg.coalesce.bypass_margin_ms / 1e3,
                        ),
                    },
                    Arc::clone(&metrics.coalesce),
                )))
            }
            None => None,
        };

        Ok(Merger {
            router: Router::new(cfg.n_rtp_workers, 64),
            user_cache: Arc::new(UserVecCache::new(cfg.user_cache_shards)),
            sim_cache: Arc::new(ShardedLru::new(
                cfg.lru_capacity,
                cfg.lru_shards,
            )),
            arena: ArenaPool::new(cfg.arena_retain),
            metrics,
            async_pool: Arc::new(ThreadPool::new(cfg.n_async_workers)),
            // Batch-scoring tasks block on RTP replies; give them their own
            // pool (2x the fleet) so they never starve the phase-1 tasks.
            score_pool: Arc::new(ThreadPool::new(cfg.n_rtp_workers + 2)),
            head_artifact: variant.artifact.clone(),
            coalescer,
            mu_artifact,
            req_ids: AtomicU64::new(AUTO_REQUEST_ID_BASE),
            manifest,
            variant,
            world,
            store,
            retriever,
            rtp,
            n2o,
            hasher,
            batch,
            cfg,
        })
    }

    fn nickname(user: usize) -> String {
        format!("user-{user}")
    }

    /// Pre-typed-API entry point, kept as a one-line compatibility shim.
    /// The old API accepted the full u64 id space; ids are masked into
    /// the caller half so the typed path's auto-id guard holds.
    #[deprecated(note = "use `score(ScoreRequest::user(user))`")]
    pub fn handle(&self, request_id: u64, user: usize) -> Result<RequestResult> {
        let id = request_id % AUTO_REQUEST_ID_BASE;
        let resp =
            self.score(ScoreRequest::user(user).with_request_id(id))?;
        Ok(RequestResult {
            top_k: resp.items.iter().map(|s| (s.item, s.score)).collect(),
            timings: resp.timings,
        })
    }

    /// Serve one request end to end through the typed contract.
    pub fn score(
        &self,
        req: ScoreRequest,
    ) -> Result<ScoreResponse, ServeError> {
        let result = self.serve(&req);
        if result.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn serve(&self, req: &ScoreRequest) -> Result<ScoreResponse, ServeError> {
        let t_total = Instant::now();

        // ---- validation (before any work is scheduled) -------------------
        let user = req.user;
        if user >= self.world.n_users {
            return Err(ServeError::UnknownUser(user));
        }
        let top_k = req.top_k.unwrap_or(self.cfg.top_k);
        if top_k == 0 {
            return Err(ServeError::BadRequest("top_k must be >= 1".into()));
        }
        if let Some(cands) = &req.candidates {
            if cands.is_empty() {
                return Err(ServeError::BadRequest(
                    "candidate override must be non-empty".into(),
                ));
            }
            if let Some(&bad) =
                cands.iter().find(|&&i| (i as usize) >= self.world.n_items)
            {
                return Err(ServeError::BadRequest(format!(
                    "unknown candidate item {bad}"
                )));
            }
        }
        if let Some(id) = req.request_id {
            if id >= AUTO_REQUEST_ID_BASE {
                return Err(ServeError::BadRequest(format!(
                    "request_id must be < 2^63 (got {id}; the top half \
                     is the auto-id space)"
                )));
            }
        }
        let request_id = req
            .request_id
            .unwrap_or_else(|| self.req_ids.fetch_add(1, Ordering::Relaxed));
        let key = RequestKey::new(request_id, &Self::nickname(user));
        let worker = self.router.route(key.0);

        // ---- phase 1: online asynchronous user-side inference -----------
        let async_done = if self.variant.user == "async" {
            let (tx, rx) = channel::<Result<Duration>>();
            let store = Arc::clone(&self.store);
            let world = Arc::clone(&self.world);
            let rtp = Arc::clone(&self.rtp);
            let cache = Arc::clone(&self.user_cache);
            let key2 = key;
            self.async_pool.spawn(move || {
                let t0 = Instant::now();
                let result = (|| -> Result<()> {
                    let uf = store.fetch_user(user);
                    // Signatures of the long-term sequence (static table):
                    // packed bytes feed the SimTier popcount path; the ±1
                    // plane goes into the tower so it can emit the
                    // linearized DIN factors.
                    let packed = packed_signs(&world, &uf.long_seq);
                    let plane = lsh::unpack_plane(
                        &packed,
                        uf.long_seq.len(),
                        world.w_hash.shape()[0],
                    );
                    let mut inputs =
                        assembly::user_tower_inputs(&world, &uf);
                    inputs.push(plane);
                    let rx2 = rtp.call_async_on(worker, "user_tower", inputs);
                    let out = rx2
                        .recv()
                        .map_err(|_| anyhow::anyhow!("RTP reply dropped"))??;
                    cache.put(
                        key2,
                        UserAsync {
                            u_vec: out[0].clone(),
                            bea_v: out[1].clone(),
                            seq_emb: out[2].clone(),
                            din_base: out[3].clone(),
                            din_g: out[4].clone(),
                            seq_sign_packed: Arc::new(packed),
                            long_seq: uf.long_seq,
                        },
                    );
                    Ok(())
                })();
                let _ = tx.send(result.map(|()| t0.elapsed()));
            });
            Some(rx)
        } else {
            None
        };

        // SIM pre-warming runs alongside retrieval too.
        if self.variant.sim_cross && self.cfg.sim_mode == SimMode::Precached {
            let store = Arc::clone(&self.store);
            let world = Arc::clone(&self.world);
            let sim_cache = Arc::clone(&self.sim_cache);
            let budget = self.cfg.sim_budget;
            let parse_us = self.cfg.sim_parse_us;
            self.async_pool.spawn(move || {
                // Only hit the remote store if any of the user's categories
                // is cold; one multi-get covers them all (Figure 5).
                let cats = world.user_sim_categories(user);
                let cold = cats.iter().any(|&c| {
                    sim_cache.get(&(user as u32, c)).is_none()
                });
                if cold {
                    for (cat, sub) in
                        store.fetch_sim_all(user, budget, parse_us)
                    {
                        sim_cache.insert((user as u32, cat), Arc::new(sub));
                    }
                }
            });
        }

        // ---- retrieval (upstream stage; blocks) -------------------------
        // A candidate override skips the retrieval stage entirely (the
        // caller already knows what to score) but keeps the phase-1 overlap.
        let t_r = Instant::now();
        let candidates = match &req.candidates {
            Some(c) => c.clone(),
            None => self.retriever.retrieve(user),
        };
        let retrieval = t_r.elapsed();

        // ---- join phase 1 -------------------------------------------------
        let user_async = match async_done {
            Some(rx) => Some(rx.recv().map_err(|_| {
                ServeError::Internal("async phase died".into())
            })??),
            None => None,
        };

        // ---- deadline gate before the pre-rank phase ---------------------
        if let Err(e) = check_deadline(req.deadline, t_total) {
            // The async result was parked for phase 2; drop it so an
            // abandoned request doesn't leak a cache entry.
            if self.variant.user == "async" {
                let _ = self.user_cache.take(key);
            }
            return Err(e);
        }

        // ---- phase 2: real-time pre-ranking ------------------------------
        let t_p = Instant::now();
        let deadline_at = req.deadline.map(|budget| t_total + budget);
        let (scores, coalesce) =
            self.prerank(key, user, &candidates, deadline_at)?;
        let prerank = t_p.elapsed();
        check_deadline(req.deadline, t_total)?;

        let top = batcher::top_k(&candidates, &scores, top_k);
        let timings = PhaseTimings {
            total: t_total.elapsed(),
            retrieval,
            user_async,
            prerank,
        };
        self.metrics.record_request(
            timings.total,
            timings.prerank,
            timings.user_async,
            timings.retrieval,
        );
        self.metrics
            .items_scored
            .fetch_add(candidates.len() as u64, Ordering::Relaxed);

        let trace = if req.trace {
            let mut stages = Vec::new();
            if let Some(ua) = user_async {
                stages.push(StageSpan {
                    stage: "user_async",
                    elapsed: ua,
                });
            }
            stages.push(StageSpan {
                stage: "retrieval",
                elapsed: retrieval,
            });
            stages.push(StageSpan {
                stage: "prerank",
                elapsed: prerank,
            });
            if coalesce.batches > 0 {
                stages.push(StageSpan {
                    stage: "coalesce_wait",
                    elapsed: coalesce.max_queue_wait,
                });
            }
            Some(ScoreTrace {
                n_candidates: candidates.len(),
                n_batches: candidates.len().div_ceil(self.batch),
                coalesced_batches: coalesce.batches,
                stages,
            })
        } else {
            None
        };

        Ok(ScoreResponse {
            request_id,
            user,
            variant: self.cfg.variant.clone(),
            items: top
                .into_iter()
                .map(|(item, score)| ScoredItem { item, score })
                .collect(),
            timings,
            trace,
        })
    }

    /// The real-time phase: score all candidates through the head artifact.
    fn prerank(
        &self,
        key: RequestKey,
        user: usize,
        candidates: &[u32],
        deadline: Option<Instant>,
    ) -> Result<(Vec<f32>, CoalesceAgg)> {
        let v = &self.variant;

        // -- request-level user-side tensors --------------------------------
        let ua: Option<UserAsync> = if v.user == "async" {
            Some(self.user_cache.take(key).ok_or_else(|| {
                anyhow::anyhow!("user async result missing for {key:?}")
            })?)
        } else {
            None
        };

        // Sequential-baseline user-side work (on the critical path).
        let mut profile_t = None;
        let mut seq_short_t = None;
        let mut seq_emb_t = None;
        let mut din_base_t = None;
        let mut din_g_t = None;
        let mut seq_sign_packed: Option<Arc<Vec<u8>>> = None;
        let mut seq_len = 0usize;
        let mut seq_mm_t = None;
        if v.user != "async" {
            let uf = self.store.fetch_user(user);
            profile_t = Some(Tensor::new(
                vec![1, uf.profile.len()],
                uf.profile.clone(),
            ));
            seq_short_t =
                Some(assembly::gather_seq_emb(&self.world, &uf.short_seq));
            if v.has_long() {
                // The user-side long-term projections run here, on the
                // request path, via a synchronous user_tower call
                // (Table 4 "+LSH"/"+Long-term" rows).
                let packed = packed_signs(&self.world, &uf.long_seq);
                let plane = lsh::unpack_plane(
                    &packed,
                    uf.long_seq.len(),
                    self.world.w_hash.shape()[0],
                );
                let mut inputs =
                    assembly::user_tower_inputs(&self.world, &uf);
                inputs.push(plane);
                let out = self.rtp.call("user_tower", inputs)?;
                self.metrics
                    .rtp_calls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                seq_emb_t = Some(out[2].clone());
                din_base_t = Some(out[3].clone());
                din_g_t = Some(out[4].clone());
                seq_len = uf.long_seq.len();
                seq_sign_packed = Some(Arc::new(packed));
                if v.needs_mm() {
                    seq_mm_t =
                        Some(assembly::gather_mm(&self.world, &uf.long_seq));
                }
            }
        } else if let Some(ua) = &ua {
            seq_emb_t = Some(ua.seq_emb.clone());
            din_base_t = Some(ua.din_base.clone());
            din_g_t = Some(ua.din_g.clone());
            seq_sign_packed = Some(Arc::clone(&ua.seq_sign_packed));
            seq_len = ua.long_seq.len();
            if v.needs_mm() {
                seq_mm_t =
                    Some(assembly::gather_mm(&self.world, &ua.long_seq));
            }
        }

        let (u_vec_t, bea_v_t) = match &ua {
            Some(ua) => (Some(ua.u_vec.clone()), Some(ua.bea_v.clone())),
            None => (None, None),
        };

        // -- N2O snapshot (one consistent generation per request) -----------
        let snapshot: Option<Arc<N2oSnapshot>> = if v.item == "nearline" {
            Some(Arc::new(self.n2o.snapshot()))
        } else {
            None
        };

        // -- per-mini-batch fan-out -----------------------------------------
        let batches = batcher::split(candidates, self.batch);
        let n_batches = batches.len();
        let (tx, rx) = channel::<(usize, Result<BatchOutcome>)>();
        for mb in &batches {
            let items: Vec<u32> = mb.items.to_vec();
            let index = mb.index;
            let tx = tx.clone();
            let this = self.clone_shared();
            let snapshot = snapshot.clone();
            let profile_t = profile_t.clone();
            let seq_short_t = seq_short_t.clone();
            let u_vec_t = u_vec_t.clone();
            let bea_v_t = bea_v_t.clone();
            let seq_emb_t = seq_emb_t.clone();
            let din_base_t = din_base_t.clone();
            let din_g_t = din_g_t.clone();
            let seq_sign_packed = seq_sign_packed.clone();
            let seq_mm_t = seq_mm_t.clone();
            self.score_pool.spawn(move || {
                let result = this.score_batch(
                    user,
                    &items,
                    snapshot.as_deref(),
                    BatchCtx {
                        profile: profile_t,
                        seq_short: seq_short_t,
                        u_vec: u_vec_t,
                        bea_v: bea_v_t,
                        seq_emb: seq_emb_t,
                        din_base: din_base_t,
                        din_g: din_g_t,
                        seq_sign_packed,
                        seq_len,
                        seq_mm: seq_mm_t,
                        deadline,
                    },
                );
                let _ = tx.send((index, result));
            });
        }
        drop(tx);

        let mut per_batch: Vec<Option<Vec<f32>>> = vec![None; n_batches];
        let mut agg = CoalesceAgg::default();
        for _ in 0..n_batches {
            let (idx, result) = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("batch worker died"))?;
            let outcome = result?;
            if let Some(wait) = outcome.queue_wait {
                agg.batches += 1;
                agg.max_queue_wait = agg.max_queue_wait.max(wait);
            }
            per_batch[idx] = Some(outcome.scores);
        }
        let per_batch: Vec<Vec<f32>> =
            per_batch.into_iter().map(|b| b.unwrap()).collect();
        Ok((
            batcher::merge_scores(candidates.len(), self.batch, &per_batch),
            agg,
        ))
    }

    /// Clone the shared handles needed inside batch tasks.
    fn clone_shared(&self) -> BatchScorer {
        BatchScorer {
            variant: self.variant.clone(),
            world: Arc::clone(&self.world),
            store: Arc::clone(&self.store),
            rtp: Arc::clone(&self.rtp),
            sim_cache: Arc::clone(&self.sim_cache),
            metrics: Arc::clone(&self.metrics),
            sim_mode: self.cfg.sim_mode,
            sim_budget: self.cfg.sim_budget,
            sim_parse_us: self.cfg.sim_parse_us,
            batch: self.batch,
            n_tiers: self.manifest.dim("N_TIERS"),
            head_artifact: self.head_artifact.clone(),
            coalescer: self.coalescer.clone(),
            mu_artifact: self.mu_artifact.clone(),
        }
    }

    /// Whether this pipeline is routing head executions through the
    /// cross-request coalescer.
    pub fn coalescing(&self) -> bool {
        self.coalescer.is_some()
    }

    /// §5.3 storage accounting: extra resident bytes vs the baseline.
    pub fn extra_storage_bytes(&self) -> usize {
        let mut total = 0;
        if self.variant.item == "nearline" {
            total += self.n2o.size_bytes();
        }
        if self.cfg.sim_mode == SimMode::Precached {
            // LRU entries: ids only (parsed subsequences).
            total += self.sim_cache.len() * self.world.l_sim_sub * 4;
        }
        total += self.arena.pooled_bytes();
        total
    }
}

impl PreRanker for Merger {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        Merger::score(self, req)
    }

    fn variant_name(&self) -> &str {
        &self.cfg.variant
    }

    fn n_users(&self) -> usize {
        self.world.n_users
    }

    fn metrics(&self) -> &ServingMetrics {
        self.metrics.as_ref()
    }

    fn extra_storage_bytes(&self) -> usize {
        Merger::extra_storage_bytes(self)
    }
}

fn check_deadline(
    deadline: Option<Duration>,
    t0: Instant,
) -> Result<(), ServeError> {
    match deadline {
        Some(budget) if t0.elapsed() > budget => {
            Err(ServeError::DeadlineExceeded {
                budget_ms: budget.as_secs_f64() * 1e3,
                elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
            })
        }
        _ => Ok(()),
    }
}

/// Per-request aggregate of the coalesced dispatch path (zeroed when the
/// request ran plain per-request executions).
#[derive(Debug, Clone, Copy, Default)]
pub struct CoalesceAgg {
    /// Mini-batches of this request that went through the coalescer.
    pub batches: usize,
    /// Worst queue dwell any of them paid.
    pub max_queue_wait: Duration,
}

/// One mini-batch's scores plus how its execution was dispatched.
struct BatchOutcome {
    scores: Vec<f32>,
    /// Some(wait) when the batch went through the coalescer.
    queue_wait: Option<Duration>,
}

/// Request-level tensors shared by every mini-batch of the request.
struct BatchCtx {
    profile: Option<Tensor>,
    seq_short: Option<Tensor>,
    u_vec: Option<Tensor>,
    bea_v: Option<Tensor>,
    seq_emb: Option<Tensor>,
    din_base: Option<Tensor>,
    din_g: Option<Tensor>,
    seq_sign_packed: Option<Arc<Vec<u8>>>,
    seq_len: usize,
    seq_mm: Option<Tensor>,
    /// Absolute request deadline, for the coalescer's bypass decision.
    deadline: Option<Instant>,
}

/// The Send-able subset of the Merger used inside batch tasks.
struct BatchScorer {
    variant: VariantSpec,
    world: Arc<World>,
    store: Arc<FeatureStore>,
    rtp: Arc<RtpPool>,
    sim_cache: Arc<ShardedLru<(u32, u32), Arc<Vec<u32>>>>,
    metrics: Arc<ServingMetrics>,
    sim_mode: SimMode,
    sim_budget: f64,
    sim_parse_us: f64,
    batch: usize,
    n_tiers: usize,
    head_artifact: String,
    coalescer: Option<Arc<BatchCoalescer>>,
    mu_artifact: Option<String>,
}

impl BatchScorer {
    fn score_batch(
        &self,
        user: usize,
        items: &[u32],
        snapshot: Option<&N2oSnapshot>,
        ctx: BatchCtx,
    ) -> Result<BatchOutcome> {
        let v = &self.variant;
        let mut inputs: Vec<Tensor> = Vec::with_capacity(8);

        // user slot
        if v.user == "async" {
            inputs.push(ctx.u_vec.clone().expect("u_vec"));
        } else {
            inputs.push(ctx.profile.clone().expect("profile"));
            inputs.push(ctx.seq_short.clone().expect("seq_short"));
        }

        // item slot (+ fetched features for inline/mm needs)
        let needs_fetch = v.item == "inline" || v.needs_mm() || v.sim_cross;
        let feats = if needs_fetch {
            Some(self.store.fetch_items(items))
        } else {
            None
        };
        let mut bea_w_nearline = None;
        let mut sign_nearline = None;
        if v.item == "nearline" {
            let snap = snapshot.expect("nearline snapshot");
            let (vec_t, w_t, s_t) = snap
                .assemble(items, self.batch)
                .ok_or_else(|| anyhow::anyhow!("N2O rows missing"))?;
            inputs.push(vec_t);
            bea_w_nearline = Some(w_t);
            sign_nearline = Some(s_t);
        } else {
            inputs.push(assembly::item_raw_batch(
                feats.as_ref().unwrap(),
                self.batch,
            ));
        }

        // BEA slot
        if v.bea == "bridge" {
            inputs.push(ctx.bea_v.clone().expect("bea_v"));
            if v.item == "nearline" {
                inputs.push(bea_w_nearline.clone().expect("bea_w"));
            }
        }

        // long-term slot
        if v.tiers_precomputed() {
            // Hoisted serving split: DIN factors from the async pass +
            // SimTier via uint8 XNOR + popcount LUT (§4.2).  No [L, .]
            // operand is assembled at all.
            let item_packed =
                packed_signs_padded(&self.world, items, self.batch);
            let n_bits = self.world.w_hash.shape()[0];
            let item_sign = match &sign_nearline {
                Some(s) => s.clone(),
                None => lsh::unpack_plane(&item_packed, self.batch, n_bits),
            };
            inputs.push(ctx.din_base.clone().expect("din_base"));
            inputs.push(ctx.din_g.clone().expect("din_g"));
            inputs.push(item_sign);
            let seq_packed =
                ctx.seq_sign_packed.as_ref().expect("seq packed");
            let hist = lsh::tier_histogram(
                &item_packed,
                self.batch,
                seq_packed,
                ctx.seq_len,
                n_bits,
                self.n_tiers,
            );
            inputs.push(Tensor::new(vec![self.batch, self.n_tiers], hist));
        } else if v.has_long() {
            inputs.push(ctx.seq_emb.clone().expect("seq_emb"));
            if v.needs_lsh() {
                unreachable!("mixed lsh variants are not served");
            }
            if v.needs_mm() {
                inputs.push(assembly::item_mm_batch(
                    feats.as_ref().unwrap(),
                    self.batch,
                ));
                inputs.push(ctx.seq_mm.clone().expect("seq_mm"));
            }
        }

        // SIM cross slot
        if v.sim_cross {
            let cats: Vec<u32> = items
                .iter()
                .map(|&i| self.world.category_of(i))
                .collect();
            let store = &self.store;
            let world = &self.world;
            let sim_cache = &self.sim_cache;
            let (mode, budget, parse_us) =
                (self.sim_mode, self.sim_budget, self.sim_parse_us);
            let t = assembly::sim_cross_batch(
                world,
                &cats,
                self.batch,
                |cat| match mode {
                    SimMode::Off => Vec::new(),
                    SimMode::Sync => store.fetch_sim_subsequence(
                        user, cat, budget, parse_us,
                    ),
                    SimMode::Precached => sim_cache
                        .get_or_insert_with((user as u32, cat), || {
                            Arc::new(store.fetch_sim_subsequence(
                                user, cat, budget, parse_us,
                            ))
                        })
                        .as_ref()
                        .clone(),
                },
            );
            inputs.push(t);
        }

        // Dispatch: through the cross-request coalescer when enabled, as
        // a plain per-request execution otherwise.  Both paths score the
        // same rows through the same math — coalescing is score-invariant
        // (the bench pins identical top-K with the knob on and off).
        if let (Some(co), Some(mu)) = (&self.coalescer, &self.mu_artifact) {
            let (user_inputs, row_inputs) =
                split_head_inputs(&self.variant, inputs);
            let (reply, rx) = channel();
            co.submit(HeadJob {
                artifact: mu.clone(),
                rows: items.len(),
                row_inputs,
                user_inputs,
                deadline: ctx.deadline,
                reply,
            });
            let js = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("coalescer dropped the reply"))??;
            return Ok(BatchOutcome {
                scores: js.scores,
                queue_wait: Some(js.queue_wait),
            });
        }

        let scores = self.rtp.call1(&self.head_artifact, inputs)?;
        self.metrics
            .rtp_calls
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(BatchOutcome {
            scores: scores.data().to_vec(),
            queue_wait: None,
        })
    }
}

/// Expected head-input names, mirroring python `model.serving_inputs`.
pub fn expected_input_names(v: &VariantSpec) -> Vec<String> {
    let mut sig: Vec<&str> = Vec::new();
    if v.user == "async" {
        sig.push("u_vec");
    } else {
        sig.push("profile");
        sig.push("seq_short");
    }
    if v.item == "nearline" {
        sig.push("item_vec");
    } else {
        sig.push("item_raw");
    }
    if v.bea == "bridge" {
        sig.push("bea_v");
        if v.item == "nearline" {
            sig.push("bea_w");
        }
    }
    if v.tiers_precomputed() {
        sig.push("din_base");
        sig.push("din_g");
        sig.push("item_sign");
        sig.push("tiers_in");
    } else if v.has_long() {
        sig.push("seq_emb");
        if v.needs_lsh() {
            sig.push("item_sign");
            sig.push("seq_sign");
        }
        if v.needs_mm() {
            sig.push("item_mm");
            sig.push("seq_mm");
        }
    }
    if v.sim_cross {
        sig.push("sim_cross");
    }
    sig.into_iter().map(String::from).collect()
}

/// Whether a variant's head can serve coalesced multi-user batches.  The
/// `_mu` artifact gathers per-row user context by a `row_user` index, so
/// the request-level operands must be compact: the async user vector plus
/// (for long-term variants) the hoisted DIN factors.  Variants that feed
/// `[L, .]` sequence operands into the head cannot coalesce.
pub fn coalesce_eligible(v: &VariantSpec) -> bool {
    v.user == "async" && (!v.has_long() || v.tiers_precomputed())
}

/// Head inputs that are request-level (one slot per request in the `_mu`
/// artifact) as opposed to row-aligned.
fn is_user_level_input(name: &str) -> bool {
    matches!(
        name,
        "u_vec"
            | "bea_v"
            | "din_base"
            | "din_g"
            | "profile"
            | "seq_short"
            | "seq_emb"
            | "seq_sign"
            | "seq_mm"
    )
}

/// Expected input names of the coalesced (`*_mu`) head flavor, mirroring
/// python `model.serving_inputs_mu`: request-level operands first (slot-
/// stacked), then the row-aligned operands, then the `row_user` gather
/// index.
pub fn expected_input_names_mu(v: &VariantSpec) -> Vec<String> {
    let base = expected_input_names(v);
    let mut sig: Vec<String> = base
        .iter()
        .filter(|n| is_user_level_input(n))
        .cloned()
        .collect();
    sig.extend(base.iter().filter(|n| !is_user_level_input(n)).cloned());
    sig.push("row_user".into());
    sig
}

/// Split assembled regular-head inputs into the `_mu` job halves:
/// request-level tensors (squeezed to slot shape) and row-aligned
/// tensors, each in `expected_input_names_mu` order.
fn split_head_inputs(
    v: &VariantSpec,
    inputs: Vec<Tensor>,
) -> (Vec<Tensor>, Vec<Tensor>) {
    let names = expected_input_names(v);
    debug_assert_eq!(names.len(), inputs.len());
    let mut user = Vec::new();
    let mut rows = Vec::new();
    for (name, t) in names.iter().zip(inputs) {
        if is_user_level_input(name) {
            // `[1, w]` request vectors stack as `[U, w]` slots.
            if t.shape.len() > 1 && t.shape[0] == 1 {
                user.push(t.reshaped(t.shape[1..].to_vec()));
            } else {
                user.push(t);
            }
        } else {
            rows.push(t);
        }
    }
    (user, rows)
}

/// Packed signature rows for a sequence of item ids (static table).
pub fn packed_signs(world: &World, items: &[u32]) -> Vec<u8> {
    let pl = world.w_hash.shape()[0].div_ceil(8);
    let mut packed = Vec::with_capacity(items.len() * pl);
    for &i in items {
        packed.extend_from_slice(world.items_sign_packed.u8_row(i as usize));
    }
    packed
}

/// Same, padded to `batch` rows by repeating the last item.
pub fn packed_signs_padded(world: &World, items: &[u32], batch: usize) -> Vec<u8> {
    let mut packed = packed_signs(world, items);
    let last = world
        .items_sign_packed
        .u8_row(items[items.len() - 1] as usize);
    for _ in items.len()..batch {
        packed.extend_from_slice(last);
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aif_variant() -> VariantSpec {
        VariantSpec {
            name: "aif".into(),
            artifact: "head_aif".into(),
            user: "async".into(),
            item: "nearline".into(),
            bea: "bridge".into(),
            din_sim: "lsh".into(),
            tier_sim: "lsh".into(),
            sim_cross: true,
            sim_budget: 1.0,
        }
    }

    #[test]
    fn eligibility_needs_async_user_and_hoisted_long_term() {
        let aif = aif_variant();
        assert!(coalesce_eligible(&aif));

        let mut base = aif_variant();
        base.user = "cheap".into();
        assert!(
            !coalesce_eligible(&base),
            "inline user towers cannot coalesce"
        );

        let mut mm = aif_variant();
        mm.din_sim = "mm".into();
        assert!(
            !coalesce_eligible(&mm),
            "[L,.] operands in the head cannot coalesce"
        );

        let mut nolong = aif_variant();
        nolong.din_sim = "none".into();
        nolong.tier_sim = "none".into();
        assert!(coalesce_eligible(&nolong));
    }

    #[test]
    fn mu_signature_orders_user_slots_first() {
        let v = aif_variant();
        assert_eq!(
            expected_input_names(&v),
            vec![
                "u_vec",
                "item_vec",
                "bea_v",
                "bea_w",
                "din_base",
                "din_g",
                "item_sign",
                "tiers_in",
                "sim_cross"
            ]
        );
        assert_eq!(
            expected_input_names_mu(&v),
            vec![
                "u_vec",
                "bea_v",
                "din_base",
                "din_g",
                "item_vec",
                "bea_w",
                "item_sign",
                "tiers_in",
                "sim_cross",
                "row_user"
            ]
        );
    }

    #[test]
    fn split_head_inputs_matches_mu_halves() {
        let v = aif_variant();
        let b = 4;
        // Shapes as the regular head assembles them.
        let inputs = vec![
            Tensor::zeros(vec![1, 32]),  // u_vec
            Tensor::zeros(vec![b, 32]),  // item_vec
            Tensor::zeros(vec![8, 32]),  // bea_v
            Tensor::zeros(vec![b, 8]),   // bea_w
            Tensor::zeros(vec![1, 32]),  // din_base
            Tensor::zeros(vec![64, 32]), // din_g
            Tensor::zeros(vec![b, 64]),  // item_sign
            Tensor::zeros(vec![b, 8]),   // tiers_in
            Tensor::zeros(vec![b, 32]),  // sim_cross
        ];
        let (user, rows) = split_head_inputs(&v, inputs);
        // Slot shapes: leading request axis of 1 squeezed away.
        let user_shapes: Vec<Vec<usize>> =
            user.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(
            user_shapes,
            vec![vec![32], vec![8, 32], vec![32], vec![64, 32]]
        );
        let row_shapes: Vec<Vec<usize>> =
            rows.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(
            row_shapes,
            vec![
                vec![b, 32],
                vec![b, 8],
                vec![b, 64],
                vec![b, 8],
                vec![b, 32]
            ]
        );
    }
}
