//! The Merger — the system's serving facade (paper §3.1, Figures 2-5).
//!
//! Historically a ~1.2k-line monolith owning the whole substrate for ONE
//! variant; now a thin composition of the two halves it was split into
//! (DESIGN.md §13):
//!
//! * [`ServingCore`] — all interaction-independent, scenario-agnostic
//!   state (RTP fleet, feature store, world, nearline N2O table, caches,
//!   coalescer queues), built once;
//! * [`ScenarioRegistry`] — named [`ScenarioEngine`]s over that core, one
//!   per served scenario, hot add/remove/reload.
//!
//! `Merger::build` keeps its one-call bring-up contract: it builds the
//! core and registers every scenario block of the config (one derived
//! from the flat fields when none are declared).  `score` routes by
//! `ScoreRequest.scenario`, defaulting to the configured scenario, so
//! every pre-registry call site works unchanged.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::core::ServingCore;
use super::overload::Controller;
use super::scenario::{ScenarioEngine, ScenarioRegistry};
use super::service::{
    PreRanker, ScenarioAdmin, ScenarioInfo, ScoreRequest, ScoreResponse,
    ServeError,
};
use crate::config::ServingConfig;
use crate::metrics::ServingMetrics;
use crate::server::http::FrontendStats;
use crate::util::json::{Object, Value};

// Helpers that predate the split keep their `coordinator::merger::` paths.
pub use super::core::AUTO_REQUEST_ID_BASE;
pub use super::scenario::{
    coalesce_eligible, expected_input_names, expected_input_names_mu,
    packed_signs, packed_signs_padded,
};

pub struct Merger {
    core: Arc<ServingCore>,
    registry: Arc<ScenarioRegistry>,
    /// The default scenario's metrics + variant, cached so the
    /// [`PreRanker`] accessors can hand out references (reloads carry the
    /// metrics `Arc` over, and the default scenario cannot be removed, so
    /// both stay valid for the Merger's lifetime).
    default_metrics: Arc<ServingMetrics>,
    default_variant: String,
    /// Requests that failed ROUTING (unknown scenario) — kept separate so
    /// no scenario's error metric is charged for traffic it never saw.
    routing_errors: AtomicU64,
    /// Background checkpoint publisher (DESIGN.md §16), present when a
    /// storage backend and `checkpoint_interval_ms > 0` are configured.
    /// Held only for its Drop (stop + join).
    _checkpoint_driver: Option<CheckpointDriver>,
    /// Load-adaptive tiering feedback loop (DESIGN.md §20), present when
    /// `overload.enabled`.  Held only for its Drop (stop + join).
    _overload_controller: Option<Controller>,
}

/// Periodic checkpoint thread; stops and joins on drop so a Merger
/// tear-down never leaves a publisher writing to a dead store.
struct CheckpointDriver {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CheckpointDriver {
    fn start(core: Arc<ServingCore>, interval: Duration) -> CheckpointDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("aif-checkpoint".into())
            .spawn(move || {
                let tick = Duration::from_millis(5).min(interval);
                let mut since = Duration::ZERO;
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    since += tick;
                    if since < interval {
                        continue;
                    }
                    since = Duration::ZERO;
                    // Nothing to publish before the first nearline
                    // generation exists; checkpointing an empty v0 table
                    // would warm-boot the next process into no data.
                    if core.n2o.version_hint() == 0 {
                        continue;
                    }
                    if let Err(e) = core.checkpoint_now() {
                        log::warn!("periodic checkpoint failed: {e:#}");
                    }
                }
            })
            .expect("spawning the checkpoint thread");
        CheckpointDriver {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for CheckpointDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Merger {
    /// Bring up the shared core and register every scenario of the config.
    /// Runs the nearline full build when any scenario reads the N2O table.
    pub fn build(cfg: ServingConfig) -> Result<Merger> {
        let scenarios = cfg.effective_scenarios();
        let default = cfg.default_scenario_name();
        anyhow::ensure!(
            scenarios.iter().any(|s| s.name == default),
            "default_scenario {default:?} does not name a scenario block"
        );
        let core = ServingCore::build(cfg)?;
        let registry = Arc::new(ScenarioRegistry::new(
            Arc::clone(&core),
            default,
        ));
        for s in scenarios {
            registry.add(s)?;
        }
        let def = registry
            .get(None)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let interval_ms = core.cfg.storage.checkpoint_interval_ms;
        let checkpoint_driver = (core.storage.is_some() && interval_ms > 0)
            .then(|| {
                CheckpointDriver::start(
                    Arc::clone(&core),
                    Duration::from_millis(interval_ms),
                )
            });
        // Any scenario serving a nearline variant gets the streaming
        // update queue (DESIGN.md §17) — its table is already built by
        // registration, so this just starts the drain thread and wires
        // the `/metrics` nearline queue block.
        if registry
            .engines()
            .iter()
            .any(|e| e.variant.item == "nearline")
        {
            core.update_queue()
                .map_err(|e| anyhow::anyhow!("nearline update queue: {e:#}"))?;
        }
        // Every scenario is registered and any nearline boot (warm or
        // cold) has completed by now — `build` is synchronous.  Cores
        // whose scenarios never touch the N2O table would otherwise sit
        // in "starting" forever.
        core.readiness.set(crate::storage::ReadyState::Ready);
        // The tiering feedback loop (DESIGN.md §20).  Off by default; when
        // disabled every request serves at tier 0 (the full ladder rung)
        // and no controller thread exists.
        let overload_controller = core.cfg.overload.enabled.then(|| {
            Controller::start(
                core.cfg.overload.clone(),
                Arc::clone(&registry),
                Arc::clone(&core.overload_signals),
            )
        });
        Ok(Merger {
            default_metrics: Arc::clone(&def.metrics),
            default_variant: def.cfg.variant.clone(),
            routing_errors: AtomicU64::new(0),
            core,
            registry,
            _checkpoint_driver: checkpoint_driver,
            _overload_controller: overload_controller,
        })
    }

    /// Serve one request end to end, routed to its scenario (the
    /// configured default when the request doesn't name one) at the tier
    /// its SLA class currently maps to: `guaranteed` always gets tier 0,
    /// `degradable` the controller's tier, `best_effort` the trailing
    /// best-effort tier.  The served tier is stamped on the response (and
    /// trace) so degradation is always visible to the caller.
    pub fn score(
        &self,
        mut req: ScoreRequest,
    ) -> Result<ScoreResponse, ServeError> {
        let entry = match self.registry.entry(req.scenario.as_deref()) {
            Ok(e) => e,
            Err(e) => {
                // Attributed to routing, NOT to any scenario's metrics —
                // no engine saw this request.
                self.routing_errors.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        let sla = req.sla.unwrap_or(self.core.cfg.overload.default_sla);
        let (engine, tier) = entry.engine_at(entry.stats.tier_for(sla));
        let engine = Arc::clone(engine);
        // The rung's compute knob applies to explicit candidate lists
        // too: a deterministic prefix truncation, so scores stay
        // bitwise-stable within a tier (the rung's engine already clamps
        // the default retrieval count).
        if let Some(cap) = entry.ladder.get(tier).map(|s| s.max_candidates) {
            if cap > 0 {
                if let Some(c) = req.candidates.as_mut() {
                    c.truncate(cap);
                }
            }
        }
        let mut resp = engine.score(req)?;
        entry.stats.observe_served(tier, sla);
        resp.tier = Some(tier);
        if let Some(t) = resp.trace.as_mut() {
            t.tier = Some(tier);
        }
        Ok(resp)
    }

    /// Pin (or unpin with `None`) a scenario's served tier, overriding the
    /// controller for `degradable`/`best_effort` traffic.  `guaranteed`
    /// requests still serve at tier 0.  Used by the per-tier determinism
    /// tests and operational drills.
    pub fn force_tier(
        &self,
        scenario: Option<&str>,
        tier: Option<usize>,
    ) -> Result<(), ServeError> {
        self.registry.entry(scenario)?.stats.force_tier(tier);
        Ok(())
    }

    /// The shared substrate (fleet, stores, caches, N2O).
    pub fn core(&self) -> &Arc<ServingCore> {
        &self.core
    }

    /// The scenario registry (hot add/remove/reload).
    pub fn registry(&self) -> &Arc<ScenarioRegistry> {
        &self.registry
    }

    /// The engine serving the default scenario.
    pub fn default_engine(&self) -> Arc<ScenarioEngine> {
        self.registry
            .get(None)
            .expect("default scenario is always registered")
    }

    /// Shared-world accessor (oracle, candidate catalog).
    pub fn world(&self) -> &Arc<crate::features::World> {
        &self.core.world
    }

    /// Whether the default scenario routes head executions through the
    /// cross-request coalescer.
    pub fn coalescing(&self) -> bool {
        self.default_engine().coalescing()
    }

    /// §5.3 storage accounting: shared-core bytes ONCE plus the (thin)
    /// per-scenario deltas — never the same N2O/cache memory re-counted
    /// per registered scenario.
    pub fn extra_storage_bytes(&self) -> usize {
        self.core.shared_storage_bytes()
            + self
                .registry
                .engines()
                .iter()
                .map(|e| e.extra_storage_bytes_delta())
                .sum::<usize>()
    }
}

impl PreRanker for Merger {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        Merger::score(self, req)
    }

    fn variant_name(&self) -> &str {
        &self.default_variant
    }

    fn n_users(&self) -> usize {
        self.core.world.n_users
    }

    fn metrics(&self) -> &ServingMetrics {
        self.default_metrics.as_ref()
    }

    fn extra_storage_bytes(&self) -> usize {
        Merger::extra_storage_bytes(self)
    }
}

impl ScenarioAdmin for Merger {
    fn list_scenarios(&self) -> Vec<ScenarioInfo> {
        self.registry.infos()
    }

    fn default_scenario(&self) -> String {
        self.registry.default_name()
    }

    fn routing_errors(&self) -> u64 {
        self.routing_errors.load(Ordering::Relaxed)
    }

    fn reload_scenario(&self, name: &str) -> Result<ScenarioInfo, ServeError> {
        let engine = self.registry.reload(name)?;
        Ok(engine.info(name == self.registry.default_name()))
    }

    fn scenario_metrics(&self, wall: Duration) -> Vec<(String, Value)> {
        self.registry
            .engines()
            .iter()
            .map(|e| (e.name().to_string(), e.metrics.snapshot(wall)))
            .collect()
    }

    fn arena_stats(&self) -> Option<Value> {
        Some(self.core.arena.stats_snapshot())
    }

    fn user_cache_stats(&self) -> Option<Value> {
        Some(
            self.core
                .user_cache
                .stats_snapshot(self.core.user_epoch()),
        )
    }

    fn storage_stats(&self) -> Option<Value> {
        self.core.storage_stats().map(Value::from)
    }

    fn nearline_stats(&self) -> Option<Value> {
        Some(Value::from(self.core.nearline_stats()))
    }

    fn overload_stats(&self) -> Option<Value> {
        let mut o = Object::new();
        o.insert("enabled", self.core.cfg.overload.enabled);
        let mut scenarios = Object::new();
        for (name, snap) in self.registry.overload_snapshots() {
            scenarios.insert(name, snap);
        }
        o.insert("scenarios", Value::from(scenarios));
        Some(Value::from(o))
    }

    fn register_frontend(&self, stats: &Arc<FrontendStats>) {
        self.core.overload_signals.register(stats);
    }

    fn readiness(&self) -> Value {
        Value::from(self.core.readiness.as_json())
    }

    fn trigger_checkpoint(&self) -> Result<Value, ServeError> {
        if self.core.storage.is_none() {
            return Err(ServeError::BadRequest(
                "no storage backend configured".into(),
            ));
        }
        let outcome = self
            .core
            .checkpoint_now()
            .map_err(|e| ServeError::Internal(format!("{e:#}")))?;
        let mut o = Object::new();
        o.insert("outcome", outcome.name());
        if let Some(stats) = self.core.storage_stats() {
            o.insert("storage", stats);
        }
        Ok(Value::from(o))
    }
}
