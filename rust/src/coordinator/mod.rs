//! L3 coordinator — the paper's system contribution: the Merger two-phase
//! request lifecycle, consistent-hash routing, mini-batch scheduling and
//! the sequential baseline (all driven by one `ServingConfig`), behind the
//! typed [`PreRanker`] serving contract.

pub mod batcher;
pub mod merger;
pub mod router;
pub mod service;

pub use merger::{Merger, PhaseTimings, RequestResult};
pub use router::Router;
pub use service::{
    PreRanker, ScoreRequest, ScoreResponse, ScoreTrace, ScoredItem,
    ServeError, StageSpan,
};
