//! L3 coordinator — the paper's system contribution: the Merger two-phase
//! request lifecycle, consistent-hash routing, mini-batch scheduling and
//! the sequential baseline (all driven by one `ServingConfig`).

pub mod batcher;
pub mod merger;
pub mod router;

pub use merger::{Merger, PhaseTimings, RequestResult};
pub use router::Router;
