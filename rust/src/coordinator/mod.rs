//! L3 coordinator — the paper's system contribution: the two-phase
//! request lifecycle, consistent-hash routing, mini-batch scheduling and
//! the sequential baseline, all behind the typed [`PreRanker`] serving
//! contract — decomposed (DESIGN.md §13) into the shared
//! [`ServingCore`], per-scenario [`ScenarioEngine`]s managed by a
//! hot-swappable [`ScenarioRegistry`], and the thin [`Merger`] facade
//! that composes them.

pub mod batcher;
pub mod cluster;
pub mod core;
pub mod merger;
pub mod overload;
pub mod remote;
pub mod router;
pub mod scenario;
pub mod service;

pub use self::core::{ServingCore, AUTO_REQUEST_ID_BASE};
pub use cluster::Cluster;
pub use merger::Merger;
pub use overload::{
    Controller, EwmaState, LoadSample, LoadSignals, OverloadStats,
};
pub use remote::RemotePreRanker;
pub use router::Router;
pub use scenario::{ScenarioEngine, ScenarioRegistry, TieredScenario};
pub use service::{
    PhaseTimings, PreRanker, ScenarioAdmin, ScenarioInfo, ScoreRequest,
    ScoreResponse, ScoreTrace, ScoredItem, ServeError, StageSpan,
};
