//! Per-scenario serving engines + the hot-swappable registry
//! (DESIGN.md §13).
//!
//! A [`ScenarioEngine`] is the scenario-*specific* half of what used to be
//! the Merger monolith: a variant spec, a head-artifact handle, the
//! request pipeline (two-phase lifecycle, mini-batch fan-out) and its own
//! metrics — everything else comes from the shared
//! [`super::ServingCore`].  Engines are cheap: registering ten scenarios
//! costs ten small structs over one substrate, not ten fleets.
//!
//! The [`ScenarioRegistry`] maps scenario names to engines: readers
//! clone the engine `Arc` under a brief read lock and serve without
//! further coordination; `add`/`remove`/`reload` build the replacement
//! engine off to the side and swap it in under a short write section.
//! In-flight requests finish on the engine they started with — hot
//! reload is zero-downtime by construction.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher;
use super::core::{sim_budget_key, ServingCore, AUTO_REQUEST_ID_BASE};
use super::overload::{OverloadStats, OverloadView, MAX_TIERS};
use super::service::{
    PhaseTimings, PreRanker, ScenarioInfo, ScoreRequest, ScoreResponse,
    ScoreTrace, ScoredItem, ServeError, StageSpan,
};
use crate::cache::{
    ArenaPool, Claim, Flight, FlightGuard, PooledBuf, RequestKey,
    ShardedLru, UserAsync, UserKey, UserSide,
};
use crate::config::{ScenarioConfig, SimMode, TierSpec};
use crate::features::{assembly, FeatureStore, World};
use crate::lsh;
use crate::metrics::ServingMetrics;
use crate::nearline::N2oSnapshot;
use crate::retrieval::Retriever;
use crate::runtime::{
    BatchCoalescer, HeadJob, RtpPool, Tensor, VariantSpec,
};

/// One scenario's serving pipeline over the shared core.
pub struct ScenarioEngine {
    pub cfg: ScenarioConfig,
    pub variant: VariantSpec,
    /// Candidate generation is scenario-scoped (scenarios differ in
    /// candidate count); the latency model comes from the core config.
    pub retriever: Arc<Retriever>,
    pub metrics: Arc<ServingMetrics>,
    /// Cross-request dispatch scheduler + the `*_mu` artifact it serves
    /// (None = sequential per-request executions, the baseline path).
    /// Shared with every other scenario on the same head artifact.
    coalescer: Option<Arc<BatchCoalescer>>,
    mu_artifact: Option<String>,
    /// Request-independent mini-batch scoring context, shared by every
    /// fan-out task (one `Arc` clone per mini-batch, no per-batch state).
    scorer: Arc<BatchScorer>,
    core: Arc<ServingCore>,
    /// Unique instance id, salting the per-request user-cache keys so two
    /// scenarios serving the same (request id, user) never alias.
    engine_id: u64,
    /// Bumped on every reload of this scenario name.
    pub generation: u64,
}

impl ScenarioEngine {
    /// Build one engine over the shared core: hot-load its artifacts into
    /// the fleet, trigger the (once-only) nearline build when the variant
    /// reads the N2O table, validate the head signature and attach the
    /// (possibly shared) coalescer queue.
    pub fn build(
        core: &Arc<ServingCore>,
        cfg: ScenarioConfig,
        generation: u64,
        carry_metrics: Option<Arc<ServingMetrics>>,
    ) -> Result<Arc<ScenarioEngine>> {
        let manifest = &core.manifest;
        let variant = manifest.variant(&cfg.variant)?.clone();

        // Artifact set this scenario needs.
        let mut artifacts = vec![variant.artifact.clone()];
        if variant.user == "async" || variant.has_long() {
            // The user tower also supplies seq_emb for the non-async
            // long-term rows (computed on the request path there).
            artifacts.push("user_tower".into());
        }
        if variant.item == "nearline" {
            artifacts.push("item_tower".into());
        }
        // Cross-request coalescing rides on the multi-user (`*_mu`) head
        // flavor.  Absence (older artifact sets) degrades to the
        // per-request path with a warning instead of failing registration.
        let mu_artifact = if cfg.coalesce.enabled {
            let name = format!("{}_mu", variant.artifact);
            if !coalesce_eligible(&variant) {
                log::warn!(
                    "coalescing requested but variant {} is not eligible \
                     (needs async user + precomputable long-term head); \
                     serving per-request executions",
                    variant.name
                );
                None
            } else if !manifest.artifacts.contains_key(&name) {
                log::warn!(
                    "coalescing requested but artifact {name:?} is not in \
                     the manifest (re-run `make artifacts`); serving \
                     per-request executions"
                );
                None
            } else {
                Some(name)
            }
        } else {
            None
        };
        if let Some(name) = &mu_artifact {
            artifacts.push(name.clone());
        }
        core.rtp.ensure_artifacts(&artifacts)?;
        if variant.item == "nearline" {
            core.ensure_nearline()?;
        }

        // Validate the head signature against what we will assemble.
        let expected = expected_input_names(&variant);
        let actual: Vec<String> = manifest
            .artifact(&variant.artifact)?
            .inputs
            .iter()
            .map(|s| s.name.clone())
            .collect();
        anyhow::ensure!(
            expected == actual,
            "head {} signature mismatch: assembling {expected:?}, \
             manifest says {actual:?}",
            variant.artifact
        );

        // Attach the (shared) coalescer against the validated `_mu`
        // signature.
        let batch = core.batch;
        let mut coalescer = None;
        let mut co_stats = None;
        if let Some(name) = &mu_artifact {
            let spec = manifest.artifact(name)?;
            let expected_mu = expected_input_names_mu(&variant);
            let actual_mu: Vec<String> =
                spec.inputs.iter().map(|s| s.name.clone()).collect();
            anyhow::ensure!(
                expected_mu == actual_mu,
                "coalesced head {name} signature mismatch: assembling \
                 {expected_mu:?}, manifest says {actual_mu:?}"
            );
            let exec_rows = spec.outputs[0].shape[0];
            let max_slots = spec.inputs[0].shape[0];
            anyhow::ensure!(
                exec_rows >= batch && max_slots >= 1,
                "coalesced head {name}: {exec_rows} rows / {max_slots} \
                 slots cannot hold a {batch}-row mini-batch"
            );
            let (co, stats) =
                core.coalescer_for(name, &cfg.coalesce, exec_rows, max_slots);
            coalescer = Some(co);
            co_stats = Some(stats);
        }

        // Carried (reload) metrics keep their histograms ONLY while they
        // are wired to the same coalescer stats the rebuilt engine
        // dispatches into; if the attachment changed, start fresh so the
        // scenario's coalesce block never reports a disconnected object.
        let coalesce_wiring_matches = |m: &Arc<ServingMetrics>| match &co_stats
        {
            Some(stats) => Arc::ptr_eq(&m.coalesce, stats),
            None => true,
        };
        let metrics = match carry_metrics {
            Some(m) if coalesce_wiring_matches(&m) => m,
            _ => {
                let mut m = ServingMetrics::new();
                // Share the per-artifact coalescer counters so every
                // scenario on the queue reports the same dispatch stats.
                if let Some(stats) = &co_stats {
                    m.coalesce = Arc::clone(stats);
                }
                Arc::new(m)
            }
        };

        let retriever = Arc::new(Retriever::new(
            Arc::clone(&core.world),
            cfg.n_candidates,
            core.cfg.retrieval_latency.clone(),
        ));

        // The batch scorer is request-independent: build it ONCE here so
        // the per-request fan-out clones one `Arc` per mini-batch instead
        // of a bag of strings and handles (DESIGN.md §14).
        let scorer = Arc::new(BatchScorer {
            variant: variant.clone(),
            world: Arc::clone(&core.world),
            store: Arc::clone(&core.store),
            rtp: Arc::clone(&core.rtp),
            sim_cache: Arc::clone(&core.sim_cache),
            metrics: Arc::clone(&metrics),
            sim_mode: cfg.sim_mode,
            sim_budget: cfg.sim_budget,
            sim_parse_us: core.cfg.sim_parse_us,
            batch: core.batch,
            n_tiers: core.manifest.dim("N_TIERS"),
            head_artifact: variant.artifact.clone(),
            coalescer: coalescer.clone(),
            mu_artifact: mu_artifact.clone(),
            arena: core.zero_copy_arena(),
        });

        Ok(Arc::new(ScenarioEngine {
            engine_id: core.next_engine_id(),
            core: Arc::clone(core),
            coalescer,
            mu_artifact,
            metrics,
            retriever,
            scorer,
            variant,
            generation,
            cfg,
        }))
    }

    pub fn name(&self) -> &str {
        &self.cfg.name
    }

    /// Admin-listing row for this engine.
    pub fn info(&self, is_default: bool) -> ScenarioInfo {
        ScenarioInfo {
            name: self.cfg.name.clone(),
            variant: self.cfg.variant.clone(),
            is_default,
            generation: self.generation,
            requests: self.metrics.requests.load(Ordering::Relaxed),
            coalescing: self.coalescing(),
        }
    }

    pub fn core(&self) -> &Arc<ServingCore> {
        &self.core
    }

    /// Whether this scenario routes head executions through the
    /// cross-request coalescer.
    pub fn coalescing(&self) -> bool {
        self.coalescer.is_some()
    }

    /// The shared coalescer handle (tests assert cross-scenario sharing
    /// via `Arc::ptr_eq`).
    pub fn coalescer_handle(&self) -> Option<&Arc<BatchCoalescer>> {
        self.coalescer.as_ref()
    }

    /// Whether this scenario relies on the shared extra-storage substrate
    /// (N2O table / SIM pre-cache pool) — the paper's "[S]" column.
    pub fn uses_shared_storage(&self) -> bool {
        self.variant.item == "nearline"
            || (self.variant.sim_cross
                && self.cfg.sim_mode == SimMode::Precached)
    }

    /// §5.3 storage accounting, per-scenario half: resident bytes this
    /// scenario adds ON TOP of the shared core, relative to the
    /// sequential baseline.  Engines are deliberately thin: the only
    /// engine-owned allocation of note (the retriever's sampling table)
    /// exists in the baseline too, so it is not "extra" — the N2O /
    /// pre-cache bytes are counted once in
    /// [`ServingCore::shared_storage_bytes`], not once per scenario.
    pub fn extra_storage_bytes_delta(&self) -> usize {
        0
    }

    fn nickname(&self, user: usize) -> String {
        format!("e{}-user-{user}", self.engine_id)
    }

    /// Serve one request end to end through the typed contract.
    pub fn score(
        &self,
        mut req: ScoreRequest,
    ) -> Result<ScoreResponse, ServeError> {
        let result = self.serve(&mut req);
        if result.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn serve(
        &self,
        req: &mut ScoreRequest,
    ) -> Result<ScoreResponse, ServeError> {
        let t_total = Instant::now();
        let core = &self.core;

        // ---- validation (before any work is scheduled) -------------------
        let user = req.user;
        if user >= core.world.n_users {
            return Err(ServeError::UnknownUser(user));
        }
        let top_k = req.top_k.unwrap_or(self.cfg.top_k);
        if top_k == 0 {
            return Err(ServeError::BadRequest("top_k must be >= 1".into()));
        }
        if let Some(cands) = &req.candidates {
            if cands.is_empty() {
                return Err(ServeError::BadRequest(
                    "candidate override must be non-empty".into(),
                ));
            }
            if let Some(&bad) =
                cands.iter().find(|&&i| (i as usize) >= core.world.n_items)
            {
                return Err(ServeError::BadRequest(format!(
                    "unknown candidate item {bad}"
                )));
            }
        }
        if let Some(id) = req.request_id {
            if id >= AUTO_REQUEST_ID_BASE {
                return Err(ServeError::BadRequest(format!(
                    "request_id must be < 2^63 (got {id}; the top half \
                     is the auto-id space)"
                )));
            }
        }
        let request_id = req
            .request_id
            .unwrap_or_else(|| core.next_request_id());

        // ---- phase 1: online asynchronous user-side inference -----------
        // Cross-request reuse (the default, DESIGN.md §15): probe the
        // shared cache keyed by (engine, user, epoch).  A hit skips the
        // async phase entirely — phase 1 collapses to this probe.  A cold
        // key races for the single-flight slot: exactly ONE request leads
        // the `user_tower` call, concurrent requests for the same hot
        // user park on its result.  `user_reuse = false` keeps the
        // request-scoped put/take handoff bit-for-bit.
        let mut user_side: Option<UserSide> = None;
        let mut legacy_key: Option<RequestKey> = None;
        let phase1 = if self.variant.user == "async" {
            if core.user_cache.is_shared() {
                let ukey = UserKey::new(
                    self.engine_id,
                    user as u32,
                    core.user_epoch(),
                );
                match core.user_cache.claim(ukey) {
                    Claim::Hit(ua) => {
                        user_side = Some(UserSide::Hit);
                        Phase1::Ready(ua)
                    }
                    Claim::Join(flight) => {
                        user_side = Some(UserSide::Joined);
                        Phase1::Flight(flight)
                    }
                    Claim::Lead(flight) => {
                        user_side = Some(UserSide::Miss);
                        // Consistent-hash pinning by the SHARED key: every
                        // phase of every request for this (user, epoch)
                        // lands on one RTP worker (§3.4).
                        let worker = core.router.route(ukey.hash64());
                        let store = Arc::clone(&core.store);
                        let world = Arc::clone(&core.world);
                        let rtp = Arc::clone(&core.rtp);
                        let arena = core.zero_copy_arena();
                        // Guarded completion: if the task unwinds, the
                        // guard publishes an error and retires the
                        // flight — waiters fail instead of hanging.
                        let guard = FlightGuard::new(
                            Arc::clone(&core.user_cache),
                            ukey,
                            Arc::clone(&flight),
                        );
                        core.async_pool.spawn(move || {
                            let t0 = Instant::now();
                            let result = compute_user_async(
                                &store,
                                &world,
                                &rtp,
                                arena.as_ref(),
                                worker,
                                user,
                            );
                            // Waiters (and this request) resolve through
                            // the flight; abandonment of any one request
                            // cannot orphan the computation.
                            guard.complete(
                                result
                                    .map(|ua| (ua, t0.elapsed()))
                                    .map_err(|e| format!("{e:#}")),
                            );
                        });
                        Phase1::Flight(flight)
                    }
                }
            } else {
                user_side = Some(UserSide::Miss);
                let key = RequestKey::new(request_id, &self.nickname(user));
                legacy_key = Some(key);
                let worker = core.router.route(key.0);
                let (tx, rx) = channel::<Result<Duration>>();
                let store = Arc::clone(&core.store);
                let world = Arc::clone(&core.world);
                let rtp = Arc::clone(&core.rtp);
                let cache = Arc::clone(&core.user_cache);
                let arena = core.zero_copy_arena();
                core.async_pool.spawn(move || {
                    let t0 = Instant::now();
                    let result = compute_user_async(
                        &store,
                        &world,
                        &rtp,
                        arena.as_ref(),
                        worker,
                        user,
                    )
                    .map(|ua| {
                        cache.put(key, ua);
                        t0.elapsed()
                    });
                    let _ = tx.send(result);
                });
                Phase1::Legacy(rx)
            }
        } else {
            Phase1::None
        };

        // SIM pre-warming runs alongside retrieval too.  With the shared
        // cache it dedups through the same single-flight layer: N
        // concurrent requests for a hot user spawn ONE warmer.
        if self.variant.sim_cross && self.cfg.sim_mode == SimMode::Precached
        {
            let budget = self.cfg.sim_budget;
            let bkey = sim_budget_key(budget);
            if let Some(slot) =
                core.user_cache.sim_prewarm(bkey, user as u32)
            {
                let store = Arc::clone(&core.store);
                let world = Arc::clone(&core.world);
                let sim_cache = Arc::clone(&core.sim_cache);
                let parse_us = core.cfg.sim_parse_us;
                core.async_pool.spawn(move || {
                    // Slot released on every exit, panics included.
                    let _slot = slot;
                    // Only hit the remote store if any of the user's
                    // categories is cold; one multi-get covers them all
                    // (Figure 5).
                    let cats = world.user_sim_categories(user);
                    let cold = cats.iter().any(|&c| {
                        sim_cache.get(&(bkey, user as u32, c)).is_none()
                    });
                    if cold {
                        for (cat, sub) in
                            store.fetch_sim_all(user, budget, parse_us)
                        {
                            sim_cache.insert(
                                (bkey, user as u32, cat),
                                Arc::new(sub),
                            );
                        }
                    }
                });
            }
        }

        // ---- retrieval (upstream stage; blocks) -------------------------
        // A candidate override skips the retrieval stage entirely (the
        // caller already knows what to score) but keeps the phase-1 overlap.
        let t_r = Instant::now();
        // `Arc` so the mini-batch fan-out shares ONE candidate list
        // (tasks capture offsets, not per-batch copies of the ids); an
        // override vector is MOVED out of the request, not cloned.
        let candidates: Arc<Vec<u32>> = Arc::new(match req.candidates.take()
        {
            Some(c) => c,
            None => self.retriever.retrieve(user),
        });
        let retrieval = t_r.elapsed();

        // ---- join phase 1 -------------------------------------------------
        // `user_async` is the time THIS request spent on / waiting for
        // the user side: the leader's compute time, a joiner's park time,
        // `None` on a cache hit (no async phase ran at all).
        let (mut ua, user_async): (Option<Arc<UserAsync>>, Option<Duration>) =
            match &phase1 {
                Phase1::None => (None, None),
                Phase1::Ready(ua) => (Some(Arc::clone(ua)), None),
                Phase1::Flight(flight) => {
                    let t_w = Instant::now();
                    match flight.wait() {
                        Ok((ua, computed)) => {
                            let d = if user_side == Some(UserSide::Joined)
                            {
                                t_w.elapsed()
                            } else {
                                computed
                            };
                            (Some(ua), Some(d))
                        }
                        Err(e) => {
                            return Err(ServeError::Internal(format!(
                                "user async phase failed: {e}"
                            )))
                        }
                    }
                }
                Phase1::Legacy(rx) => {
                    let d = rx.recv().map_err(|_| {
                        ServeError::Internal("async phase died".into())
                    })??;
                    (None, Some(d)) // resolved by take() below
                }
            };

        // ---- deadline gate before the pre-rank phase ---------------------
        if let Err(e) = check_deadline(req.deadline, t_total) {
            // Request-scoped entries are keyed by THIS request and must
            // not leak when it is abandoned.  Shared entries stay: they
            // are reusable state other requests for this user will hit —
            // abandoning one request must not evict it (the LRU's
            // TTL/byte budget bounds residency instead).
            if let Some(key) = legacy_key {
                let _ = core.user_cache.take(key);
            }
            return Err(e);
        }
        if let Some(key) = legacy_key {
            // Legacy two-phase handoff: phase 2 consumes exactly once.
            ua = Some(Arc::new(core.user_cache.take(key).ok_or_else(
                || {
                    ServeError::Internal(format!(
                        "user async result missing for {key:?}"
                    ))
                },
            )?));
        }

        // ---- phase 2: real-time pre-ranking ------------------------------
        let t_p = Instant::now();
        let deadline_at = req.deadline.map(|budget| t_total + budget);
        let (scores, coalesce) =
            self.prerank(user, ua.as_deref(), &candidates, deadline_at)?;
        let prerank = t_p.elapsed();
        check_deadline(req.deadline, t_total)?;

        let top = batcher::top_k(&candidates, scores.as_slice(), top_k);
        drop(scores); // arena-backed: return the merged buffer now
        // Served items are what traffic actually cares about: feed the
        // heat signal that routes the update queue's priority lane
        // (wait-free relaxed counters; the hot path takes no lock here).
        core.heat.touch(top.iter().map(|&(item, _)| item));
        let timings = PhaseTimings {
            total: t_total.elapsed(),
            retrieval,
            user_async,
            prerank,
        };
        self.metrics.record_request(
            timings.total,
            timings.prerank,
            timings.user_async,
            timings.retrieval,
        );
        self.metrics
            .items_scored
            .fetch_add(candidates.len() as u64, Ordering::Relaxed);

        let trace = if req.trace {
            let mut stages = Vec::new();
            if let Some(ua) = user_async {
                stages.push(StageSpan {
                    stage: "user_async",
                    elapsed: ua,
                });
            }
            stages.push(StageSpan {
                stage: "retrieval",
                elapsed: retrieval,
            });
            stages.push(StageSpan {
                stage: "prerank",
                elapsed: prerank,
            });
            if coalesce.batches > 0 {
                stages.push(StageSpan {
                    stage: "coalesce_wait",
                    elapsed: coalesce.max_queue_wait,
                });
            }
            Some(ScoreTrace {
                n_candidates: candidates.len(),
                n_batches: candidates.len().div_ceil(core.batch),
                coalesced_batches: coalesce.batches,
                user_side: user_side.map(UserSide::as_str),
                // Stamped by the tier-resolving facade (Merger); a bare
                // engine has no ladder position.
                tier: None,
                stages,
            })
        } else {
            None
        };

        Ok(ScoreResponse {
            request_id,
            user,
            scenario: self.cfg.name.clone(),
            variant: self.cfg.variant.clone(),
            tier: None,
            items: top
                .into_iter()
                .map(|(item, score)| ScoredItem { item, score })
                .collect(),
            timings,
            trace,
        })
    }

    /// The real-time phase: score all candidates through the head
    /// artifact.  `ua` is the request's resolved user-side state (async
    /// variants; `None` otherwise) — cache hit, single-flight result or
    /// legacy take, all bitwise-identical by construction.
    fn prerank(
        &self,
        user: usize,
        ua: Option<&UserAsync>,
        candidates: &Arc<Vec<u32>>,
        deadline: Option<Instant>,
    ) -> Result<(MergedScores, CoalesceAgg)> {
        let core = &self.core;
        let v = &self.variant;

        // Sequential-baseline user-side work (on the critical path).
        let mut profile_t = None;
        let mut seq_short_t = None;
        let mut seq_emb_t = None;
        let mut din_base_t = None;
        let mut din_g_t = None;
        let mut seq_sign_packed: Option<Arc<Vec<u8>>> = None;
        let mut seq_len = 0usize;
        let mut seq_mm_t = None;
        if v.user != "async" {
            let uf = core.store.fetch_user(user);
            profile_t = Some(Tensor::new(
                vec![1, uf.profile.len()],
                uf.profile.clone(),
            ));
            seq_short_t =
                Some(assembly::gather_seq_emb(&core.world, &uf.short_seq));
            if v.has_long() {
                // The user-side long-term projections run here, on the
                // request path, via a synchronous user_tower call
                // (Table 4 "+LSH"/"+Long-term" rows).
                let packed = packed_signs(&core.world, &uf.long_seq);
                let plane = lsh::unpack_plane(
                    &packed,
                    uf.long_seq.len(),
                    core.world.w_hash.shape()[0],
                );
                let mut inputs =
                    assembly::user_tower_inputs(&core.world, &uf);
                inputs.push(plane);
                let out = core.rtp.call("user_tower", inputs)?;
                self.metrics
                    .rtp_calls
                    .fetch_add(1, Ordering::Relaxed);
                seq_emb_t = Some(out[2].clone());
                din_base_t = Some(out[3].clone());
                din_g_t = Some(out[4].clone());
                seq_len = uf.long_seq.len();
                seq_sign_packed = Some(Arc::new(packed));
                if v.needs_mm() {
                    seq_mm_t = Some(assembly::gather_mm(
                        &core.world,
                        &uf.long_seq,
                    ));
                }
            }
        } else if let Some(ua) = &ua {
            seq_emb_t = Some(ua.seq_emb.clone());
            din_base_t = Some(ua.din_base.clone());
            din_g_t = Some(ua.din_g.clone());
            seq_sign_packed = Some(Arc::clone(&ua.seq_sign_packed));
            seq_len = ua.long_seq.len();
            if v.needs_mm() {
                seq_mm_t =
                    Some(assembly::gather_mm(&core.world, &ua.long_seq));
            }
        }

        let (u_vec_t, bea_v_t) = match &ua {
            Some(ua) => (Some(ua.u_vec.clone()), Some(ua.bea_v.clone())),
            None => (None, None),
        };

        // -- N2O snapshot (one consistent generation per request) -----------
        let snapshot: Option<Arc<N2oSnapshot>> = if v.item == "nearline" {
            Some(Arc::new(core.n2o.snapshot()))
        } else {
            None
        };

        // -- per-mini-batch fan-out -----------------------------------------
        // The request-level context is built ONCE and shared by `Arc`:
        // each task captures three `Arc`s and two offsets — no per-batch
        // tensor-handle clones, no per-batch candidate copies.
        let ctx = Arc::new(BatchCtx {
            profile: profile_t,
            seq_short: seq_short_t,
            u_vec: u_vec_t,
            bea_v: bea_v_t,
            seq_emb: seq_emb_t,
            din_base: din_base_t,
            din_g: din_g_t,
            seq_sign_packed,
            seq_len,
            seq_mm: seq_mm_t,
            deadline,
        });
        let n = candidates.len();
        let n_batches = n.div_ceil(core.batch);
        let (tx, rx) = channel::<(usize, Result<BatchOutcome>)>();
        for index in 0..n_batches {
            let start = index * core.batch;
            let len = (n - start).min(core.batch);
            let tx = tx.clone();
            let scorer = Arc::clone(&self.scorer);
            let snapshot = snapshot.clone();
            let ctx = Arc::clone(&ctx);
            let cands = Arc::clone(candidates);
            core.score_pool.spawn(move || {
                let result = scorer.score_batch(
                    user,
                    &cands[start..start + len],
                    snapshot.as_deref(),
                    &ctx,
                );
                let _ = tx.send((index, result));
            });
        }
        drop(tx);

        let mut per_batch: Vec<Option<BatchScores>> =
            (0..n_batches).map(|_| None).collect();
        let mut agg = CoalesceAgg::default();
        for _ in 0..n_batches {
            let (idx, result) = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("batch worker died"))?;
            let outcome = result?;
            if let Some(wait) = outcome.queue_wait {
                agg.batches += 1;
                agg.max_queue_wait = agg.max_queue_wait.max(wait);
            }
            per_batch[idx] = Some(outcome.scores);
        }
        let per_batch: Vec<BatchScores> =
            per_batch.into_iter().map(|b| b.unwrap()).collect();
        // Zero-copy path: merge into an arena buffer (returned when the
        // response's top-K has been cut); legacy path keeps the owned vec.
        let merged = match core.zero_copy_arena() {
            Some(arena) => {
                let mut buf = arena.get(candidates.len());
                batcher::merge_scores_into(
                    candidates.len(),
                    core.batch,
                    &per_batch,
                    &mut buf,
                );
                MergedScores::Pooled(buf)
            }
            None => MergedScores::Owned(batcher::merge_scores(
                candidates.len(),
                core.batch,
                &per_batch,
            )),
        };
        Ok((merged, agg))
    }
}

impl PreRanker for ScenarioEngine {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, ServeError> {
        ScenarioEngine::score(self, req)
    }

    fn variant_name(&self) -> &str {
        &self.cfg.variant
    }

    fn n_users(&self) -> usize {
        self.core.world.n_users
    }

    fn metrics(&self) -> &ServingMetrics {
        self.metrics.as_ref()
    }

    fn extra_storage_bytes(&self) -> usize {
        // The per-scenario DELTA only; shared-core bytes are reported once
        // by `ServingCore::shared_storage_bytes` (see the Merger facade).
        self.extra_storage_bytes_delta()
    }
}

// ==========================================================================
// The registry
// ==========================================================================

/// One registered scenario with its execution-tier ladder: `tiers[0]` is
/// the full-fidelity engine, higher indices are the cheaper rungs the
/// overload controller degrades into (DESIGN.md §20).  Scenarios without
/// a configured ladder get the single-rung `[full(variant)]` — identical
/// to the pre-tiering registry.  The [`OverloadStats`] lives OUTSIDE the
/// engines and survives reload, so a reload under saturation keeps the
/// controller's tier instead of resetting to full.
#[derive(Clone)]
pub struct TieredScenario {
    pub tiers: Vec<Arc<ScenarioEngine>>,
    pub ladder: Vec<TierSpec>,
    pub stats: Arc<OverloadStats>,
}

impl TieredScenario {
    /// The engine at `tier`, clamped into the ladder.
    pub fn engine_at(&self, tier: usize) -> (&Arc<ScenarioEngine>, usize) {
        let t = tier.min(self.tiers.len() - 1);
        (&self.tiers[t], t)
    }
}

struct RegistryState {
    engines: HashMap<String, TieredScenario>,
    /// Registration order (stable listings).
    order: Vec<String>,
    default: String,
}

/// Name -> engine map behind a reader-writer lock.  Lookups clone the
/// engine `Arc` under a brief read lock and then serve lock-free;
/// `add`/`reload` build the replacement engine entirely OUTSIDE the lock
/// (artifact compiles included) and swap it in under a short write
/// section — in-flight requests hold their own engine `Arc` and finish on
/// it, so hot swaps are zero-downtime.
pub struct ScenarioRegistry {
    core: Arc<ServingCore>,
    state: RwLock<RegistryState>,
}

impl ScenarioRegistry {
    /// An empty registry over `core`; `default` is the scenario that
    /// serves requests not naming one (it does not need to exist yet).
    pub fn new(core: Arc<ServingCore>, default: String) -> ScenarioRegistry {
        ScenarioRegistry {
            core,
            state: RwLock::new(RegistryState {
                engines: HashMap::new(),
                order: Vec::new(),
                default,
            }),
        }
    }

    pub fn core(&self) -> &Arc<ServingCore> {
        &self.core
    }

    /// Register a new scenario (hot add).  Every ladder rung's engine is
    /// built outside the lock — traffic keeps flowing while artifacts
    /// compile.
    pub fn add(
        &self,
        cfg: ScenarioConfig,
    ) -> Result<Arc<ScenarioEngine>> {
        let name = cfg.name.clone();
        anyhow::ensure!(
            !self.state.read().unwrap().engines.contains_key(&name),
            "scenario {name:?} is already registered"
        );
        let tiers = build_ladder(&self.core, &cfg, 0, &[])?;
        let entry = TieredScenario {
            stats: Arc::new(OverloadStats::new(tiers.len())),
            ladder: cfg.effective_ladder(),
            tiers,
        };
        let engine = Arc::clone(&entry.tiers[0]);
        let mut state = self.state.write().unwrap();
        anyhow::ensure!(
            !state.engines.contains_key(&name),
            "scenario {name:?} was registered concurrently"
        );
        state.engines.insert(name.clone(), entry);
        state.order.push(name);
        Ok(engine)
    }

    /// Rebuild one scenario from its spec and swap it in (hot reload:
    /// re-resolves the variant, signature validation and coalescer
    /// attachment against the core's manifest, metrics carried over).
    /// The manifest is the one loaded at core startup — picking up
    /// re-exported artifact *files* still needs a process restart
    /// (artifact hot-swap is future work); reload's job is swapping
    /// engine state with zero downtime.  In-flight requests finish on
    /// the old engine.  If the scenario was removed or swapped by
    /// another admin while the replacement was building, the stale
    /// result is discarded instead of resurrecting it.
    pub fn reload(
        &self,
        name: &str,
    ) -> Result<Arc<ScenarioEngine>, ServeError> {
        let old = self
            .state
            .read()
            .unwrap()
            .engines
            .get(name)
            .cloned()
            .ok_or_else(|| ServeError::UnknownScenario(name.to_string()))?;
        let old_top = &old.tiers[0];
        let tiers = build_ladder(
            &self.core,
            &old_top.cfg,
            old_top.generation + 1,
            &old.tiers,
        )
        .map_err(|e| ServeError::Internal(format!("{e:#}")))?;
        // The overload state survives the swap: a reload during
        // saturation keeps serving at the controller's tier instead of
        // snapping back to full and spiking p99.  Only the ladder SIZE
        // is re-clamped (a shrunk ladder can't point past its end).
        old.stats.set_n_tiers(tiers.len());
        let entry = TieredScenario {
            stats: Arc::clone(&old.stats),
            ladder: old_top.cfg.effective_ladder(),
            tiers,
        };
        let engine = Arc::clone(&entry.tiers[0]);
        // Checkpoint barrier (DESIGN.md §16): the engine swap + epoch
        // bump is a version event, and a checkpoint captured halfway
        // through it would pair the old epoch with the new engine.
        // Serialize against checkpoint capture; the barrier is taken
        // BEFORE the registry write lock (same order everywhere).
        let mut crossings = self.core.checkpoint_barrier.lock().unwrap();
        *crossings += 1;
        let mut state = self.state.write().unwrap();
        match state.engines.get(name) {
            // Still the engines we rebuilt from: swap.
            Some(current) if Arc::ptr_eq(&current.tiers[0], old_top) => {
                state.engines.insert(name.to_string(), entry);
                // Invalidate cached cross-request user state: reload is a
                // version event, so the epoch moves and old entries stop
                // matching (they age out via TTL/LRU, no sweep needed).
                // The fresh engine id already salts the new keys; the
                // bump additionally covers engines sharing the core.
                self.core.user_cache.bump_epoch();
                Ok(engine)
            }
            // Removed while we were building: do NOT resurrect it.
            None => Err(ServeError::UnknownScenario(name.to_string())),
            // Concurrently swapped (another reload won): drop our stale
            // build; the caller can retry against the new engine.
            Some(_) => Err(ServeError::Internal(format!(
                "scenario {name:?} changed during reload; retry"
            ))),
        }
    }

    /// Remove a scenario (hot).  The default scenario cannot be removed —
    /// requests not naming a scenario must always have somewhere to go.
    pub fn remove(&self, name: &str) -> Result<(), ServeError> {
        let mut state = self.state.write().unwrap();
        if state.default == name {
            return Err(ServeError::BadRequest(format!(
                "cannot remove the default scenario {name:?}"
            )));
        }
        if state.engines.remove(name).is_none() {
            return Err(ServeError::UnknownScenario(name.to_string()));
        }
        state.order.retain(|n| n != name);
        Ok(())
    }

    /// Resolve a request's scenario: the named one, or the default.
    /// Returns the FULL (tier-0) engine — tier resolution is the
    /// facade's job via [`ScenarioRegistry::entry`].
    pub fn get(
        &self,
        name: Option<&str>,
    ) -> Result<Arc<ScenarioEngine>, ServeError> {
        Ok(Arc::clone(&self.entry(name)?.tiers[0]))
    }

    /// Resolve a request's scenario WITH its tier ladder and overload
    /// state (clones three `Arc`s under the brief read lock).
    pub fn entry(
        &self,
        name: Option<&str>,
    ) -> Result<TieredScenario, ServeError> {
        let state = self.state.read().unwrap();
        let key = name.unwrap_or(state.default.as_str());
        state
            .engines
            .get(key)
            .cloned()
            .ok_or_else(|| ServeError::UnknownScenario(key.to_string()))
    }

    pub fn default_name(&self) -> String {
        self.state.read().unwrap().default.clone()
    }

    pub fn len(&self) -> usize {
        self.state.read().unwrap().engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered names in registration order.
    pub fn names(&self) -> Vec<String> {
        self.state.read().unwrap().order.clone()
    }

    /// Admin listing (drives `GET /v1/scenarios`).
    pub fn infos(&self) -> Vec<ScenarioInfo> {
        let state = self.state.read().unwrap();
        state
            .order
            .iter()
            .filter_map(|n| state.engines.get(n))
            .map(|e| {
                let top = &e.tiers[0];
                top.info(top.cfg.name == state.default)
            })
            .collect()
    }

    /// Tier-0 engines in registration order (workload drivers iterate
    /// these).
    pub fn engines(&self) -> Vec<Arc<ScenarioEngine>> {
        let state = self.state.read().unwrap();
        state
            .order
            .iter()
            .filter_map(|n| state.engines.get(n))
            .map(|e| Arc::clone(&e.tiers[0]))
            .collect()
    }

    /// Controller view of every scenario: its overload state plus the
    /// metrics of every rung (for the windowed-p99 sample).
    pub fn overload_views(&self) -> Vec<OverloadView> {
        let state = self.state.read().unwrap();
        state
            .order
            .iter()
            .filter_map(|n| state.engines.get(n).map(|e| (n, e)))
            .map(|(n, e)| OverloadView {
                name: n.clone(),
                stats: Arc::clone(&e.stats),
                metrics: e
                    .tiers
                    .iter()
                    .map(|t| Arc::clone(&t.metrics))
                    .collect(),
            })
            .collect()
    }

    /// Per-scenario `overload` blocks for `/metrics`.
    pub fn overload_snapshots(&self) -> Vec<(String, crate::util::json::Value)>
    {
        let state = self.state.read().unwrap();
        state
            .order
            .iter()
            .filter_map(|n| state.engines.get(n).map(|e| (n, e)))
            .map(|(n, e)| (n.clone(), e.stats.snapshot(&e.ladder)))
            .collect()
    }
}

/// Build one engine per ladder rung.  Rung 0 carries the old tier-0
/// metrics on reload; rungs 1+ share rung 0's metrics object so the
/// scenario reports ONE latency/request stream wherever its requests
/// land on the ladder (the engine build falls back to a fresh object
/// only if a rung's coalescer wiring diverges).
fn build_ladder(
    core: &Arc<ServingCore>,
    cfg: &ScenarioConfig,
    generation: u64,
    old_tiers: &[Arc<ScenarioEngine>],
) -> Result<Vec<Arc<ScenarioEngine>>> {
    let ladder = cfg.effective_ladder();
    anyhow::ensure!(
        ladder.len() <= MAX_TIERS,
        "scenario {:?}: ladder has {} rungs (max {MAX_TIERS})",
        cfg.name,
        ladder.len()
    );
    let mut tiers: Vec<Arc<ScenarioEngine>> =
        Vec::with_capacity(ladder.len());
    for (i, rung) in ladder.iter().enumerate() {
        let mut rung_cfg = cfg.clone();
        rung_cfg.variant = rung.variant.clone();
        if rung.max_candidates > 0 {
            // The compute knob: fewer candidates through retrieval means
            // proportionally fewer mini-batches through the head.
            rung_cfg.n_candidates =
                rung_cfg.n_candidates.min(rung.max_candidates);
        }
        let carry = if i == 0 {
            old_tiers.first().map(|t| Arc::clone(&t.metrics))
        } else {
            Some(Arc::clone(&tiers[0].metrics))
        };
        tiers.push(ScenarioEngine::build(core, rung_cfg, generation, carry)?);
    }
    Ok(tiers)
}

// ==========================================================================
// Pipeline internals shared with the pre-registry Merger (moved verbatim)
// ==========================================================================

/// Phase-1 state of one request: how its user-side tensors will arrive.
enum Phase1 {
    /// Variant has no async user side.
    None,
    /// Shared-cache hit — the tensors are already here.
    Ready(Arc<UserAsync>),
    /// A single-flight computation (led by this request or joined) will
    /// publish into the shared slot.
    Flight(Arc<Flight>),
    /// Legacy request-scoped path: the spawned task puts under this
    /// request's key and reports its elapsed time here.
    Legacy(std::sync::mpsc::Receiver<Result<Duration>>),
}

/// The online asynchronous user-side computation (paper §3.1 phase 1):
/// fetch user features, sign the long-term sequence, run the user tower
/// on the pinned worker.  ONE implementation shared by the single-flight
/// leader and the legacy request-scoped task — which is what makes the
/// two modes bitwise-identical by construction.
fn compute_user_async(
    store: &FeatureStore,
    world: &World,
    rtp: &RtpPool,
    arena: Option<&Arc<ArenaPool>>,
    worker: usize,
    user: usize,
) -> Result<UserAsync> {
    let uf = store.fetch_user(user);
    // Signatures of the long-term sequence (static table): packed bytes
    // feed the SimTier popcount path; the ±1 plane goes into the tower so
    // it can emit the linearized DIN factors.
    let packed = packed_signs(world, &uf.long_seq);
    let n_bits = world.w_hash.shape()[0];
    // Zero-copy: the tower operands assemble into arena buffers too
    // (they retire with the RTP call).
    let mut inputs = assembly::user_tower_inputs_opt(world, &uf, arena);
    inputs.push(Tensor::build_with(
        arena,
        vec![uf.long_seq.len(), n_bits],
        |buf| {
            lsh::unpack_plane_into(&packed, uf.long_seq.len(), n_bits, buf)
        },
    ));
    let rx = rtp.call_async_on(worker, "user_tower", inputs);
    let out = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("RTP reply dropped"))??;
    Ok(UserAsync {
        u_vec: out[0].clone(),
        bea_v: out[1].clone(),
        seq_emb: out[2].clone(),
        din_base: out[3].clone(),
        din_g: out[4].clone(),
        seq_sign_packed: Arc::new(packed),
        long_seq: uf.long_seq,
    })
}

fn check_deadline(
    deadline: Option<Duration>,
    t0: Instant,
) -> Result<(), ServeError> {
    match deadline {
        Some(budget) if t0.elapsed() > budget => {
            Err(ServeError::DeadlineExceeded {
                budget_ms: budget.as_secs_f64() * 1e3,
                elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
            })
        }
        _ => Ok(()),
    }
}

/// Per-request aggregate of the coalesced dispatch path (zeroed when the
/// request ran plain per-request executions).
#[derive(Debug, Clone, Copy, Default)]
pub struct CoalesceAgg {
    /// Mini-batches of this request that went through the coalescer.
    pub batches: usize,
    /// Worst queue dwell any of them paid.
    pub max_queue_wait: Duration,
}

/// One mini-batch's scores: the direct RTP output tensor (zero-copy — no
/// `to_vec` of the padded scores) or an owned vector (coalesced replies /
/// the legacy owned path).
enum BatchScores {
    Tensor(Tensor),
    Owned(Vec<f32>),
}

impl AsRef<[f32]> for BatchScores {
    fn as_ref(&self) -> &[f32] {
        match self {
            BatchScores::Tensor(t) => t.data(),
            BatchScores::Owned(v) => v,
        }
    }
}

/// The request's merged score vector; arena-backed on the zero-copy path
/// (returned to the pool right after the top-K cut).
enum MergedScores {
    Owned(Vec<f32>),
    Pooled(PooledBuf),
}

impl MergedScores {
    fn as_slice(&self) -> &[f32] {
        match self {
            MergedScores::Owned(v) => v,
            MergedScores::Pooled(b) => b,
        }
    }
}

/// One mini-batch's scores plus how its execution was dispatched.
struct BatchOutcome {
    scores: BatchScores,
    /// Some(wait) when the batch went through the coalescer.
    queue_wait: Option<Duration>,
}

/// Request-level tensors shared by every mini-batch of the request.
struct BatchCtx {
    profile: Option<Tensor>,
    seq_short: Option<Tensor>,
    u_vec: Option<Tensor>,
    bea_v: Option<Tensor>,
    seq_emb: Option<Tensor>,
    din_base: Option<Tensor>,
    din_g: Option<Tensor>,
    seq_sign_packed: Option<Arc<Vec<u8>>>,
    seq_len: usize,
    seq_mm: Option<Tensor>,
    /// Absolute request deadline, for the coalescer's bypass decision.
    deadline: Option<Instant>,
}

/// The Send-able subset of the engine used inside batch tasks.
struct BatchScorer {
    variant: VariantSpec,
    world: Arc<World>,
    store: Arc<FeatureStore>,
    rtp: Arc<RtpPool>,
    sim_cache: Arc<ShardedLru<super::core::SimKey, Arc<Vec<u32>>>>,
    metrics: Arc<ServingMetrics>,
    sim_mode: SimMode,
    sim_budget: f64,
    sim_parse_us: f64,
    batch: usize,
    n_tiers: usize,
    head_artifact: String,
    coalescer: Option<Arc<BatchCoalescer>>,
    mu_artifact: Option<String>,
    /// Arena for mini-batch tensor assembly (`None` = the owned legacy
    /// path, kept for the hotpath bench's before/after comparison).
    arena: Option<Arc<ArenaPool>>,
}

impl BatchScorer {
    fn score_batch(
        &self,
        user: usize,
        items: &[u32],
        snapshot: Option<&N2oSnapshot>,
        ctx: &BatchCtx,
    ) -> Result<BatchOutcome> {
        let v = &self.variant;
        let mut inputs: Vec<Tensor> = Vec::with_capacity(8);

        // user slot
        if v.user == "async" {
            inputs.push(ctx.u_vec.clone().expect("u_vec"));
        } else {
            inputs.push(ctx.profile.clone().expect("profile"));
            inputs.push(ctx.seq_short.clone().expect("seq_short"));
        }

        // item slot (+ fetched features for inline/mm needs)
        let needs_fetch = v.item == "inline" || v.needs_mm() || v.sim_cross;
        let feats = if needs_fetch {
            Some(self.store.fetch_items(items))
        } else {
            None
        };
        let mut bea_w_nearline = None;
        let mut sign_nearline = None;
        if v.item == "nearline" {
            let snap = snapshot.expect("nearline snapshot");
            // One columnar gather straight out of the pinned generation's
            // flat chunks — pooled buffers on the zero-copy path.
            let (vec_t, w_t, s_t) = snap
                .assemble_opt(items, self.batch, self.arena.as_ref())
                .ok_or_else(|| anyhow::anyhow!("N2O rows missing"))?;
            inputs.push(vec_t);
            bea_w_nearline = Some(w_t);
            sign_nearline = Some(s_t);
        } else {
            inputs.push(assembly::item_raw_batch_opt(
                feats.as_ref().unwrap(),
                self.batch,
                self.arena.as_ref(),
            ));
        }

        // BEA slot
        if v.bea == "bridge" {
            inputs.push(ctx.bea_v.clone().expect("bea_v"));
            if v.item == "nearline" {
                inputs.push(bea_w_nearline.clone().expect("bea_w"));
            }
        }

        // long-term slot
        if v.tiers_precomputed() {
            // Hoisted serving split: DIN factors from the async pass +
            // SimTier via uint8 XNOR + popcount LUT (§4.2).  No [L, .]
            // operand is assembled at all.
            let item_packed =
                packed_signs_padded(&self.world, items, self.batch);
            let n_bits = self.world.w_hash.shape()[0];
            let item_sign = match &sign_nearline {
                Some(s) => s.clone(),
                None => Tensor::build_with(
                    self.arena.as_ref(),
                    vec![self.batch, n_bits],
                    |buf| {
                        lsh::unpack_plane_into(
                            &item_packed,
                            self.batch,
                            n_bits,
                            buf,
                        )
                    },
                ),
            };
            inputs.push(ctx.din_base.clone().expect("din_base"));
            inputs.push(ctx.din_g.clone().expect("din_g"));
            inputs.push(item_sign);
            let seq_packed =
                ctx.seq_sign_packed.as_ref().expect("seq packed");
            inputs.push(Tensor::build_with(
                self.arena.as_ref(),
                vec![self.batch, self.n_tiers],
                |buf| {
                    lsh::tier_histogram_into(
                        &item_packed,
                        self.batch,
                        seq_packed,
                        ctx.seq_len,
                        n_bits,
                        self.n_tiers,
                        buf,
                    )
                },
            ));
        } else if v.has_long() {
            inputs.push(ctx.seq_emb.clone().expect("seq_emb"));
            if v.needs_lsh() {
                unreachable!("mixed lsh variants are not served");
            }
            if v.needs_mm() {
                inputs.push(assembly::item_mm_batch_opt(
                    feats.as_ref().unwrap(),
                    self.batch,
                    self.arena.as_ref(),
                ));
                inputs.push(ctx.seq_mm.clone().expect("seq_mm"));
            }
        }

        // SIM cross slot
        if v.sim_cross {
            let cats: Vec<u32> = items
                .iter()
                .map(|&i| self.world.category_of(i))
                .collect();
            let store = &self.store;
            let world = &self.world;
            let sim_cache = &self.sim_cache;
            let (mode, budget, parse_us) =
                (self.sim_mode, self.sim_budget, self.sim_parse_us);
            let bkey = sim_budget_key(budget);
            let subseq_of = |cat| match mode {
                SimMode::Off => Vec::new(),
                SimMode::Sync => store.fetch_sim_subsequence(
                    user, cat, budget, parse_us,
                ),
                SimMode::Precached => sim_cache
                    .get_or_insert_with((bkey, user as u32, cat), || {
                        Arc::new(store.fetch_sim_subsequence(
                            user, cat, budget, parse_us,
                        ))
                    })
                    .as_ref()
                    .clone(),
            };
            inputs.push(assembly::sim_cross_batch_opt(
                world,
                &cats,
                self.batch,
                subseq_of,
                self.arena.as_ref(),
            ));
        }

        // Dispatch: through the cross-request coalescer when enabled, as
        // a plain per-request execution otherwise.  Both paths score the
        // same rows through the same math — coalescing is score-invariant
        // (the bench pins identical top-K with the knob on and off).
        if let (Some(co), Some(mu)) = (&self.coalescer, &self.mu_artifact) {
            let (user_inputs, row_inputs) =
                split_head_inputs(&self.variant, inputs);
            let (reply, rx) = channel();
            co.submit(HeadJob {
                artifact: mu.clone(),
                rows: items.len(),
                row_inputs,
                user_inputs,
                deadline: ctx.deadline,
                reply,
            });
            let js = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("coalescer dropped the reply"))??;
            return Ok(BatchOutcome {
                scores: BatchScores::Owned(js.scores),
                queue_wait: Some(js.queue_wait),
            });
        }

        let scores = self.rtp.call1(&self.head_artifact, inputs)?;
        self.metrics.rtp_calls.fetch_add(1, Ordering::Relaxed);
        // Zero-copy: keep the output tensor and merge straight from it;
        // the legacy path copies out (the allocation the bench counts).
        let scores = match &self.arena {
            Some(_) => BatchScores::Tensor(scores),
            None => BatchScores::Owned(scores.data().to_vec()),
        };
        Ok(BatchOutcome {
            scores,
            queue_wait: None,
        })
    }
}

/// Expected head-input names, mirroring python `model.serving_inputs`.
pub fn expected_input_names(v: &VariantSpec) -> Vec<String> {
    let mut sig: Vec<&str> = Vec::new();
    if v.user == "async" {
        sig.push("u_vec");
    } else {
        sig.push("profile");
        sig.push("seq_short");
    }
    if v.item == "nearline" {
        sig.push("item_vec");
    } else {
        sig.push("item_raw");
    }
    if v.bea == "bridge" {
        sig.push("bea_v");
        if v.item == "nearline" {
            sig.push("bea_w");
        }
    }
    if v.tiers_precomputed() {
        sig.push("din_base");
        sig.push("din_g");
        sig.push("item_sign");
        sig.push("tiers_in");
    } else if v.has_long() {
        sig.push("seq_emb");
        if v.needs_lsh() {
            sig.push("item_sign");
            sig.push("seq_sign");
        }
        if v.needs_mm() {
            sig.push("item_mm");
            sig.push("seq_mm");
        }
    }
    if v.sim_cross {
        sig.push("sim_cross");
    }
    sig.into_iter().map(String::from).collect()
}

/// Whether a variant's head can serve coalesced multi-user batches.  The
/// `_mu` artifact gathers per-row user context by a `row_user` index, so
/// the request-level operands must be compact: the async user vector plus
/// (for long-term variants) the hoisted DIN factors.  Variants that feed
/// `[L, .]` sequence operands into the head cannot coalesce.
pub fn coalesce_eligible(v: &VariantSpec) -> bool {
    v.user == "async" && (!v.has_long() || v.tiers_precomputed())
}

/// Head inputs that are request-level (one slot per request in the `_mu`
/// artifact) as opposed to row-aligned.
fn is_user_level_input(name: &str) -> bool {
    matches!(
        name,
        "u_vec"
            | "bea_v"
            | "din_base"
            | "din_g"
            | "profile"
            | "seq_short"
            | "seq_emb"
            | "seq_sign"
            | "seq_mm"
    )
}

/// Expected input names of the coalesced (`*_mu`) head flavor, mirroring
/// python `model.serving_inputs_mu`: request-level operands first (slot-
/// stacked), then the row-aligned operands, then the `row_user` gather
/// index.
pub fn expected_input_names_mu(v: &VariantSpec) -> Vec<String> {
    let base = expected_input_names(v);
    let mut sig: Vec<String> = base
        .iter()
        .filter(|n| is_user_level_input(n))
        .cloned()
        .collect();
    sig.extend(base.iter().filter(|n| !is_user_level_input(n)).cloned());
    sig.push("row_user".into());
    sig
}

/// Request-level operands assembled with a leading request axis of 1
/// (`[1, w]` vectors) — squeezed to slot shape before slot-stacking.
/// Matrix operands (`bea_v [n, D]`, `din_g [d', D]`, sequence rows) keep
/// their shape even when a dimension happens to be 1, so the merged
/// `_mu` input rank always matches the compiled artifact.
fn is_request_vector_input(name: &str) -> bool {
    matches!(name, "u_vec" | "din_base" | "profile")
}

/// Split assembled regular-head inputs into the `_mu` job halves:
/// request-level tensors (squeezed to slot shape) and row-aligned
/// tensors, each in `expected_input_names_mu` order.
fn split_head_inputs(
    v: &VariantSpec,
    inputs: Vec<Tensor>,
) -> (Vec<Tensor>, Vec<Tensor>) {
    let names = expected_input_names(v);
    debug_assert_eq!(names.len(), inputs.len());
    let mut user = Vec::new();
    let mut rows = Vec::new();
    for (name, t) in names.iter().zip(inputs) {
        if is_user_level_input(name) {
            // `[1, w]` request vectors stack as `[U, w]` slots; squeeze
            // by NAME, not by shape — a bea_v/din_g whose first axis is
            // legitimately 1 must keep its rank.
            if is_request_vector_input(name)
                && t.shape.len() > 1
                && t.shape[0] == 1
            {
                user.push(t.reshaped(t.shape[1..].to_vec()));
            } else {
                user.push(t);
            }
        } else {
            rows.push(t);
        }
    }
    (user, rows)
}

/// Packed signature rows for a sequence of item ids (static table).
pub fn packed_signs(world: &World, items: &[u32]) -> Vec<u8> {
    let pl = world.w_hash.shape()[0].div_ceil(8);
    let mut packed = Vec::with_capacity(items.len() * pl);
    for &i in items {
        packed.extend_from_slice(world.items_sign_packed.u8_row(i as usize));
    }
    packed
}

/// Same, padded to `batch` rows by repeating the last item.
pub fn packed_signs_padded(world: &World, items: &[u32], batch: usize) -> Vec<u8> {
    let mut packed = packed_signs(world, items);
    let last = world
        .items_sign_packed
        .u8_row(items[items.len() - 1] as usize);
    for _ in items.len()..batch {
        packed.extend_from_slice(last);
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aif_variant() -> VariantSpec {
        VariantSpec {
            name: "aif".into(),
            artifact: "head_aif".into(),
            user: "async".into(),
            item: "nearline".into(),
            bea: "bridge".into(),
            din_sim: "lsh".into(),
            tier_sim: "lsh".into(),
            sim_cross: true,
            sim_budget: 1.0,
        }
    }

    #[test]
    fn eligibility_needs_async_user_and_hoisted_long_term() {
        let aif = aif_variant();
        assert!(coalesce_eligible(&aif));

        let mut base = aif_variant();
        base.user = "cheap".into();
        assert!(
            !coalesce_eligible(&base),
            "inline user towers cannot coalesce"
        );

        let mut mm = aif_variant();
        mm.din_sim = "mm".into();
        assert!(
            !coalesce_eligible(&mm),
            "[L,.] operands in the head cannot coalesce"
        );

        let mut nolong = aif_variant();
        nolong.din_sim = "none".into();
        nolong.tier_sim = "none".into();
        assert!(coalesce_eligible(&nolong));
    }

    #[test]
    fn mu_signature_orders_user_slots_first() {
        let v = aif_variant();
        assert_eq!(
            expected_input_names(&v),
            vec![
                "u_vec",
                "item_vec",
                "bea_v",
                "bea_w",
                "din_base",
                "din_g",
                "item_sign",
                "tiers_in",
                "sim_cross"
            ]
        );
        assert_eq!(
            expected_input_names_mu(&v),
            vec![
                "u_vec",
                "bea_v",
                "din_base",
                "din_g",
                "item_vec",
                "bea_w",
                "item_sign",
                "tiers_in",
                "sim_cross",
                "row_user"
            ]
        );
    }

    #[test]
    fn split_head_inputs_matches_mu_halves() {
        let v = aif_variant();
        let b = 4;
        // Shapes as the regular head assembles them.
        let inputs = vec![
            Tensor::zeros(vec![1, 32]),  // u_vec
            Tensor::zeros(vec![b, 32]),  // item_vec
            Tensor::zeros(vec![8, 32]),  // bea_v
            Tensor::zeros(vec![b, 8]),   // bea_w
            Tensor::zeros(vec![1, 32]),  // din_base
            Tensor::zeros(vec![64, 32]), // din_g
            Tensor::zeros(vec![b, 64]),  // item_sign
            Tensor::zeros(vec![b, 8]),   // tiers_in
            Tensor::zeros(vec![b, 32]),  // sim_cross
        ];
        let (user, rows) = split_head_inputs(&v, inputs);
        // Slot shapes: leading request axis of 1 squeezed away.
        let user_shapes: Vec<Vec<usize>> =
            user.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(
            user_shapes,
            vec![vec![32], vec![8, 32], vec![32], vec![64, 32]]
        );
        let row_shapes: Vec<Vec<usize>> =
            rows.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(
            row_shapes,
            vec![
                vec![b, 32],
                vec![b, 8],
                vec![b, 64],
                vec![b, 8],
                vec![b, 32]
            ]
        );
    }
}
